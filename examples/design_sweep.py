"""Design-space exploration: sweep PIM parameters with the simulator.

The paper positions LP5X-PIM Sim as "a robust framework for exploring PIM
architectures and software stacks"; this example sweeps two hardware knobs
(MAC interval, SRF capacity) and one software knob (reshape) and prints
the speedup surface — the kind of study the simulator exists for.

    PYTHONPATH=src python examples/design_sweep.py
"""
import dataclasses

from repro.core import engine
from repro.core.pimsim import PimSimulator
from repro.core.timing import PimSpec, SystemSpec
from repro.pimkernel.tileconfig import PimDType

H = W = 4096
DT = PimDType.W8A8

print(f"speedup surface for {H}x{W} {DT.name} "
      "(rows: MAC interval CK; cols: SRF bytes)\n")
srf_options = (256, 512, 1024)
print("          " + "".join(f"srf={s:<6}" for s in srf_options))
for mac in (2, 3, 4, 6):
    row = []
    for srf in srf_options:
        spec = SystemSpec(pim=PimSpec(mac_interval_ck=mac, srf_bytes=srf))
        row.append(PimSimulator(spec).speedup(H, W, DT))
    print(f"mac={mac} CK  " + "".join(f"{s:<10.2f}" for s in row))

print("\nlesson: the MAC interval dominates (compute-limited MB mode); "
      "doubling SRF helps only the small-tile dtypes via fewer chunk "
      "reloads.")

# The timing configuration is traced fleet data, not a compile-time
# constant: the 12 spec variants above shared a handful of engine
# executables (one per stream-length bucket), not one each.
print(f"\nengine executables compiled for the whole surface: "
      f"{engine.compile_cache_size()}")

print("\nsoftware knob — reshape split cap (paper caps gains ~1.65x):")
for cap in (1, 2, 4):
    spec = SystemSpec(pim=PimSpec(max_reshape_split=cap))
    sim = PimSimulator(spec)
    g = sim.gemv(1024, 4096, DT, reshape=False).ns / \
        sim.gemv(1024, 4096, DT, reshape=True).ns
    print(f"  max_split={cap}: reshape gain {g:.2f}x at H=1024")
