"""Design-space exploration: sweep PIM parameters with the simulator.

The paper positions LP5X-PIM Sim as "a robust framework for exploring PIM
architectures and software stacks"; this example sweeps two hardware knobs
(MAC interval, SRF capacity) and one software knob (reshape) and prints
the speedup surface — the kind of study the simulator exists for.

Since the facade is spec-vectorized, the WHOLE heterogeneous surface —
12 hardware variants x (PIM + baseline) — is one ``run_many`` fleet call:
every stream resolves in a single batched engine dispatch, and because
the timing configuration is traced fleet data the variants share a
handful of compiled executables instead of compiling one each.

    PYTHONPATH=src python examples/design_sweep.py
"""
from repro.core import engine
from repro.core.pimsim import PimSimulator
from repro.core.timing import PimSpec, SystemSpec
from repro.pimkernel.executor import GemvRequest
from repro.pimkernel.tileconfig import PimDType

H = W = 4096
DT = PimDType.W8A8

mac_options = (2, 3, 4, 6)
srf_options = (256, 512, 1024)
variants = {(mac, srf): SystemSpec(pim=PimSpec(mac_interval_ck=mac,
                                               srf_bytes=srf))
            for mac in mac_options for srf in srf_options}

# One fleet call for the entire surface: every variant's PIM point and
# its host baseline ride the same resolve_fleet batch.
sim = PimSimulator()
reqs = [r for spec in variants.values()
        for r in (GemvRequest.baseline(H, W, DT, spec=spec),
                  GemvRequest.pim(H, W, DT, spec=spec))]
res = sim.run_many(reqs)
speedup = {key: base.ns / pim.ns
           for key, (base, pim) in zip(variants,
                                       zip(res[::2], res[1::2]))}

print(f"speedup surface for {H}x{W} {DT.name} "
      "(rows: MAC interval CK; cols: SRF bytes)\n")
print("          " + "".join(f"srf={s:<6}" for s in srf_options))
for mac in mac_options:
    row = "".join(f"{speedup[(mac, srf)]:<10.2f}" for srf in srf_options)
    print(f"mac={mac} CK  " + row)

print("\nlesson: the MAC interval dominates (compute-limited MB mode); "
      "doubling SRF helps only the small-tile dtypes via fewer chunk "
      "reloads.")

# The timing configuration is traced fleet data, not a compile-time
# constant: the 12 spec variants above shared a handful of engine
# executables (one per stream-length bucket), not one each.
print(f"\nengine executables compiled for the whole surface: "
      f"{engine.compile_cache_size()}")

print("\nsoftware knob — reshape split cap (paper caps gains ~1.65x):")
cap_specs = {cap: SystemSpec(pim=PimSpec(max_reshape_split=cap))
             for cap in (1, 2, 4)}
cap_reqs = [r for spec in cap_specs.values()
            for r in (GemvRequest.pim(1024, 4096, DT, spec=spec),
                      GemvRequest.pim(1024, 4096, DT, reshape=True,
                                      spec=spec))]
cap_res = sim.run_many(cap_reqs)
for cap, (flat, shaped) in zip(cap_specs, zip(cap_res[::2], cap_res[1::2])):
    print(f"  max_split={cap}: reshape gain {flat.ns/shaped.ns:.2f}x "
          f"at H=1024")
