"""Quickstart: simulate a GEMV on LP5X-PIM and reproduce a Fig-4 point.

Runs the full paper pipeline — Data Mapper placement, IRF code gen,
command-stream synthesis, cycle-accurate timing, energy — plus the
functional HW/SW co-simulation proving the command stream computes the
right answer.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.pimsim import PimSimulator
from repro.pimkernel.tileconfig import PimDType

sim = PimSimulator()

# --- paper Fig. 4 headline point: 4096x4096 W8A8 ------------------------
H = W = 4096
dt = PimDType.W8A8
pim = sim.gemv(H, W, dt)
base = sim.baseline(H, W, dt)
print(f"GEMV {H}x{W} {dt.name} on LPDDR5X-9600 x4ch")
print(f"  non-PIM sequential weight read : {base.ns/1e3:8.1f} us")
print(f"  LP5X-PIM (MB-mode broadcast)   : {pim.ns/1e3:8.1f} us")
print(f"  speedup                        : {base.ns/pim.ns:8.2f}x "
      f"(paper: 6.0-6.2x)")
fenced = sim.gemv(H, W, dt, fence=True)
print(f"  with 150 ns fences             : {base.ns/fenced.ns:8.2f}x "
      f"(paper: >5x)")
print(f"  energy                         : "
      f"{pim.energy['pj_per_op']:8.2f} pJ/op vs "
      f"{base.energy['pj_per_op']:.2f} pJ/op baseline")

# --- behavioral fidelity: the command stream computes W @ x -------------
rng = np.random.default_rng(0)
Hs, Ws = 256, 2048
weights = rng.integers(-128, 128, size=(Hs, Ws)).astype(np.int32)
x = rng.integers(-128, 128, size=(Ws,)).astype(np.int32)
y, res = sim.gemv_functional(weights, x, dt)
ok = np.array_equal(y, weights.astype(np.int64) @ x.astype(np.int64))
print(f"\nHW/SW co-simulation on {Hs}x{Ws}: streams -> device model "
      f"== numpy GEMV? {ok}")
print(f"  {res.cycles} cycles, utilization {res.utilization:.0%}, "
      f"{int(res.counts.sum())} DRAM/PIM commands")

# --- batched co-simulation: many (weights, x) in one timing dispatch ----
from repro.pimkernel.executor import FunctionalGemv

items = []
for hs, ws in ((128, 512), (192, 1024), (64, 2048)):
    wm = rng.integers(-8, 8, size=(hs, ws)).astype(np.int32)
    xv = rng.integers(-8, 8, size=(ws,)).astype(np.int32)
    items.append(FunctionalGemv(wm, xv, PimDType.W4A8))
all_ok = all(
    np.array_equal(y, it.weights.astype(np.int64) @ it.x.astype(np.int64))
    for it, (y, _r) in zip(items, sim.gemv_functional_many(items)))
print(f"\nBatched co-simulation ({len(items)} GEMVs, one engine "
      f"dispatch): all exact? {all_ok}")

# --- reshape optimization (paper §3.3) ----------------------------------
small_h = 1024
t0 = sim.gemv(small_h, 4096, dt, reshape=False)
t1 = sim.gemv(small_h, 4096, dt, reshape=True)
print(f"\nReshape optimization at H={small_h}: {t0.ns/t1.ns:.2f}x gain "
      f"(paper: up to 1.65x), utilization "
      f"{t0.utilization:.0%} -> {t1.utilization:.0%}")
