"""End-to-end driver: serve a small LM with batched requests + PIM offload.

The paper's use case is on-device LLM inference: decode-phase matmuls are
GEMVs against resident weights, exactly what LP5X-PIM accelerates.  This
example serves a reduced granite-8b with continuous batching and reports
the simulator-predicted decode speedup of offloading each projection to
the LPDDR5X-PIM memory system.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.pimsim import PimSimulator
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import OffloadPlanner
from repro.serving.policy import OffloadController

full_cfg = ARCHS["granite-8b"]
cfg = smoke_config(full_cfg)
params = M.init_params(cfg, jax.random.PRNGKey(0))
planner = OffloadPlanner(full_cfg, PimSimulator())
# Adaptive offload control: the hysteresis policy damps decision flips
# near the crossover batch, so it needs one planner query for the whole
# run instead of one per decode step.
controller = OffloadController(planner, policy="hysteresis")
engine = ServingEngine(cfg, params, slots=4, max_seq=96, planner=planner,
                       controller=controller)

rng = np.random.default_rng(0)
requests = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5 + i),
                    max_new=6) for i in range(10)]
for r in requests:
    engine.submit(r)
stats = engine.run(max_steps=500)

print(f"completed {len(requests)} requests "
      f"({stats['tokens']} generated tokens, {stats['steps']} decode "
      f"steps, continuous batching over 4 slots)")
for r in requests[:3]:
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")

tel = stats["pim_telemetry"]
print(f"\nLP5X-PIM offload for the full {full_cfg.name} decode step "
      f"(batch={tel['batch']}):")
print(f"  host-only GEMV time : {tel['host_ns']/1e3:9.1f} us")
print(f"  PIM-offloaded       : {tel['mixed_ns']/1e3:9.1f} us   "
      f"-> {tel['speedup']:.2f}x")
print(f"  offloaded sites: {', '.join(tel['offloaded'][:6])} ... "
      f"({len(tel['offloaded'])}/{tel['n_sites']})")

rep = stats["policy"]
print(f"\nadaptive offload control ({rep['policy']} policy):")
print(f"  realized speedup {rep['realized_speedup']:.2f}x vs oracle "
      f"{rep['oracle_speedup']:.2f}x (efficiency {rep['efficiency']:.3f})")
print(f"  {rep['switches']} decision switches, "
      f"{rep['planner_queries']} planner queries over {rep['steps']} steps")

# batch-size sweep: where does PIM stop winning?
print("\nbatch-size crossover (decode-step speedup from offload):")
for b in (1, 2, 4, 8, 16, 32):
    s = planner.decode_speedup(batch=b)["speedup"]
    print(f"  batch {b:3d}: {s:5.2f}x")
