"""Train a small LM end-to-end with the production training stack.

Reduced granite-8b on synthetic data: microbatched grad accumulation,
int8 gradient compression with error feedback, async checkpointing, and
a checkpoint/restart drill halfway through.

    PYTHONPATH=src python examples/train_small.py [--steps 120]
"""
import argparse
import tempfile

import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.training.grad_compress import CompressionConfig
from repro.training.trainer import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

cfg = smoke_config(ARCHS["granite-8b"])
ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
tcfg = TrainConfig(lr=3e-3, warmup=10, total_steps=args.steps,
                   microbatches=2,
                   compression=CompressionConfig("int8"),
                   ckpt_every=args.steps // 2, ckpt_dir=ckpt_dir,
                   remat=False)
trainer = Trainer(cfg, tcfg)
src = SyntheticLM(cfg.vocab, seed=0)


def batches(start):
    step = start
    while True:
        yield {k: jnp.asarray(v) for k, v in src.batch(step, 8, 64).items()}
        step += 1


half = args.steps // 2
hist = trainer.train(batches(0), steps=half, log_every=10)
trainer.ckpt.save(trainer.step, (trainer.params, trainer.opt))
trainer.ckpt.wait()

print(f"\n== checkpoint/restart drill at step {trainer.step} ==")
restarted = Trainer(cfg, tcfg)
assert restarted.restore_latest(), "restore failed"
print(f"restored step {restarted.step}; continuing to {args.steps}")
hist = restarted.train(batches(restarted.step), steps=args.steps - half,
                       log_every=10)

first = sum(h["loss"] for h in trainer.history[:5]) / 5
last = sum(h["loss"] for h in restarted.history[-5:]) / 5
print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
      f"(ckpt dir {ckpt_dir})")
assert last < first, "loss did not improve"
