"""Generate EXPERIMENTS.md sections from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md
(The §Perf narrative is maintained in benchmarks/perf_log.py as
structured iteration records.)
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import ARCHS, SHAPES, shapes_for
from . import roofline as RL

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / \
    "dryrun"


def dryrun_section() -> str:
    out = ["## §Dry-run — 512-chip multi-pod compile matrix", ""]
    out.append(
        "Every (architecture x shape) cell is lowered and compiled for "
        "the single-pod mesh (16x16 = 256 chips, axes `data x model`) "
        "AND the multi-pod mesh (2x16x16 = 512 chips, axes "
        "`pod x data x model`). `coll B/dev` is the per-device collective "
        "traffic of one step (HLO parse, scan bodies x L); `args GiB/dev` "
        "proves the sharded state fits.")
    out.append("")
    out.append("| arch | shape | mesh | status | compile (s) | "
               "coll B/dev | args GiB/dev |")
    out.append("|---|---|---|---|---|---|---|")
    n_ok = n_err = 0
    for arch, cfg in ARCHS.items():
        for shape in shapes_for(cfg):
            for mesh in ("pod1", "pod2"):
                p = DRYRUN / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    out.append(f"| {arch} | {shape} | {mesh} | MISSING "
                               "| | | |")
                    n_err += 1
                    continue
                r = json.loads(p.read_text())
                ok = r["status"] == "ok"
                n_ok += ok
                n_err += (not ok)
                if ok:
                    out.append(
                        f"| {arch} | {shape} | {mesh} | ok | "
                        f"{r['compile_s']} | "
                        f"{r['collective']['total']:.2e} | "
                        f"{r['per_device_arg_gib']:.3f} |")
                else:
                    out.append(f"| {arch} | {shape} | {mesh} | "
                               f"ERROR: {r.get('error', '?')[:60]} | | | |")
    out.append("")
    skips = [(a, "long_500k") for a, c in ARCHS.items()
             if not c.sub_quadratic]
    out.append(f"**{n_ok} cells compiled, {n_err} failed/missing.** "
               f"{len(skips)} cells skipped by design (long_500k on pure "
               "full-attention archs — DESIGN.md §Arch-applicability): "
               + ", ".join(a for a, _ in skips) + ".")
    out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    out = ["## §Roofline — per-cell terms (single-pod, TPU v5e model)", ""]
    out.append(
        "`compute = HLO_FLOPs/(chips*197e12)`; `memory = HLO_bytes/"
        "(chips*819e9)`; `collective = transferred_bytes/(chips*50e9)`. "
        "FLOPs/bytes from the unrolled-probe extrapolation (exact; "
        "methodology in EXPERIMENTS §Methodology); collective bytes from "
        "the full compile's HLO. `MODEL/HLO` = useful-FLOPs ratio "
        "(remat/replication waste); `roofline frac` = useful-FLOPs "
        "throughput vs peak if running at the dominant-term bound.")
    out.append("")
    out.append(RL.markdown_table("pod1"))
    out.append("")
    picks = RL.pick_hillclimb_cells("pod1")
    out.append("**Hillclimb cells (§Perf):** "
               f"worst roofline fraction = `{picks['worst'].arch}/"
               f"{picks['worst'].shape}` "
               f"({picks['worst'].roofline_fraction:.3f}); "
               f"most collective-bound = `{picks['collective'].arch}/"
               f"{picks['collective'].shape}`; "
               f"paper-representative (batched decode GEMV) = "
               f"`{picks['representative'].arch}/"
               f"{picks['representative'].shape}`.")
    out.append("")
    return "\n".join(out)


def main() -> None:
    print(dryrun_section())
    print(roofline_section())


if __name__ == "__main__":
    main()
