"""Energy-efficiency benchmark (paper's second evaluation axis).

The paper's abstract promises "precise evaluation of system performance
AND energy efficiency"; this benchmark reports pJ/op for PIM vs the
non-PIM baseline across dtypes and dims, plus the flush-mode comparison
(RD_ACC bus read-out vs MOV_ACC internal ACC->DRAM movement).
"""
from __future__ import annotations

from repro.core.pimsim import PimSimulator
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType


def main() -> dict:
    sim = PimSimulator()
    out = {}
    for dt in ALL_DTYPES:
        p = sim.gemv(4096, 4096, dt)
        b = sim.baseline(4096, 4096, dt)
        ratio = b.energy["pj_per_op"] / p.energy["pj_per_op"]
        out[dt.name] = dict(pim=p.energy["pj_per_op"],
                            base=b.energy["pj_per_op"], ratio=ratio)
        print(f"energy/{dt.name},{p.energy['pj_per_op']:.3f},{ratio:.3f}")
    # flush-mode comparison (W8A8): bus read-out vs internal DRAM move
    for flush in ("bus", "dram"):
        r = sim.gemv(4096, 4096, PimDType.W8A8, flush=flush)
        print(f"energy/flush_{flush},{r.ns/1e3:.2f},"
              f"{r.energy['pj_per_op']:.3f}")
    # energy scales down with dim (fixed overheads amortize)
    for d in (512, 2048, 8192):
        r = sim.gemv(d, d, PimDType.W8A8)
        print(f"energy/dim{d},{r.ns/1e3:.2f},{r.energy['pj_per_op']:.3f}")
    return out


if __name__ == "__main__":
    main()
