"""Force one XLA host device per core before JAX initializes.

The engine's multi-device lane sharding parallelizes fleet resolution
across `jax.devices()`; on a stock CPU backend that is one device, so
the benchmark entry points turn a multi-core host into a (<= ``cap``)
device fleet.  No-op once JAX is imported or when the flag is already
set by the caller's environment.
"""
from __future__ import annotations

import os
import sys


def force_host_devices(cap: int = 4) -> None:
    if "jax" in sys.modules:
        return
    if "--xla_force_host_platform_device_count" in \
            os.environ.get("XLA_FLAGS", ""):
        return
    n = min(cap, os.cpu_count() or 1)
    if n > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
