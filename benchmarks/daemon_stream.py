"""Serve-daemon streaming benchmark: bounded-memory traces at scale.

The daemon battery (tests/test_daemon.py) proves the contracts on the
golden bursty scenario; this benchmark proves them at *daemon* scale —
the ROADMAP's "heavy traffic" claim — on one long synthetic arrival
stream (``--requests``, CI runs 50000):

* ``daemon/stream_run`` — a :class:`~repro.serving.daemon.ServeDaemon`
  run over the full stream with the trace streamed through a
  :class:`~repro.serving.daemon.TraceWriter` (never held in RAM), the
  prefill and decode cells on DIFFERENT backend scopes (Pallas prefill
  when the resolver supports it, ``shard_map`` mesh decode over the
  forced host devices) and SLO-driven autoscaling on.  Asserted:
  request conservation with zero shed/dropped, zero unhandled
  exceptions, and the streamed per-tick trace tick-exact against the
  model-free ``simulate_disagg`` oracle for the same spec — the parity
  the differential suite pins at golden scale, held at 50k.
* ``daemon/stream_rss`` — resident-set growth across the streamed run,
  asserted under a fixed bound (the in-RAM path would grow with the
  run; the writer's buffer is ``chunk_records`` lines, full stop).
* ``daemon/autoscale_efficiency`` — decode work served per slot-tick
  *provisioned*: the autoscaler against the fixed-slot oracle
  (``slots x ticks``), asserted >= 0.95x (in practice well above 1 —
  idle slots are the oracle's waste).
* ``daemon/stream_parity`` — a small sub-stream run twice, streamed and
  in-memory, asserting the reassembled trace byte-identical (canonical
  JSON) to ``ServeDaemon.trace()`` and replayable.

Prints ``daemon/<row>,<v1>,<v2>`` rows plus one machine-parseable
``daemon/ok,...,unhandled=0`` line for CI to grep, and writes
BENCH_daemon_stream.json.
"""
from __future__ import annotations

import sys

try:
    from ._xla_host_devices import force_host_devices
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    from _xla_host_devices import force_host_devices
force_host_devices()

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.engine import BackendScope
from repro.kernels import lane_scan
from repro.models import model as M
from repro.serving.daemon import ServeDaemon, TraceWriter
from repro.serving.offload import OffloadPlanner
from repro.serving.scenarios import (Arrival, AutoscaleConfig,
                                     DisaggConfig, ScenarioSpec,
                                     assign_slo, simulate_disagg)

# Arrival pacing: mean rate must sit under the prefill budget and the
# decode capacity (slots / mean decode hold) or the stream never
# drains; the rate/capacity gap is what the autoscaler's pressure rule
# feeds on during bursts.  Slots stay small on purpose — every distinct
# decode batch size is one XLA compile variant, and the steady-state
# RSS bound below only means something once compilation has converged.
RATE = 4
SLOTS = 8
STEADY_TICK = 400
BOUNDED = DisaggConfig(prefill_budget=6, handoff_bound=10,
                       starvation_age=4)


def stream_spec(n: int, seed: int = 11, slots: int = SLOTS,
                name: str = "stream") -> ScenarioSpec:
    """A synthetic n-request arrival stream: Poisson-ish bursts around
    RATE arrivals/tick, short prompts, 2-3 decode tokens — the shape
    that makes a 50k-request run minutes, not hours, while still
    exercising admission waits, handoff pressure and autoscale moves."""
    rng = np.random.default_rng(seed)
    # Bernoulli tick-advance gaps: mean RATE arrivals/tick with seeded
    # burst structure (runs of same-tick arrivals).
    steps = np.cumsum(rng.random(size=n) < 1.0 / RATE)
    arrivals = tuple(
        Arrival(rid=i, step=int(steps[i]),
                prompt_len=int(rng.integers(4, 9)),
                max_new=int(rng.integers(2, 4)))
        for i in range(n))
    return ScenarioSpec(name=name, seed=seed, slots=slots,
                        arrivals=arrivals)


def rss_mb() -> float:
    with open("/proc/self/status", encoding="utf-8") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return int(line.split()[1]) / 1024.0
    return 0.0


def build_scopes() -> tuple[BackendScope, BackendScope, str]:
    mesh_n = min(4, len(jax.devices()))
    decode = BackendScope(mesh=mesh_n, name="decode")
    if lane_scan.pallas_lane_supported():
        return (BackendScope(backend="pallas", name="prefill"),
                decode, "pallas")
    return BackendScope(name="prefill"), decode, "default"


def main(requests: int = 2000, trace_out: str | None = None) -> dict:
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    planner = OffloadPlanner(ARCHS["mamba2-130m"])
    auto = AutoscaleConfig(min_slots=2)
    results: dict = dict(requests=requests)

    # -- the big streamed run ------------------------------------------
    spec = stream_spec(requests)
    slo = assign_slo(spec, 0.6)
    sim = simulate_disagg(spec, BOUNDED, slo, autoscale=auto)
    tdir = None
    if trace_out is None:
        tdir = tempfile.TemporaryDirectory(prefix="repro-daemon-stream-")
        trace_out = os.path.join(tdir.name, "trace.jsonl")
    prefill_scope, decode_scope, prefill_backend = build_scopes()
    writer = TraceWriter(trace_out, chunk_records=256)

    # Steady-state RSS baseline: sampled once compilation of every
    # decode batch-size variant has converged (STEADY_TICK), so the
    # bound measures trace accumulation, not the XLA compile cache.
    steady = dict(rss=None, tick=0)

    def sample_rss(t, eng):
        if steady["rss"] is None and t >= min(STEADY_TICK,
                                              len(sim["per_tick_batch"])
                                              // 4):
            steady["rss"] = rss_mb()
            steady["tick"] = t

    daemon = ServeDaemon(cfg, params, planner, scenario=spec,
                         policy="per-step", disagg=BOUNDED, slo=slo,
                         autoscale=auto, prefill_scope=prefill_scope,
                         decode_scope=decode_scope, writer=writer,
                         on_tick=sample_rss)
    t0 = time.perf_counter()
    rep = daemon.run()
    wall = time.perf_counter() - t0
    rss_growth = rss_mb() - (steady["rss"] if steady["rss"] is not None
                             else rss_mb())
    acct = rep["accounting"]
    assert acct["completed"] == requests and acct["shed"] == 0 \
        and acct["dropped"] == 0, f"stream run lost requests: {acct}"

    streamed = TraceWriter.load(trace_out)
    assert streamed["per_tick_batch"] == sim["per_tick_batch"], \
        "streamed daemon trace diverged from the model-free oracle"
    assert streamed["autoscale"]["limits"] == sim["limits"], \
        "autoscale limit trace diverged from the model-free oracle"
    ticks = len(streamed["per_tick_batch"])
    trace_mb = os.path.getsize(trace_out) / 1e6
    print(f"daemon/stream_run,{wall*1e6/ticks:.1f},{ticks/wall:.0f}")
    print(f"daemon/stream_rss,{rss_growth:.1f},{trace_mb:.2f}")
    # The writer's buffer is chunk-bounded by construction; the process
    # bound catches any trace state accidentally accumulated in RAM.
    rss_bound = 256.0
    assert rss_growth < rss_bound, \
        f"streamed run grew RSS {rss_growth:.1f} MB (bound {rss_bound})"
    results.update(ticks=ticks, wall_s=wall, tick_us=wall * 1e6 / ticks,
                   rss_growth_mb=rss_growth, trace_mb=trace_mb,
                   prefill_backend=prefill_backend,
                   flushes=writer.flushes)

    # -- autoscale vs the fixed-slot oracle ----------------------------
    fixed = simulate_disagg(spec, BOUNDED, slo)
    auto_eff = (sum(streamed["per_tick_batch"])
                / sum(streamed["autoscale"]["limits"]))
    fixed_eff = (sum(fixed["per_tick_batch"])
                 / (spec.slots * len(fixed["per_tick_batch"])))
    eff_ratio = auto_eff / fixed_eff
    assert eff_ratio >= 0.95, \
        f"autoscale efficiency {eff_ratio:.3f}x below the oracle"
    grows = streamed["autoscale"]["grows"]
    shrinks = streamed["autoscale"]["shrinks"]
    print(f"daemon/autoscale_efficiency,{eff_ratio:.2f},{grows+shrinks}")
    results.update(autoscale_efficiency=eff_ratio, grows=grows,
                   shrinks=shrinks)

    # -- streamed == in-memory byte parity (sub-stream, run twice) -----
    sub = stream_spec(min(400, requests), name="stream-sub")
    sub_slo = assign_slo(sub, 0.6)
    mem = ServeDaemon(cfg, params, planner, scenario=sub,
                      policy="per-step", disagg=BOUNDED, slo=sub_slo,
                      autoscale=AutoscaleConfig(min_slots=2))
    mem.run()
    with tempfile.TemporaryDirectory(prefix="repro-daemon-sub-") as sd:
        sub_path = os.path.join(sd, "trace.jsonl")
        sw = TraceWriter(sub_path, chunk_records=64)
        ServeDaemon(cfg, params, planner, scenario=sub,
                    policy="per-step", disagg=BOUNDED, slo=sub_slo,
                    autoscale=AutoscaleConfig(min_slots=2),
                    writer=sw).run()
        loaded = TraceWriter.load(sub_path)
    mem_trace = mem.trace()
    assert (json.dumps(loaded, sort_keys=True)
            == json.dumps(mem_trace, sort_keys=True)), \
        "streamed trace is not byte-identical to the in-memory path"
    # The loaded trace replays from its embedded records alone (the
    # bounded cell-pair schedule, so the disagg+autoscale mirror — not
    # the monolithic replay_batches path, which covers mirror configs).
    replayed = simulate_disagg(
        ScenarioSpec.from_record(loaded["scenario"]),
        DisaggConfig.from_record(loaded["disagg"]["config"]),
        {int(r): s for r, s in loaded["disagg"]["slo"].items()},
        autoscale=AutoscaleConfig.from_record(
            loaded["autoscale"]["config"]))
    assert replayed["per_tick_batch"] == loaded["per_tick_batch"]
    print(f"daemon/stream_parity,{sw.flushes},{len(loaded['per_tick_batch'])}")
    results["parity_flushes"] = sw.flushes

    print(f"daemon/ok,requests={requests},ticks={ticks},"
          f"completed={acct['completed']},shed=0,dropped=0,"
          f"rss_mb={rss_growth:.1f},prefill={prefill_backend},"
          f"unhandled=0")
    if tdir is not None:
        tdir.cleanup()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--trace-out", type=str, default=None)
    ap.add_argument("--out", type=str, default="BENCH_daemon_stream.json")
    args = ap.parse_args()
    res = main(requests=args.requests, trace_out=args.trace_out)
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)
