"""§Perf hillclimb utilities + the structured iteration log.

Each iteration: hypothesis -> change (a dry-run --variant) -> measured
roofline terms before/after -> confirmed/refuted + lesson.  The table in
EXPERIMENTS.md §Perf renders PERF_LOG; `compare()` recomputes the terms
from the stored dry-run artifacts so the numbers are reproducible.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import ARCHS, SHAPES
from repro.distribution import roofline as RLmod
from repro.distribution import sharding as SHmod
from repro.distribution.roofline import RooflineTerms, min_traffic_bytes

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / \
    "dryrun"


def load_cell(arch: str, shape: str, mesh: str = "pod1",
              variant: str = "baseline") -> dict:
    suffix = "" if variant == "baseline" else f"__{variant}"
    p = DRYRUN / f"{arch}__{shape}__{mesh}{suffix}.json"
    return json.loads(p.read_text())


def terms(rec: dict) -> RooflineTerms:
    from repro.models import model as Mmod
    variant = rec.get("variant", "baseline")
    SHmod.SERVE_TP_ONLY = variant.startswith("serve-tp")
    RLmod.FLASH_SKIP_BLOCKS = "flash-skip" in variant
    Mmod.QUANT_BITS = 8 if "w8" in variant else \
        4 if "w4" in variant else 0
    Mmod.KV_QUANT = "kv8" in variant
    try:
        cfg = ARCHS[rec["arch"]]
        shape = SHAPES[rec["shape"]]
        ex = rec.get("extrap", {})
        chips = rec["chips"]
        return RooflineTerms(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            chips=chips,
            hlo_flops=ex.get("flops_dev", rec["flops"]) * chips,
            hlo_bytes=ex.get("bytes_dev", rec["bytes_accessed"]) * chips,
            coll_bytes=rec["collective"]["total"] * chips,
            model_flops=rec["model_flops"],
            traffic_dev=min_traffic_bytes(cfg, shape),
        )
    finally:
        SHmod.SERVE_TP_ONLY = False
        RLmod.FLASH_SKIP_BLOCKS = False
        Mmod.QUANT_BITS = 0
        Mmod.KV_QUANT = False


def compare(arch: str, shape: str, variants: list[str],
            mesh: str = "pod1") -> None:
    print(f"== {arch} / {shape} / {mesh} ==")
    print(f"{'variant':18s} {'compute':>10s} {'memory':>10s} "
          f"{'collective':>11s} {'dominant':>10s} {'frac':>7s}")
    for v in ["baseline"] + variants:
        try:
            t = terms(load_cell(arch, shape, mesh, v))
        except FileNotFoundError:
            print(f"{v:18s} (not measured)")
            continue
        print(f"{v:18s} {t.t_compute*1e3:9.2f}ms {t.t_memory*1e3:9.2f}ms "
              f"{t.t_collective*1e3:10.2f}ms {t.bottleneck:>10s} "
              f"{t.roofline_fraction:7.3f}")


# ----------------------------------------------------------------------
# The iteration log (EXPERIMENTS.md §Perf renders this).
# ----------------------------------------------------------------------
PERF_LOG: list[dict] = []


def log(cell, it, hypothesis, change, before, after, verdict, lesson):
    PERF_LOG.append(dict(cell=cell, iteration=it, hypothesis=hypothesis,
                         change=change, before=before, after=after,
                         verdict=verdict, lesson=lesson))


if __name__ == "__main__":
    compare("qwen2-72b", "decode_32k", ["serve-tp"])
