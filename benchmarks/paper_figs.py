"""Paper-reproduction benchmarks: Fig. 4a, Fig. 4b, §3.3 Reshape.

Each function prints ``name,us_per_call,derived`` CSV rows and returns a
dict used by EXPERIMENTS.md generation.  "derived" is the paper-comparable
number (speedup ratio / gain).
"""
from __future__ import annotations

import numpy as np

from repro.core.pimsim import PimSimulator
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType

DIMS = [512, 1024, 2048, 4096, 8192]
BASE = 4096

# Paper targets at the 4096 baseline dimension (Fig. 4 text, §3.1/3.2).
PAPER_TARGETS = {
    ("W8A8", False): 6.1, ("W4A4", False): 6.1, ("FP_W8A8", False): 6.1,
    ("W8A16", False): 5.75, ("W4A16", False): 5.75, ("FP_W8A16", False): 5.75,
    ("W4A8", False): 5.9,
    ("W4A16", True): 4.1,
}


def fig4a(sim: PimSimulator | None = None) -> dict:
    """GEMV speedup across dims/dtypes, no memory fence (Fig. 4a)."""
    sim = sim or PimSimulator()
    out = {}
    for axis in ("activation", "output"):
        sweep = sim.sweep(DIMS, ALL_DTYPES, axis=axis, base_dim=BASE)
        out[axis] = sweep
        for name, row in sweep.items():
            for d, s in zip(DIMS, row):
                pim_us = sim.gemv(*( (BASE, d) if axis == "activation"
                                     else (d, BASE)), name).ns / 1e3
                print(f"fig4a/{axis}/{name}/dim{d},{pim_us:.2f},{s:.3f}")
    return out


def fig4b(sim: PimSimulator | None = None) -> dict:
    """GEMV speedup with a 150 ns memory fence between tiles (Fig. 4b)."""
    sim = sim or PimSimulator()
    out = {}
    for axis in ("activation", "output"):
        sweep = sim.sweep(DIMS, ALL_DTYPES, axis=axis, base_dim=BASE,
                          fence=True)
        out[axis] = sweep
        for name, row in sweep.items():
            for d, s in zip(DIMS, row):
                pim_us = sim.gemv(*( (BASE, d) if axis == "activation"
                                     else (d, BASE)), name,
                                  fence=True).ns / 1e3
                print(f"fig4b/{axis}/{name}/dim{d},{pim_us:.2f},{s:.3f}")
    return out


def reshape(sim: PimSimulator | None = None) -> dict:
    """§3.3: reshape-optimization gain on small output dims."""
    sim = sim or PimSimulator()
    out = {}
    for H in (256, 512, 1024, 2048):
        t0 = sim.gemv(H, BASE, PimDType.W8A8, reshape=False)
        t1 = sim.gemv(H, BASE, PimDType.W8A8, reshape=True)
        gain = t0.ns / t1.ns
        out[H] = dict(gain=gain, util0=t0.utilization,
                      util1=t1.utilization, split=t1.split)
        print(f"reshape/H{H},{t1.ns/1e3:.2f},{gain:.3f}")
    return out


def check_paper_targets(sim: PimSimulator | None = None) -> dict:
    """Deviation table vs the paper's published 4096-dim numbers."""
    sim = sim or PimSimulator()
    rows = {}
    worst = 0.0
    for (name, fence), target in PAPER_TARGETS.items():
        got = sim.speedup(BASE, BASE, name, fence=fence)
        dev = (got - target) / target
        worst = max(worst, abs(dev))
        rows[(name, fence)] = (got, target, dev)
        print(f"target/{name}{'/fence' if fence else ''},"
              f"{sim.gemv(BASE, BASE, name, fence=fence).ns/1e3:.2f},"
              f"{got:.3f} (paper {target}, dev {dev:+.1%})")
    rows["worst_abs_dev"] = worst
    return rows


def main() -> dict:
    sim = PimSimulator()
    return dict(fig4a=fig4a(sim), fig4b=fig4b(sim), reshape=reshape(sim),
                targets=check_paper_targets(sim))


if __name__ == "__main__":
    main()
