"""Simulator-throughput microbenchmark: JAX scan engine vs Python oracle.

The JAX engine is what makes full-figure sweeps tractable (DESIGN.md §2.1);
this benchmark quantifies the speedup in resolved commands/second.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import commands as C
from repro.core.engine import resolve_fleet, run_streams
from repro.core.engine_ref import RefEngine
from repro.core.timing import DEFAULT_SYSTEM
from repro.pimkernel.executor import PimExecutor
from repro.pimkernel.tileconfig import PimDType


def main() -> dict:
    cyc = DEFAULT_SYSTEM.derive_cycles()
    ex = PimExecutor(DEFAULT_SYSTEM)
    layout, program = ex.plan(4096, 4096, PimDType.W8A8)
    gs = ex.build_streams(layout, program)
    stream = gs.streams[0]
    n = stream.shape[0]

    # Python oracle on a prefix (full stream would take minutes).
    prefix = stream[: min(n, 20000)]
    t0 = time.perf_counter()
    RefEngine(cyc, validate=False).run(prefix)
    ref_s = time.perf_counter() - t0
    ref_rate = prefix.shape[0] / ref_s

    # JAX engine: jit warmup, then timed.
    run_streams(cyc, [stream])
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        run_streams(cyc, [stream])
    jax_s = (time.perf_counter() - t0) / reps
    jax_rate = n / jax_s

    # Wide-fleet throughput: 64 distinct lanes (one spec variant per
    # lane, so resolve_fleet's lane dedup cannot collapse them) — the
    # regime design-space sweeps run in.
    lanes = 64
    variants = [dataclasses.replace(cyc, cRCD=cyc.cRCD + i)
                for i in range(lanes)]
    points = [(v, [stream]) for v in variants]
    resolve_fleet(points)
    t0 = time.perf_counter()
    resolve_fleet(points)
    fleet_s = time.perf_counter() - t0
    fleet_rate = lanes * n / fleet_s

    print(f"engine/ref,{ref_s*1e6/prefix.shape[0]*1e0:.3f},{ref_rate:.0f}")
    print(f"engine/jax,{jax_s*1e6/n:.3f},{jax_rate:.0f}")
    print(f"engine/fleet64,{fleet_s*1e6/(lanes*n):.3f},{fleet_rate:.0f}")
    print(f"engine/speedup,{jax_s*1e6:.1f},{jax_rate/ref_rate:.1f}")
    return dict(ref_cmds_per_s=ref_rate, jax_cmds_per_s=jax_rate,
                fleet_cmds_per_s=fleet_rate,
                speedup=jax_rate / ref_rate, stream_len=n)


if __name__ == "__main__":
    main()
