"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by launch/dryrun.py), derives
the three roofline terms per (arch x shape x mesh), identifies the
dominant bottleneck, and emits the EXPERIMENTS.md §Roofline table.

FLOPs/bytes come from the unrolled-probe extrapolation (exact per-device
totals; see dryrun.cost_extrapolate), collective bytes from the HLO parse
of the full scanned compile with while-body trip multiplication.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import ARCHS, SHAPES
from repro.distribution.roofline import RooflineTerms, model_flops

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" \
    / "dryrun"


def load_cells(mesh: str = "pod1") -> list[dict]:
    cells = []
    for path in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") == "ok":
            cells.append(rec)
    return cells


def terms_for(rec: dict) -> RooflineTerms:
    chips = rec["chips"]
    ex = rec.get("extrap", {})
    flops_dev = ex.get("flops_dev", rec["flops"])
    bytes_dev = ex.get("bytes_dev", rec["bytes_accessed"])
    from repro.distribution.roofline import min_traffic_bytes
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    return RooflineTerms(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=chips,
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        coll_bytes=rec["collective"]["total"] * chips,
        model_flops=rec["model_flops"],
        traffic_dev=min_traffic_bytes(cfg, shape),
    )


def table(mesh: str = "pod1", print_csv: bool = True) -> list:
    rows = []
    for rec in load_cells(mesh):
        t = terms_for(rec)
        rows.append(t)
        if print_csv:
            dom = t.bottleneck
            print(f"roofline/{t.arch}/{t.shape}/{mesh},"
                  f"{max(t.t_compute, t.t_memory, t.t_collective)*1e6:.1f},"
                  f"{t.roofline_fraction:.4f}")
    return rows


def markdown_table(mesh: str = "pod1") -> str:
    rows = table(mesh, print_csv=False)
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms)"
           " | bottleneck | MODEL/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for t in sorted(rows, key=lambda r: (r.arch, r.shape)):
        out.append(
            f"| {t.arch} | {t.shape} | {t.t_compute*1e3:.3f} | "
            f"{t.t_memory*1e3:.3f} | {t.t_collective*1e3:.3f} | "
            f"**{t.bottleneck}** | {t.useful_ratio:.2f} | "
            f"{t.roofline_fraction:.3f} |")
    return "\n".join(out)


def pick_hillclimb_cells(mesh: str = "pod1") -> dict:
    """The three §Perf cells: worst roofline fraction, most
    collective-bound, most representative of the paper's technique
    (distinct cells, tiny replication-bound archs excluded)."""
    rows = [t for t in table(mesh, print_csv=False)]
    picked = set()

    def take(t):
        picked.add((t.arch, t.shape))
        return t

    # "worst": big archs, throughput shapes (single-request long-context
    # decode is inherently replication-bound on 256 chips — a finding,
    # not a tuning target)
    big = [t for t in rows if ARCHS[t.arch].param_count() > 3e9
           and t.shape in ("train_4k", "prefill_32k")]
    worst = take(min(big, key=lambda t: t.roofline_fraction))
    coll = take(max((t for t in rows if t.arch != worst.arch),
                    key=lambda t: t.t_collective /
                    max(t.t_compute, t.t_memory, 1e-30)))
    # paper's technique = batched decode GEMV -> a decode cell of a
    # weight-heavy dense arch
    decode = [t for t in rows if t.shape == "decode_32k"
              and ARCHS[t.arch].family == "dense"
              and (t.arch, t.shape) not in picked]
    rep = take(max(decode, key=lambda t: ARCHS[t.arch].param_count()))
    return dict(worst=worst, collective=coll, representative=rep)


def main() -> None:
    for mesh in ("pod1",):
        print(f"== roofline ({mesh}) ==")
        table(mesh)
    picks = pick_hillclimb_cells()
    for k, t in picks.items():
        print(f"pick/{k},{t.arch}/{t.shape},"
              f"{t.roofline_fraction:.4f}")


if __name__ == "__main__":
    main()
