"""Benchmark driver — one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows:
  fig4a/*      — GEMV speedup, no fence            (paper Fig. 4a)
  fig4b/*      — GEMV speedup, 150 ns fences       (paper Fig. 4b)
  reshape/*    — reshape-optimization gain          (paper §3.3)
  target/*     — deviation vs published 4096 numbers
  engine/*     — cycle-engine throughput (JAX vs oracle)
  fleet/*      — planning/resolution split, batched vs looped sweeps,
                 serve-replan lane-cache rows (fleet API)
  offload/*    — LLM decode offload case study (framework layer)
  roofline/*   — dominant term + roofline fraction per dry-run cell
"""
from __future__ import annotations

# One XLA host device per core (up to 4), set before JAX initializes, so
# the fleet rows exercise the engine's multi-device lane sharding.
from ._xla_host_devices import force_host_devices

force_host_devices()


def main() -> None:
    from . import energy_fig, engine_speed, fleet_speed, paper_figs, \
        roofline

    paper_figs.main()
    engine_speed.main()
    fleet_speed.main()
    energy_fig.main()

    # LLM decode offload case study (the paper's motivating workload)
    from repro.configs import ARCHS
    from repro.core.pimsim import PimSimulator
    from repro.serving.offload import OffloadPlanner
    sim = PimSimulator()
    for arch in ("granite-8b", "qwen2-72b", "granite-moe-3b-a800m",
                 "mamba2-130m"):
        tel = OffloadPlanner(ARCHS[arch], sim).decode_speedup(batch=1)
        print(f"offload/{arch}/b1,{tel['mixed_ns']/1e3:.1f},"
              f"{tel['speedup']:.3f}")

    try:
        roofline.main()
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"roofline/unavailable,0,{type(e).__name__}")


if __name__ == "__main__":
    main()
