"""Cold-start smoke: serve twice, assert warm start actually warms.

Runs ``repro.launch.serve --scenario bursty --quick`` in two fresh
subprocesses sharing one ``--cache-dir``.  The first process compiles
everything and resolves every lane cold, then snapshots; the second must

* report a strictly better ``serve/time_to_first_batch`` (persistent XLA
  compile cache + lane snapshot replace the dominant cold costs), and
* do ZERO lane re-resolves for cached keys — ``serve/lane_cache``
  misses == 0, every telemetry lane replayed from the snapshot.

Exit status is the assertion: CI runs this as the cold-start gate.
Usage: ``python benchmarks/coldstart_smoke.py [--scenario bursty]``.
"""
from __future__ import annotations

import argparse
import re
import subprocess
import sys
import tempfile


def _parse(out: str) -> dict:
    m_ttfb = re.search(r"^serve/time_to_first_batch,([\d.]+)$", out, re.M)
    m_cache = re.search(
        r"^serve/lane_cache,hits=(\d+),misses=(\d+),size=(\d+)$", out, re.M)
    if not (m_ttfb and m_cache):
        raise SystemExit(f"serve output missing parseable rows:\n{out}")
    return dict(ttfb=float(m_ttfb.group(1)),
                hits=int(m_cache.group(1)),
                misses=int(m_cache.group(2)),
                size=int(m_cache.group(3)))


def run_once(cache_dir: str, scenario: str) -> dict:
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--scenario", scenario, "--quick", "--cache-dir", cache_dir]
    proc = subprocess.run(cmd, capture_output=True, text=True)
    if proc.returncode != 0:
        raise SystemExit(f"serve failed:\n{proc.stdout}\n{proc.stderr}")
    return _parse(proc.stdout)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="bursty")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory(prefix="repro-coldstart-") as d:
        cold = run_once(d, args.scenario)
        warm = run_once(d, args.scenario)

    print(f"coldstart/ttfb_cold,{cold['ttfb']:.3f},misses={cold['misses']}")
    print(f"coldstart/ttfb_warm,{warm['ttfb']:.3f},misses={warm['misses']}")
    print(f"coldstart/ttfb_speedup,{warm['ttfb']:.3f},"
          f"{cold['ttfb'] / warm['ttfb']:.2f}")

    assert warm["misses"] == 0, \
        (f"warm serve re-resolved {warm['misses']} lanes that the "
         f"snapshot should have replayed (cold run had "
         f"{cold['misses']} misses)")
    assert warm["ttfb"] < cold["ttfb"], \
        (f"warm time-to-first-batch {warm['ttfb']:.3f}s did not improve "
         f"on cold {cold['ttfb']:.3f}s")
    print("coldstart smoke OK")


if __name__ == "__main__":
    main()
