"""Fleet-throughput benchmark: batched vs looped sweep resolution.

Comparisons over the full Fig. 4 grid (both axes, all dtypes, fence
on/off, PIM + baseline points):

* ``fleet/plan_*`` — the Python planning side alone: per-command
  ``StreamBuilder`` reference synthesis vs the vectorized block
  synthesizer (byte-identical streams, asserted).
* ``fleet/resolve_*`` — the execution core alone: per-point
  ``engine.run_streams`` loop vs one ``engine.resolve_fleet`` call on the
  same prebuilt streams (isolates the dispatch/batching win).
* ``fleet/sweep_*`` — end to end: a per-call ``run_gemv``/``run_baseline``
  loop vs one ``PimExecutor.run_many`` (includes stream building, which
  both paths share).
* ``fleet/specs_*`` — the spec-lifted facade: a (4 SystemSpec variants x
  shapes) design grid as per-variant executors + per-point calls vs ONE
  heterogeneous ``run_many`` fleet.
* ``fleet/specfam_*`` — the heterogeneous spec-family fleet
  (``configs/specfam.py``: phone-class LP5X / server parts /
  CXL-expander-like populations): per-family executors vs ONE batched
  ``run_many`` (cycle counts asserted bit-identical), then each
  family's offload frontier and speculative-decode economics as
  per-population rows.
* ``fleet/mesh_*`` — lane execution backends on the same prebuilt
  streams: the threaded per-device dispatch vs ONE ``shard_map``
  program per slab over a 1-D ``lanes`` mesh, at mesh sizes {1, 2, 4}
  (bounded by visible devices; bit-exactness asserted).
* ``fleet/pallas_*`` — the Pallas lane-resolver backend vs the scan
  resolver on the same prebuilt streams (bounded subset; interpret mode
  on CPU, so a parity/portability row — native on TPU).
* ``fleet/serve_replan_*`` — repeated serving-loop telemetry queries
  (fresh planner per query, the replan pattern) with the resolved-lane
  LRU disabled vs enabled.
* ``fleet/coldstart_*`` — a fresh subprocess workload run cold vs warm
  against one persistent ``--cache-dir`` (XLA compile cache + lane
  snapshot); the warm child must replay with zero lane resolves.
* ``fleet/policy_*`` — adaptive offload control closed-loop over a
  bursty serving trace: per-step recompute vs hysteresis vs sticky on
  control cost (us/step, planner queries) with the realized/oracle
  efficiency asserted >= 0.95.
* ``fleet/disagg_*`` — disaggregated prefill/decode serving: the
  model-free cell-pair simulator vs the monolithic queue model
  (us/tick, mirror parity asserted), policy efficiency over the
  bounded SLO-mixed pair's decode occupancy (>= 0.95 asserted), the
  peak KV-handoff depth vs its bound, and the warm-handoff lane
  account (zero re-resolves asserted).
* ``fleet/chaos_*`` — the degradation ladder on the resolve path: the
  same prebuilt points resolved healthy, under a transient top-rung
  fault (absorbed by bounded retry, backoff on a virtual clock — no
  real sleeps), and under a persistent top-rung fault (ladder
  step-down).  All three are asserted byte-identical to the looped
  oracle: degradation moves latency, never bytes.

The resolved-lane cache is cleared before every timed resolution section
so the ``resolve``/``sweep``/``specs`` rows measure real engine work on
both sides; ``serve_replan`` is the row that measures the cache itself.
Batched cycle counts are asserted bit-identical to the looped ones, so
the speedup rows in BENCH_*.json always track a correct result.

When run before JAX initializes, the process forces one XLA host device
per core (up to 4) so the engine's multi-device lane sharding is
exercised — the rows then measure the sharded fleet path with its
single-device fallback still covered by CI's default job.
"""
from __future__ import annotations

import sys

try:
    from ._xla_host_devices import force_host_devices
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    from _xla_host_devices import force_host_devices
force_host_devices()

import json
import subprocess
import tempfile
import time

import numpy as np

from repro.core import engine, faults, warmstart
from repro.core.pimsim import PimSimulator

# Honour REPRO_CACHE_DIR: benchmark runs share the launchers' persistent
# warm-start plumbing (no-op when the env knob is unset).
warmstart.enable_warm_start()
from repro.core.timing import DEFAULT_SYSTEM, LpddrTimings, SystemSpec
from repro.pimkernel.executor import GemvRequest, PimExecutor, spec_context
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType

DIMS = [512, 1024, 2048, 4096, 8192]
QUICK_DIMS = [512, 1024, 2048]
BASE = 4096

# Cold-start probe child: a fresh process resolving a small PIM grid
# under --cache-dir semantics (warm-start load at entry, snapshot save at
# exit), reporting elapsed wall, lane-cache misses and the cycle totals
# as one JSON line.  Run twice against the same directory, the second
# process must reproduce the totals byte-identically with ZERO fleet
# resolves — the process-level analogue of the serve_replan rows.
_COLDSTART_CHILD = r"""
import json, sys, time
t0 = time.perf_counter()
from repro.core import engine, warmstart
from repro.core.timing import DEFAULT_SYSTEM
from repro.pimkernel.executor import GemvRequest, PimExecutor
from repro.pimkernel.tileconfig import PimDType
warmstart.enable_warm_start(sys.argv[1])
reqs = [GemvRequest.pim(1024, d, PimDType.W8A8) for d in (256, 512)] + \
    [GemvRequest.baseline(1024, 256, PimDType.W8A8)]
res = PimExecutor(DEFAULT_SYSTEM).run_many(reqs)
info = engine.lane_cache_info()
warmstart.save_warm_start(sys.argv[1])
print(json.dumps(dict(elapsed=time.perf_counter() - t0,
                      totals=[int(r.cycles) for r in res],
                      misses=info["misses"])))
"""


def fig4_grid(dims=None) -> list[GemvRequest]:
    """Every (axis, dtype, dim, fence) point of Fig. 4 + its baseline."""
    reqs: list[GemvRequest] = []
    seen: set = set()
    for fence in (False, True):
        for axis in ("activation", "output"):
            for dt in ALL_DTYPES:
                for d in dims or DIMS:
                    H, W = (BASE, d) if axis == "activation" else (d, BASE)
                    for r in (GemvRequest.pim(H, W, dt, fence=fence),
                              GemvRequest.baseline(H, W, dt)):
                        if r.key not in seen:
                            seen.add(r.key)
                            reqs.append(r)
    return reqs


def main(quick: bool = False) -> dict:
    dims = QUICK_DIMS if quick else DIMS
    ex = PimExecutor(DEFAULT_SYSTEM)
    reqs = fig4_grid(dims)
    n = len(reqs)

    # ---- planning: vectorized block synthesis vs StreamBuilder oracle --
    pim_reqs = [r for r in reqs if r.kind == "pim"]
    plans = [ex.plan(r.H, r.W, r.dtype, reshape=r.reshape) for r in pim_reqs]

    t0 = time.perf_counter()
    ref_streams = [
        spec_context(layout.spec).kernel.build_reference(
            layout, program, fence=r.fence, flush=r.flush)
        for r, (layout, program) in zip(pim_reqs, plans)]
    plan_ref_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    vec_streams = [
        spec_context(layout.spec).kernel.build(
            layout, program, fence=r.fence, flush=r.flush)
        for r, (layout, program) in zip(pim_reqs, plans)]
    plan_vec_s = time.perf_counter() - t0

    for gr, gv in zip(ref_streams, vec_streams):
        for a, b in zip(gr.streams, gv.streams):
            np.testing.assert_array_equal(a, b)

    m_pim = len(pim_reqs)
    print(f"fleet/plan_reference,{plan_ref_s*1e6/m_pim:.1f},"
          f"{m_pim/plan_ref_s:.1f}")
    print(f"fleet/plan_vectorized,{plan_vec_s*1e6/m_pim:.1f},"
          f"{m_pim/plan_vec_s:.1f}")
    print(f"fleet/plan_speedup,{plan_vec_s*1e3:.1f},"
          f"{plan_ref_s/plan_vec_s:.1f}")

    # Build all streams once; both resolve paths time the same arrays.
    planned = ex.plan_many(reqs)
    cyc = planned[0].ctx.cyc
    points = [(p.ctx.cyc, p.streams) for p in planned]

    # Warm the compile caches of both paths (compilation is a one-time
    # cost shared across every spec variant; we measure steady state).
    engine.run_streams(cyc, planned[0].streams)
    engine.resolve_fleet(points)

    engine.lane_cache_clear()
    t0 = time.perf_counter()
    looped = [engine.run_streams(p.ctx.cyc, p.streams)[1] for p in planned]
    resolve_loop_s = time.perf_counter() - t0

    engine.lane_cache_clear()
    t0 = time.perf_counter()
    fleet = engine.resolve_fleet(points)
    resolve_batch_s = time.perf_counter() - t0

    for solo, fr in zip(looped, fleet):
        np.testing.assert_array_equal(solo, fr.totals)

    print(f"fleet/resolve_looped,{resolve_loop_s*1e6/n:.1f},"
          f"{n/resolve_loop_s:.1f}")
    print(f"fleet/resolve_batched,{resolve_batch_s*1e6/n:.1f},"
          f"{n/resolve_batch_s:.1f}")
    print(f"fleet/resolve_speedup,{resolve_batch_s*1e3:.1f},"
          f"{resolve_loop_s/resolve_batch_s:.1f}")

    # Mesh lane execution: the same prebuilt streams resolved by the
    # threaded per-device dispatch (the resolve_batched row above) vs
    # ONE shard_map program per bucketed slab, at every mesh size the
    # visible devices allow.  Cycle counts are asserted bit-identical,
    # so the mesh rows always track a correct backend.
    mesh_sizes = [m for m in (1, 2, 4)
                  if m <= len(engine.lane_devices())]
    mesh_row_s: dict[int, float] = {}
    for m in mesh_sizes:
        with engine.lane_mesh_scope(m):
            engine.lane_cache_clear()           # else warm-up is LRU hits
            engine.resolve_fleet(points)        # warm the mesh compiles
            engine.lane_cache_clear()
            t0 = time.perf_counter()
            meshed = engine.resolve_fleet(points)
            mesh_row_s[m] = time.perf_counter() - t0
        for solo, fr in zip(looped, meshed):
            np.testing.assert_array_equal(solo, fr.totals)
        print(f"fleet/mesh_shardmap_{m},{mesh_row_s[m]*1e6/n:.1f},"
              f"{n/mesh_row_s[m]:.1f}")
    mesh_best_s = min(mesh_row_s.values())
    print(f"fleet/mesh_threaded,{resolve_batch_s*1e6/n:.1f},"
          f"{n/resolve_batch_s:.1f}")
    print(f"fleet/mesh_speedup,{mesh_best_s*1e3:.1f},"
          f"{resolve_batch_s/mesh_best_s:.1f}")

    # Pallas lane resolver vs the scan resolver on the same prebuilt
    # streams (a bounded subset — on this CPU container the kernel runs
    # under the Pallas *interpreter*, so the row is an honest parity/
    # portability report, not a speed claim; the crossover is native TPU
    # compilation, where the same kernel keeps lane state in VMEM).
    # Bit-exactness asserted like every other row.
    from repro.kernels import lane_scan
    pallas_speedup = None
    if lane_scan.pallas_lane_supported():
        sub = points[: min(8, n)]
        ns = len(sub)
        engine.lane_cache_clear()
        engine.resolve_fleet(sub)               # scan path warm
        engine.lane_cache_clear()
        t0 = time.perf_counter()
        scan_res = engine.resolve_fleet(sub)
        pallas_scan_s = time.perf_counter() - t0
        with engine.lane_backend_scope("pallas"):
            engine.lane_cache_clear()
            engine.resolve_fleet(sub)           # warm the kernel compiles
            engine.lane_cache_clear()
            t0 = time.perf_counter()
            pallas_res = engine.resolve_fleet(sub)
            pallas_kernel_s = time.perf_counter() - t0
        for a, b in zip(scan_res, pallas_res):
            np.testing.assert_array_equal(a.totals, b.totals)
        pallas_speedup = pallas_scan_s / pallas_kernel_s
        print(f"fleet/pallas_scan,{pallas_scan_s*1e6/ns:.1f},"
              f"{ns/pallas_scan_s:.1f}")
        print(f"fleet/pallas_kernel,{pallas_kernel_s*1e6/ns:.1f},"
              f"{ns/pallas_kernel_s:.1f}")
        print(f"fleet/pallas_speedup,{pallas_kernel_s*1e3:.1f},"
              f"{pallas_speedup:.2f}")
    else:
        print("fleet/pallas_kernel,unsupported,0.0")

    # End to end: fresh executors so neither path reuses built streams.
    # Warm the keyed fleet path too (its dedupe can produce slab shapes
    # the unkeyed warm-up above never compiled).
    PimExecutor(DEFAULT_SYSTEM).run_many(reqs)
    ex_loop = PimExecutor(DEFAULT_SYSTEM)
    engine.lane_cache_clear()
    t0 = time.perf_counter()
    solo_res = [
        ex_loop.run_gemv(r.H, r.W, r.dtype, fence=r.fence,
                         reshape=r.reshape, flush=r.flush)
        if r.kind == "pim" else
        ex_loop.run_baseline(r.H, r.W, r.dtype)
        for r in reqs]
    sweep_loop_s = time.perf_counter() - t0

    ex_batch = PimExecutor(DEFAULT_SYSTEM)
    engine.lane_cache_clear()
    t0 = time.perf_counter()
    batch_res = ex_batch.run_many(reqs)
    sweep_batch_s = time.perf_counter() - t0

    for a, b in zip(solo_res, batch_res):
        assert a.cycles == b.cycles, (a.meta, a.cycles, b.cycles)

    print(f"fleet/sweep_looped,{sweep_loop_s*1e6/n:.1f},"
          f"{n/sweep_loop_s:.1f}")
    print(f"fleet/sweep_batched,{sweep_batch_s*1e6/n:.1f},"
          f"{n/sweep_batch_s:.1f}")
    print(f"fleet/sweep_speedup,{sweep_batch_s*1e3:.1f},"
          f"{sweep_loop_s/sweep_batch_s:.1f}")

    # Spec-lifted facade: a heterogeneous (spec x shape x kind) design
    # grid through one run_many vs per-variant executors.
    specs = [DEFAULT_SYSTEM] + [
        SystemSpec(timings=LpddrTimings(tRCD=20.0 + 2 * i,
                                        tRP=20.0 + 2 * i))
        for i in range(3)]
    grid = [r for sp in specs for d in dims
            for r in (GemvRequest.pim(BASE, d, PimDType.W8A8, spec=sp),
                      GemvRequest.baseline(BASE, d, PimDType.W8A8,
                                           spec=sp))]
    m = len(grid)
    PimExecutor().run_many(grid)     # warm the heterogeneous slab shapes

    engine.lane_cache_clear()
    t0 = time.perf_counter()
    spec_loop = []
    for sp in specs:
        ex_sp = PimExecutor(sp)
        spec_loop += [ex_sp.run_gemv(r.H, r.W, r.dtype)
                      if r.kind == "pim" else
                      ex_sp.run_baseline(r.H, r.W, r.dtype)
                      for r in grid if r.spec == sp]
    specs_loop_s = time.perf_counter() - t0

    engine.lane_cache_clear()
    t0 = time.perf_counter()
    spec_batch = PimExecutor().run_many(grid)
    specs_batch_s = time.perf_counter() - t0

    for a, b in zip(spec_loop, spec_batch):
        assert a.cycles == b.cycles

    print(f"fleet/specs_looped,{specs_loop_s*1e6/m:.1f},"
          f"{m/specs_loop_s:.1f}")
    print(f"fleet/specs_batched,{specs_batch_s*1e6/m:.1f},"
          f"{m/specs_batch_s:.1f}")
    print(f"fleet/specs_speedup,{specs_batch_s*1e3:.1f},"
          f"{specs_loop_s/specs_batch_s:.1f}")

    # Heterogeneous spec-family fleets: the configs/specfam.py
    # populations (phone-class LP5X, server parts, a CXL-expander-like
    # latency profile) as one design grid — per-family executors +
    # per-point calls vs ONE batched run_many over the whole population,
    # cycle counts asserted bit-identical.  Then each family's offload
    # frontier and draft-model speculative-decode economics become
    # per-population rows (cache lookups + arithmetic after a single
    # plan_grid dispatch).
    from repro.configs import ARCHS
    from repro.configs.specfam import SPEC_FAMILIES
    from repro.serving.offload import OffloadPlanner
    fam_grid = [r for sp in SPEC_FAMILIES.values() for d in dims
                for r in (GemvRequest.pim(BASE, d, PimDType.W8A8, spec=sp),
                          GemvRequest.baseline(BASE, d, PimDType.W8A8,
                                               spec=sp))]
    fm = len(fam_grid)
    PimExecutor().run_many(fam_grid)  # warm heterogeneous slab shapes

    engine.lane_cache_clear()
    t0 = time.perf_counter()
    fam_loop = []
    for sp in SPEC_FAMILIES.values():
        ex_fam = PimExecutor(sp)
        fam_loop += [ex_fam.run_gemv(r.H, r.W, r.dtype)
                     if r.kind == "pim" else
                     ex_fam.run_baseline(r.H, r.W, r.dtype)
                     for r in fam_grid if r.spec == sp]
    specfam_loop_s = time.perf_counter() - t0

    engine.lane_cache_clear()
    t0 = time.perf_counter()
    fam_batch = PimExecutor().run_many(fam_grid)
    specfam_batch_s = time.perf_counter() - t0

    for a, b in zip(fam_loop, fam_batch):
        assert a.cycles == b.cycles, (a.meta, a.cycles, b.cycles)

    print(f"fleet/specfam_looped,{specfam_loop_s*1e6/fm:.1f},"
          f"{fm/specfam_loop_s:.1f}")
    print(f"fleet/specfam_batched,{specfam_batch_s*1e6/fm:.1f},"
          f"{fm/specfam_batch_s:.1f}")
    print(f"fleet/specfam_speedup,{specfam_batch_s*1e3:.1f},"
          f"{specfam_loop_s/specfam_batch_s:.1f}")

    fam_planner = OffloadPlanner(ARCHS["mamba2-130m"], PimSimulator())
    fam_planner.plan_grid(list(SPEC_FAMILIES.values()))
    specfam_spec_decode = {}
    for fam_name, sp in SPEC_FAMILIES.items():
        frontier = fam_planner.frontier(spec=sp)
        sdrec = fam_planner.spec_decode_speedup(spec=sp)
        specfam_spec_decode[fam_name] = sdrec["speedup"]
        n_pim = sum(1 for b in frontier.values() if b > 1)
        print(f"fleet/specfam_{fam_name},{n_pim}/{len(frontier)},"
              f"{sdrec['speedup']:.2f}")

    # Serving replan loop: fresh planner per query (so the planner's own
    # plan cache cannot hide engine work), resolved-lane LRU off vs on.
    from repro.configs import ARCHS
    from repro.serving.offload import OffloadPlanner
    cfg = ARCHS["mamba2-130m"]
    reps = 2

    def replan_once() -> float:
        return OffloadPlanner(cfg, PimSimulator()).decode_speedup(
            batch=4)["speedup"]

    engine.configure_lane_cache(0)          # disabled
    replan_once()                           # warm engine compiles
    t0 = time.perf_counter()
    cold = [replan_once() for _ in range(reps)]
    replan_cold_s = (time.perf_counter() - t0) / reps

    engine.configure_lane_cache(4096)       # enabled, then warmed
    replan_once()
    t0 = time.perf_counter()
    warm = [replan_once() for _ in range(reps)]
    replan_warm_s = (time.perf_counter() - t0) / reps

    assert cold == warm, "lane cache must not change telemetry results"

    print(f"fleet/serve_replan_cold,{replan_cold_s*1e6:.1f},"
          f"{1/replan_cold_s:.2f}")
    print(f"fleet/serve_replan_cached,{replan_warm_s*1e6:.1f},"
          f"{1/replan_warm_s:.2f}")
    print(f"fleet/serve_replan_speedup,{replan_warm_s*1e3:.1f},"
          f"{replan_cold_s/replan_warm_s:.1f}")

    # Adaptive offload control: each policy closed-loop over the same
    # bursty serving trace (simulated occupancy, fresh planner per
    # policy so the plan cost is inside the measurement).  Columns:
    # us per decode step, planner queries issued.  The efficiency row
    # asserts the cheap policies stay >= 0.95x of the per-step oracle —
    # the rows always track a correct control loop, same discipline as
    # the bit-exactness asserts above.
    from repro.serving.scenarios import DisaggConfig, assign_slo, \
        make_scenario, occupancy_trace, run_policy_over_trace, \
        simulate_batches, simulate_disagg
    trace = occupancy_trace(make_scenario("bursty", seed=7, quick=quick))
    policy_reports = {}
    policy_step_us = {}
    for pol in ("per-step", "hysteresis", "sticky"):
        planner_pol = OffloadPlanner(cfg, PimSimulator())
        t0 = time.perf_counter()
        controller = run_policy_over_trace(planner_pol, pol, trace)
        dt = time.perf_counter() - t0
        rep = controller.report()
        policy_reports[pol] = rep
        policy_step_us[pol] = dt * 1e6 / max(rep["steps"], 1)
        print(f"fleet/policy_{pol},{policy_step_us[pol]:.1f},"
              f"{rep['planner_queries']}")
    per_step = policy_reports["per-step"]
    assert abs(per_step["efficiency"] - 1.0) < 1e-12, \
        "per-step recompute must be its own oracle"
    for pol in ("hysteresis", "sticky"):
        rep = policy_reports[pol]
        assert rep["efficiency"] >= 0.95, (pol, rep["efficiency"])
        assert rep["planner_queries"] < per_step["planner_queries"], \
            (pol, rep["planner_queries"])
    print(f"fleet/policy_efficiency,"
          f"{policy_reports['hysteresis']['efficiency']:.4f},"
          f"{policy_reports['sticky']['efficiency']:.4f}")

    # Disaggregated serving: the model-free cell-pair simulator vs the
    # monolithic queue model on the same bursty workload (us/tick;
    # mirror parity asserted, so the rows always track the pinned
    # scheduling semantics), then the policy closed loop over the
    # bounded SLO-mixed pair's decode occupancy with the efficiency
    # floor, the handoff bound and warm-handoff lane accounting all
    # asserted.
    spec_d = make_scenario("bursty", seed=7, quick=quick)
    reps_d = 20
    t0 = time.perf_counter()
    for _ in range(reps_d):
        mono_batches = simulate_batches(spec_d)
    disagg_mono_s = (time.perf_counter() - t0) / reps_d
    t0 = time.perf_counter()
    for _ in range(reps_d):
        mirror_sim = simulate_disagg(spec_d)
    disagg_cells_s = (time.perf_counter() - t0) / reps_d
    assert mirror_sim["per_tick_batch"] == mono_batches, \
        "mirror cells must replay the monolithic queue model"
    ticks = len(mono_batches)
    print(f"fleet/disagg_sim_mono,{disagg_mono_s*1e6/ticks:.2f},"
          f"{ticks/disagg_mono_s:.0f}")
    print(f"fleet/disagg_sim_cells,{disagg_cells_s*1e6/ticks:.2f},"
          f"{ticks/disagg_cells_s:.0f}")

    dcfg = DisaggConfig(prefill_budget=2, handoff_bound=3,
                        starvation_age=4)
    dsim = simulate_disagg(spec_d, dcfg, assign_slo(spec_d, 0.6))
    assert dsim["max_handoff_depth"] <= dcfg.handoff_bound, \
        "KV-handoff bound overrun"
    dec_trace = [b for b in dsim["per_tick_batch"] if b > 0]
    disagg_eff = {}
    for pol in ("hysteresis", "sticky"):
        rep = run_policy_over_trace(OffloadPlanner(cfg, PimSimulator()),
                                    pol, dec_trace).report()
        assert rep["efficiency"] >= 0.95, (pol, rep["efficiency"])
        disagg_eff[pol] = rep["efficiency"]
    print(f"fleet/disagg_efficiency,{disagg_eff['hysteresis']:.4f},"
          f"{disagg_eff['sticky']:.4f}")
    print(f"fleet/disagg_handoff,{dsim['max_handoff_depth']},"
          f"{dcfg.handoff_bound}")

    # Warm handoff does zero lane re-resolves: once the planner's fleet
    # query has populated the lane LRU, serving the whole disagg trace
    # adds no misses.
    warm_planner = OffloadPlanner(cfg, PimSimulator())
    warm_planner.plan()
    before_misses = engine.lane_cache_info()["misses"]
    run_policy_over_trace(warm_planner, "hysteresis", dec_trace)
    new_misses = engine.lane_cache_info()["misses"] - before_misses
    assert new_misses == 0, \
        f"warm disagg serve re-resolved {new_misses} lanes"
    print(f"fleet/disagg_lane_resolves,{new_misses},{len(dec_trace)}")

    # Serve-daemon economics (model-free): the autoscaled cell pair vs
    # the fixed-slot oracle on the same bounded SLO-mixed workload.
    # Efficiency = decode work served per slot-tick PROVISIONED — the
    # fixed oracle provisions slots x ticks, the autoscaler only what
    # its limit trace admits — asserted >= 0.95x the oracle (in
    # practice well above 1: idle slots are the oracle's waste).  The
    # streamed-trace writer is timed per record with its chunk
    # reassembly asserted equal, so the daemon rows always track a
    # correct trace path.
    from repro.serving.daemon import TraceWriter
    from repro.serving.scenarios import AutoscaleConfig
    auto_cfg = AutoscaleConfig(min_slots=1)
    slo_d = assign_slo(spec_d, 0.6)
    t0 = time.perf_counter()
    for _ in range(reps_d):
        auto_sim = simulate_disagg(spec_d, dcfg, slo_d,
                                   autoscale=auto_cfg)
    daemon_auto_s = (time.perf_counter() - t0) / reps_d
    fixed_sim = simulate_disagg(spec_d, dcfg, slo_d)
    auto_ticks = len(auto_sim["per_tick_batch"])
    auto_eff = sum(auto_sim["per_tick_batch"]) / sum(auto_sim["limits"])
    fixed_eff = (sum(fixed_sim["per_tick_batch"])
                 / (spec_d.slots * len(fixed_sim["per_tick_batch"])))
    daemon_eff_ratio = auto_eff / fixed_eff
    assert daemon_eff_ratio >= 0.95, \
        f"autoscale efficiency {daemon_eff_ratio:.3f}x below the oracle"
    assert set(auto_sim["completion_ticks"]) == \
        set(fixed_sim["completion_ticks"]), \
        "autoscale must complete the same request set"
    print(f"fleet/daemon_sim_autoscale,{daemon_auto_s*1e6/auto_ticks:.2f},"
          f"{auto_ticks/daemon_auto_s:.0f}")
    print(f"fleet/daemon_autoscale_efficiency,{daemon_eff_ratio:.2f},"
          f"{auto_ticks/len(fixed_sim['per_tick_batch']):.2f}")

    with tempfile.TemporaryDirectory(prefix="repro-daemon-") as tdir:
        path = f"{tdir}/trace.jsonl"
        writer = TraceWriter(path, chunk_records=256)
        writer.write_meta(scenario=spec_d.to_record(), policy="bench",
                          fence=True)
        t0 = time.perf_counter()
        for tick, b in enumerate(auto_sim["per_tick_batch"]):
            writer.write_tick(tick, b)
        writer.write_summary(dict(limits=auto_sim["limits"]))
        writer.close()
        daemon_stream_s = time.perf_counter() - t0
        loaded = TraceWriter.load(path)
    assert loaded["per_tick_batch"] == auto_sim["per_tick_batch"], \
        "streamed chunks must reassemble the exact tick trace"
    print(f"fleet/daemon_stream,{daemon_stream_s*1e6/auto_ticks:.2f},"
          f"{writer.flushes}")

    # Chaos: the degradation ladder on the fleet resolve path.  The
    # same prebuilt points resolve three ways — healthy; under a
    # transient top-rung fault (absorbed by one bounded retry, backoff
    # on a VirtualClock so the row never real-sleeps); and, when the
    # ladder has a lower rung, under a persistent top-rung fault that
    # steps the resolve down.  Every variant's totals are asserted
    # byte-identical to the looped oracle: degradation moves latency,
    # never bytes.
    ladder = engine.ladder_rungs()
    top_site = f"backend.{ladder[0]}"
    engine.lane_cache_clear()
    t0 = time.perf_counter()
    chaos_healthy = engine.resolve_fleet(points)
    chaos_healthy_s = time.perf_counter() - t0
    for solo, fr in zip(looped, chaos_healthy):
        np.testing.assert_array_equal(solo, fr.totals)

    faults.reset()
    inj = faults.FaultInjector()
    inj.arm(top_site, count=1, message="benchmark transient")
    engine.lane_cache_clear()
    with faults.fault_scope(inj), \
            faults.retry_scope(clock=faults.VirtualClock()):
        t0 = time.perf_counter()
        absorbed = engine.resolve_fleet(points)
        chaos_absorbed_s = time.perf_counter() - t0
    kinds = [e["kind"] for e in faults.events()]
    assert "retry" in kinds, "transient fault was never retried"
    assert "degrade" not in kinds, "transient fault must not step down"
    for solo, fr in zip(looped, absorbed):
        np.testing.assert_array_equal(solo, fr.totals)

    chaos_degraded_s = None
    if len(ladder) > 1:
        faults.reset()
        inj = faults.FaultInjector()
        inj.arm(top_site, count=-1, message="benchmark persistent")
        engine.lane_cache_clear()
        with faults.fault_scope(inj), \
                faults.retry_scope(clock=faults.VirtualClock()):
            t0 = time.perf_counter()
            degraded = engine.resolve_fleet(points)
            chaos_degraded_s = time.perf_counter() - t0
        n_degrades = sum(1 for e in faults.events()
                         if e["kind"] == "degrade")
        assert n_degrades >= 1, "persistent fault never stepped down"
        for solo, fr in zip(looped, degraded):
            np.testing.assert_array_equal(solo, fr.totals)
    faults.reset()

    print(f"fleet/chaos_healthy,{chaos_healthy_s*1e6/n:.1f},"
          f"{n/chaos_healthy_s:.1f}")
    print(f"fleet/chaos_absorbed,{chaos_absorbed_s*1e6/n:.1f},"
          f"{chaos_absorbed_s/chaos_healthy_s:.2f}")
    if chaos_degraded_s is not None:
        print(f"fleet/chaos_degraded,{chaos_degraded_s*1e6/n:.1f},"
              f"{chaos_degraded_s/chaos_healthy_s:.2f}")
    else:
        print("fleet/chaos_degraded,terminal_rung_only,1.00")

    # Cold vs warm process start: same child workload twice against one
    # persistent cache dir.  The warm child must produce byte-identical
    # totals with zero lane-cache misses (every lane replayed from the
    # snapshot, XLA executables from the compile cache).
    with tempfile.TemporaryDirectory(prefix="repro-warm-") as cache_dir:
        runs = []
        for _ in range(2):
            out = subprocess.run(
                [sys.executable, "-c", _COLDSTART_CHILD, cache_dir],
                capture_output=True, text=True, check=True)
            runs.append(json.loads(out.stdout.strip().splitlines()[-1]))
    cold_run, warm_run = runs
    assert warm_run["totals"] == cold_run["totals"], \
        "warm-start replay must be bit-identical"
    assert warm_run["misses"] == 0, \
        (f"warm process resolved lanes it should have replayed: "
         f"{warm_run['misses']} misses")
    coldstart_speedup = cold_run["elapsed"] / warm_run["elapsed"]
    print(f"fleet/coldstart_cold,{cold_run['elapsed']*1e3:.0f},"
          f"{cold_run['misses']}")
    print(f"fleet/coldstart_warm,{warm_run['elapsed']*1e3:.0f},"
          f"{warm_run['misses']}")
    print(f"fleet/coldstart_speedup,{warm_run['elapsed']*1e3:.0f},"
          f"{coldstart_speedup:.2f}")

    return dict(points=n,
                devices=len(engine.lane_devices()),
                plan_speedup=plan_ref_s / plan_vec_s,
                resolve_speedup=resolve_loop_s / resolve_batch_s,
                mesh_sizes=mesh_sizes,
                mesh_speedup=resolve_batch_s / mesh_best_s,
                mesh_step_us={m: s * 1e6 / n
                              for m, s in mesh_row_s.items()},
                sweep_speedup=sweep_loop_s / sweep_batch_s,
                specs_speedup=specs_loop_s / specs_batch_s,
                specfam_speedup=specfam_loop_s / specfam_batch_s,
                specfam_families=list(SPEC_FAMILIES),
                specfam_spec_decode=specfam_spec_decode,
                serve_replan_speedup=replan_cold_s / replan_warm_s,
                pallas_speedup=pallas_speedup,
                coldstart_speedup=coldstart_speedup,
                coldstart_cold_s=cold_run["elapsed"],
                coldstart_warm_s=warm_run["elapsed"],
                policy_efficiency={p: r["efficiency"]
                                   for p, r in policy_reports.items()},
                policy_queries={p: r["planner_queries"]
                                for p, r in policy_reports.items()},
                policy_step_us=policy_step_us,
                disagg_sim_mono_tick_us=disagg_mono_s * 1e6 / ticks,
                disagg_sim_cells_tick_us=disagg_cells_s * 1e6 / ticks,
                disagg_efficiency=disagg_eff,
                disagg_max_handoff_depth=dsim["max_handoff_depth"],
                disagg_lane_resolves=new_misses,
                daemon_autoscale_efficiency=daemon_eff_ratio,
                daemon_sim_tick_us=daemon_auto_s * 1e6 / auto_ticks,
                daemon_stream_record_us=daemon_stream_s * 1e6 / auto_ticks,
                chaos_ladder=ladder,
                chaos_absorbed_overhead=chaos_absorbed_s / chaos_healthy_s,
                chaos_degraded_overhead=(
                    chaos_degraded_s / chaos_healthy_s
                    if chaos_degraded_s is not None else None),
                plan_batched_s=plan_vec_s,
                sweep_batched_s=sweep_batch_s,
                sweep_looped_s=sweep_loop_s)


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
