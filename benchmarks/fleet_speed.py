"""Fleet-throughput benchmark: batched vs looped sweep resolution.

Two comparisons over the full Fig. 4 grid (both axes, all dtypes, fence
on/off, PIM + baseline points):

* ``fleet/resolve_*`` — the execution core alone: per-point
  ``engine.run_streams`` loop vs one ``engine.resolve_fleet`` call on the
  same prebuilt streams (isolates the dispatch/batching win).
* ``fleet/sweep_*`` — end to end: a per-call ``run_gemv``/``run_baseline``
  loop vs one ``PimExecutor.run_many`` (includes stream building, which
  both paths share).

Also asserts the batched cycle counts are bit-identical to the looped
ones, so the speedup rows in BENCH_*.json always track a correct result.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine
from repro.core.timing import DEFAULT_SYSTEM
from repro.pimkernel.executor import GemvRequest, PimExecutor
from repro.pimkernel.tileconfig import ALL_DTYPES

DIMS = [512, 1024, 2048, 4096, 8192]
BASE = 4096


def fig4_grid() -> list[GemvRequest]:
    """Every (axis, dtype, dim, fence) point of Fig. 4 + its baseline."""
    reqs: list[GemvRequest] = []
    seen: set = set()
    for fence in (False, True):
        for axis in ("activation", "output"):
            for dt in ALL_DTYPES:
                for d in DIMS:
                    H, W = (BASE, d) if axis == "activation" else (d, BASE)
                    for r in (GemvRequest.pim(H, W, dt, fence=fence),
                              GemvRequest.baseline(H, W, dt)):
                        if r.key not in seen:
                            seen.add(r.key)
                            reqs.append(r)
    return reqs


def main() -> dict:
    ex = PimExecutor(DEFAULT_SYSTEM)
    reqs = fig4_grid()
    n = len(reqs)

    # Build all streams once; both resolve paths time the same arrays.
    planned = ex.plan_many(reqs)
    points = [(ex.cyc, p.streams) for p in planned]

    # Warm the compile caches of both paths (compilation is a one-time
    # cost shared across every spec variant; we measure steady state).
    engine.run_streams(ex.cyc, planned[0].streams)
    engine.resolve_fleet(points)

    t0 = time.perf_counter()
    looped = [engine.run_streams(ex.cyc, p.streams)[1] for p in planned]
    resolve_loop_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    fleet = engine.resolve_fleet(points)
    resolve_batch_s = time.perf_counter() - t0

    for solo, fr in zip(looped, fleet):
        np.testing.assert_array_equal(solo, fr.totals)

    print(f"fleet/resolve_looped,{resolve_loop_s*1e6/n:.1f},"
          f"{n/resolve_loop_s:.1f}")
    print(f"fleet/resolve_batched,{resolve_batch_s*1e6/n:.1f},"
          f"{n/resolve_batch_s:.1f}")
    print(f"fleet/resolve_speedup,{resolve_batch_s*1e3:.1f},"
          f"{resolve_loop_s/resolve_batch_s:.1f}")

    # End to end: fresh executors so neither path reuses built streams.
    ex_loop = PimExecutor(DEFAULT_SYSTEM)
    t0 = time.perf_counter()
    solo_res = [
        ex_loop.run_gemv(r.H, r.W, r.dtype, fence=r.fence,
                         reshape=r.reshape, flush=r.flush)
        if r.kind == "pim" else
        ex_loop.run_baseline(r.H, r.W, r.dtype)
        for r in reqs]
    sweep_loop_s = time.perf_counter() - t0

    ex_batch = PimExecutor(DEFAULT_SYSTEM)
    t0 = time.perf_counter()
    batch_res = ex_batch.run_many(reqs)
    sweep_batch_s = time.perf_counter() - t0

    for a, b in zip(solo_res, batch_res):
        assert a.cycles == b.cycles, (a.meta, a.cycles, b.cycles)

    print(f"fleet/sweep_looped,{sweep_loop_s*1e6/n:.1f},"
          f"{n/sweep_loop_s:.1f}")
    print(f"fleet/sweep_batched,{sweep_batch_s*1e6/n:.1f},"
          f"{n/sweep_batch_s:.1f}")
    print(f"fleet/sweep_speedup,{sweep_batch_s*1e3:.1f},"
          f"{sweep_loop_s/sweep_batch_s:.1f}")

    return dict(points=n,
                resolve_speedup=resolve_loop_s / resolve_batch_s,
                sweep_speedup=sweep_loop_s / sweep_batch_s,
                sweep_batched_s=sweep_batch_s,
                sweep_looped_s=sweep_loop_s)


if __name__ == "__main__":
    main()
