"""Planning-pipeline fast path: vectorized synthesis, structural lane
keys, the resolved-lane LRU, and multi-device lane sharding.

The contract under test: the block-vectorized ``GemvKernel.build`` is
byte-identical to the retained ``StreamBuilder`` reference path, keyed
lane resolution is result-identical to byte-hash dedupe (and no weaker at
deduping), the lane cache is a pure memo (hits change nothing but time),
and sharding slabs across forced XLA host devices is bit-identical to the
single-device fallback.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import engine
from repro.core.timing import DEFAULT_SYSTEM, LpddrTimings, SystemSpec
from repro.pimkernel.executor import (GemvRequest, PimExecutor,
                                      spec_context)
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType

from test_engine import build_valid_stream, random_op_tuples


@pytest.fixture(autouse=True)
def _fresh_lane_cache():
    engine.configure_lane_cache(4096)
    engine.lane_cache_reset()
    yield
    engine.configure_lane_cache(4096)
    engine.lane_cache_reset()


def _build_both(ex, H, W, dt, fence=False, reshape=False, flush="bus",
                x=None):
    layout, program = ex.plan(H, W, dt, reshape=reshape)
    kernel = spec_context(layout.spec).kernel
    vec = kernel.build(layout, program, x=x, fence=fence, flush=flush)
    ref = kernel.build_reference(layout, program, x=x, fence=fence,
                                 flush=flush)
    return vec, ref


def _assert_streams_equal(vec, ref, ctx=""):
    assert len(vec.streams) == len(ref.streams)
    for ch, (a, b) in enumerate(zip(vec.streams, ref.streams)):
        np.testing.assert_array_equal(a, b, err_msg=f"{ctx} ch{ch}")
    assert vec.meta == ref.meta
    for pv, pr in zip(vec.payloads, ref.payloads):
        assert sorted(pv) == sorted(pr), ctx
        for k in pv:
            np.testing.assert_array_equal(pv[k], pr[k], err_msg=ctx)


def test_vectorized_builder_parity_fig4_grid():
    """Byte-identical streams across the Fig-4 grid (both tile groups,
    fence on/off, reshape on/off, both flush modes)."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    for dt in ALL_DTYPES:
        for d in (512, 2048, 8192):
            for fence in (False, True):
                for reshape in (False, True):
                    for flush in ("bus", "dram"):
                        vec, ref = _build_both(ex, 4096, d, dt,
                                               fence=fence,
                                               reshape=reshape,
                                               flush=flush)
                        _assert_streams_equal(
                            vec, ref, f"{dt} d={d} f={fence} r={reshape}")


def test_vectorized_builder_parity_fuzzed_shapes():
    """Fuzzed (H, W) incl. edge tiles, tiny shapes and payload paths."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    rng = np.random.default_rng(42)
    for _ in range(20):
        H = int(rng.integers(1, 5000))
        W = int(rng.integers(1, 5000))
        dt = ALL_DTYPES[int(rng.integers(len(ALL_DTYPES)))]
        fence = bool(rng.integers(2))
        reshape = bool(rng.integers(2))
        flush = ("bus", "dram")[int(rng.integers(2))]
        vec, ref = _build_both(ex, H, W, dt, fence=fence, reshape=reshape,
                               flush=flush)
        _assert_streams_equal(vec, ref, f"H={H} W={W} {dt}")
    # payload (functional) parity on a W4 path that exercises packing
    x = rng.integers(-8, 8, 700).astype(np.int8)
    vec, ref = _build_both(ex, 300, 700, PimDType.W4A4, reshape=True, x=x)
    _assert_streams_equal(vec, ref, "payload")


def test_stream_keys_shared_across_equal_channels():
    """Channels with identical round-sets share one ndarray + one key."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    layout, program = ex.plan(4096, 4096, PimDType.W8A8)
    gs = spec_context(layout.spec).kernel.build(layout, program)
    assert gs.stream_keys is not None
    by_key = {}
    for s, k in zip(gs.streams, gs.stream_keys):
        if k in by_key:
            assert by_key[k] is s, "equal keys must share the ndarray"
        by_key[k] = s
    # full-utilization layout: every channel plays the same role
    assert len(by_key) < len(gs.streams)


def _fuzz_lanes(n_points=3, seed=9):
    rng = np.random.default_rng(seed)
    lanes = []
    for i in range(n_points):
        spec = SystemSpec(timings=LpddrTimings(tRCD=18.0 + 2 * i))
        cyc = spec.derive_cycles()
        for _ in range(3):
            lanes.append((cyc, build_valid_stream(random_op_tuples(
                rng, max_ops=30))))
    return lanes


def test_structural_keys_match_byte_hash():
    """Keyed resolution == unkeyed resolution, lane by lane."""
    lanes = _fuzz_lanes()
    plain = engine.resolve_lanes(lanes)
    engine.lane_cache_clear()
    keyed = engine.resolve_lanes(lanes, keys=[("k", i) for i in
                                              range(len(lanes))])
    for (ia, ta), (ib, tb) in zip(plain, keyed):
        assert ta == tb
        np.testing.assert_array_equal(ia, ib)


def test_structural_key_dedupe_shares_results():
    """Lanes with one key resolve once (same result object), and equal
    bytes under different keys still merge via the hash fallback."""
    rng = np.random.default_rng(3)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    s = build_valid_stream(random_op_tuples(rng, max_ops=25))
    lanes = [(cyc, s), (cyc, s.copy()), (cyc, s.copy())]
    out = engine.resolve_lanes(lanes, keys=["a", "a", "b"])
    assert out[0][0] is out[1][0], "same key -> one resolution"
    # key "b" has identical bytes: second-level dedupe shares the array
    assert out[0][0] is out[2][0], "equal bytes -> one resolution"
    assert out[0][1] == out[2][1]


def test_lane_cache_hits_and_invalidation():
    lanes = _fuzz_lanes(seed=11)
    keys = [("lane", i) for i in range(len(lanes))]
    engine.configure_lane_cache(4096)
    first = engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    info0 = engine.lane_cache_info()
    assert info0["size"] > 0
    second = engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    info1 = engine.lane_cache_info()
    assert info1["hits"] >= info0["hits"] + len(lanes)
    for (_, ta), (_, tb) in zip(first, second):
        assert ta == tb
    # totals-only entries don't serve need_issue=True for large lanes,
    # but results must still agree after the recompute/upgrade
    third = engine.resolve_lanes(lanes, keys=keys, need_issue=True)
    for (_, ta), (ib, tb) in zip(first, third):
        assert ta == tb and ib is not None
    # invalidation: clear drops entries, next resolve misses again
    engine.lane_cache_clear()
    assert engine.lane_cache_info()["size"] == 0
    miss0 = engine.lane_cache_info()["misses"]
    engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    assert engine.lane_cache_info()["misses"] > miss0
    # different timing config must never hit the old entries
    other = SystemSpec(timings=LpddrTimings(tRCD=31.0)).derive_cycles()
    alt = engine.resolve_lanes([(other, s) for _c, s in lanes], keys=keys)
    for (_, ta), (_, tb) in zip(first, alt):
        pass  # totals may legitimately differ; the point is no crash
    # disabled cache: no entries, identical results
    engine.configure_lane_cache(0)
    off = engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    assert engine.lane_cache_info()["size"] == 0
    for (_, ta), (_, tb) in zip(first, off):
        assert ta == tb


def test_lane_cache_lru_eviction():
    lanes = _fuzz_lanes(seed=13)
    keys = [("e", i) for i in range(len(lanes))]
    engine.configure_lane_cache(2)
    engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    assert engine.lane_cache_info()["size"] <= 2


def test_run_many_replay_served_from_lane_cache():
    """A repeated sweep resolves from the lane LRU with equal results."""
    engine.configure_lane_cache(4096)
    reqs = [GemvRequest.pim(256, 1024, PimDType.W8A8),
            GemvRequest.pim(512, 512, PimDType.W4A4, fence=True),
            GemvRequest.baseline(256, 1024, PimDType.W8A8)]
    first = PimExecutor(DEFAULT_SYSTEM).run_many(reqs)
    h0 = engine.lane_cache_info()["hits"]
    again = PimExecutor(DEFAULT_SYSTEM).run_many(reqs)
    assert engine.lane_cache_info()["hits"] > h0
    for a, b in zip(first, again):
        assert a.cycles == b.cycles and a.energy == b.energy


_CHILD = r"""
import json, sys
import numpy as np
from repro.core import engine
from repro.core.timing import DEFAULT_SYSTEM, LpddrTimings, SystemSpec
sys.path.insert(0, __TESTDIR__)
from test_engine import build_valid_stream, random_op_tuples

import jax
assert jax.device_count() == 4, jax.device_count()

rng = np.random.default_rng(21)
specs = [SystemSpec(timings=LpddrTimings(tRCD=18.0 + i)) for i in range(3)]
points = [(sp.derive_cycles(),
           [build_valid_stream(random_op_tuples(rng, max_ops=40))
            for _ in range(5)]) for sp in specs for _ in range(2)]

engine.configure_lane_cache(0)           # measure real resolution
engine.configure_lane_devices(1)         # single-device fallback
solo = engine.resolve_fleet(points)
warm_single = engine.compile_cache_size()

engine.configure_lane_devices(None)      # all 4 forced host devices
assert len(engine.lane_devices()) == 4
shard = engine.resolve_fleet(points)
for a, b in zip(solo, shard):
    np.testing.assert_array_equal(a.totals, b.totals)
    for ia, ib in zip(a.issue, b.issue):
        np.testing.assert_array_equal(ia, ib)

# compile-cache invariant under sharding: new spec variants on the same
# fleet shape compile nothing, at any device count
warm = engine.compile_cache_size()
more = [SystemSpec(timings=LpddrTimings(tRCD=25.0 + i))
        for i in range(len(points))]
points2 = [(sp.derive_cycles(), streams)
           for sp, (cyc, streams) in zip(more, points)]
engine.resolve_fleet(points2)
assert engine.compile_cache_size() == warm, "spec variants recompiled"
print(json.dumps({"ok": True, "compiles": warm}))
"""


def test_multi_device_sharding_parity():
    """Forced 4-host-device run: sharded == single-device bit-exactly,
    and compile_cache_size stays spec-variant-invariant when sharded."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _CHILD.replace("__TESTDIR__", repr(os.path.dirname(__file__)))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]


def test_occupancy_weighted_offload_speedup():
    """The occupancy-weighted telemetry is the histogram-weighted mix of
    per-batch decisions (ROADMAP: crossover per step, not per run)."""
    from repro.configs import ARCHS
    from repro.serving.offload import OffloadPlanner
    planner = OffloadPlanner(ARCHS["mamba2-130m"])
    one = planner.decode_speedup(batch=2)
    flat = planner.occupancy_weighted_speedup({2: 5})
    assert flat["speedup"] == pytest.approx(one["speedup"])
    assert flat["steps"] == 5
    mixed = planner.occupancy_weighted_speedup({1: 3, 2: 1, 4: 2})
    host = sum(planner.decode_speedup(batch=b)["host_ns"] * c
               for b, c in {1: 3, 2: 1, 4: 2}.items())
    mix = sum(planner.decode_speedup(batch=b)["mixed_ns"] * c
              for b, c in {1: 3, 2: 1, 4: 2}.items())
    assert mixed["speedup"] == pytest.approx(host / mix)
    assert set(mixed["per_batch_speedup"]) == {1, 2, 4}
