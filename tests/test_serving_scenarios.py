"""Trace-driven serving scenario tests + the golden serve-trace fixture.

Three layers of trust, PIMSIM-NN style (policies only earn belief
through reproducible, scenario-diverse validation):

1. *Generators* — every built-in scenario is seed-deterministic and
   shape-checked (bursts burst, drains drain, prefill-heavy prompts are
   long).
2. *Conformance* — ``simulate_batches`` (the pure queue model the
   benchmarks and dry-run closed loops run on) matches a real
   ``ServingEngine`` scenario run tick for tick.
3. *Policies* — on every scenario x {hysteresis, sticky}, the adaptive
   controller keeps >= 0.95x of the per-step oracle's
   occupancy-weighted speedup while issuing strictly fewer planner
   queries; per-step recompute is its own oracle everywhere.

One seeded bursty scenario's full telemetry is pinned byte-exactly in
``tests/golden/serve_trace.json``; regenerate deliberately with
``python tests/test_serving_scenarios.py``.
"""
import json
import pathlib

import jax
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import model as M
from repro.serving.offload import OffloadPlanner
from repro.serving.scenarios import (SCENARIOS, ScenarioSpec,
                                     make_scenario, occupancy_trace,
                                     replay_batches, run_policy_over_trace,
                                     run_scenario, simulate_batches)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "serve_trace.json"

GOLDEN_SCENARIO = dict(name="bursty", seed=3, slots=4, quick=True)
GOLDEN_POLICY = "hysteresis"


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def planner():
    # Site grid of the smallest arch — one batched fleet query, then
    # every policy run is pure arithmetic over the cached decisions.
    return OffloadPlanner(ARCHS["mamba2-130m"])


# ---------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenarios_deterministic(name):
    a = make_scenario(name, seed=11, quick=True)
    b = make_scenario(name, seed=11, quick=True)
    assert a == b
    c = make_scenario(name, seed=12, quick=True)
    assert a.arrivals != c.arrivals


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_shapes(name):
    spec = make_scenario(name, seed=0)
    assert spec.arrivals, name
    for a in spec.arrivals:
        assert a.step >= 0 and a.prompt_len >= 4 and a.max_new >= 2
    batches = simulate_batches(spec)
    nonzero = [b for b in batches if b]
    assert nonzero and max(nonzero) <= spec.slots
    if name == "prefill-heavy":
        assert min(a.prompt_len for a in spec.arrivals) >= 24
    if name == "drain-refill":
        # waves separated by idle gaps: occupancy collapses to zero
        # strictly inside the trace, then refills
        first, last = batches.index(0), len(batches) - 1
        assert 0 < first and 0 in batches[first:last]
        assert any(b > 0 for b in batches[batches.index(0):])
    if name == "bursty":
        assert max(nonzero) >= 4     # bursts actually pile up


def test_unknown_scenario_raises():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("rush-hour")


def test_scenario_record_roundtrip():
    spec = make_scenario("diurnal", seed=5, quick=True)
    rec = json.loads(json.dumps(spec.to_record()))
    assert ScenarioSpec.from_record(rec) == spec


# ---------------------------------------------------------------------
# Conformance: pure queue model vs the real engine
# ---------------------------------------------------------------------

def test_simulated_occupancy_matches_engine(small_lm, planner):
    cfg, params = small_lm
    spec = make_scenario("bursty", seed=1, slots=3, quick=True)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step")
    assert trace["per_tick_batch"] == simulate_batches(spec)
    assert sum(1 for b in trace["per_tick_batch"] if b) == trace["steps"]
    occupancy = {}
    for b in trace["per_tick_batch"]:
        if b:
            occupancy[str(b)] = occupancy.get(str(b), 0) + 1
    assert occupancy == trace["occupancy"]


# ---------------------------------------------------------------------
# Policy battery: every scenario, realized vs oracle
# ---------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["hysteresis", "sticky"])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_policy_efficiency_battery(planner, name, policy):
    trace = occupancy_trace(make_scenario(name, seed=0))
    rep = run_policy_over_trace(planner, policy, trace).report()
    assert rep["steps"] == len(trace)
    assert rep["efficiency"] >= 0.95, (name, policy, rep["efficiency"])
    assert rep["realized_speedup"] <= rep["oracle_speedup"] + 1e-12
    assert rep["planner_queries"] < rep["steps"], \
        (name, policy, rep["planner_queries"])


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_per_step_is_oracle_on_every_scenario(planner, name):
    trace = occupancy_trace(make_scenario(name, seed=0))
    rep = run_policy_over_trace(planner, "per-step", trace).report()
    assert rep["efficiency"] == 1.0
    assert rep["planner_queries"] == rep["steps"]


# ---------------------------------------------------------------------
# Golden replay fixture
# ---------------------------------------------------------------------

def _golden_trace(small_lm) -> dict:
    cfg, params = small_lm
    spec = make_scenario(**GOLDEN_SCENARIO)
    planner = OffloadPlanner(ARCHS["granite-8b"])
    return run_scenario(spec, cfg, params, planner, policy=GOLDEN_POLICY)


def test_golden_serve_trace_exact(small_lm):
    """The bursty scenario's full telemetry — per-step speedups,
    occupancy histogram, switch log, controller report — is diffed
    EXACTLY against the committed fixture (scheduling is decode-budget
    driven and speedups are arithmetic over bit-exact engine cycles, so
    nothing platform-dependent enters the trace).  Regenerate
    deliberately with `python tests/test_serving_scenarios.py`."""
    fixture = json.loads(GOLDEN.read_text())
    current = json.loads(json.dumps(_golden_trace(small_lm)))
    assert set(current) == set(fixture)
    for key in fixture:
        assert current[key] == fixture[key], f"golden drift at {key}"


def test_golden_trace_replays_without_model():
    """The committed trace is replayable from its embedded schedule
    alone: the pure queue model re-derives the recorded occupancy."""
    fixture = json.loads(GOLDEN.read_text())
    assert replay_batches(fixture) == fixture["per_tick_batch"]
    rep = fixture["controller"]
    assert rep["policy"] == GOLDEN_POLICY
    assert rep["steps"] == sum(1 for b in fixture["per_tick_batch"] if b)
    assert rep["efficiency"] >= 0.95


if __name__ == "__main__":          # regenerate the committed fixture
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(_golden_trace((cfg, params)), indent=1,
                                 sort_keys=True))
    print(f"wrote {GOLDEN}")
