"""Serve daemon + per-cell backend scopes: the daemonization battery.

Five layers:

1. *Scope isolation* (the headline regression) — the old process-global
   ``configure_lane_backend`` / ``configure_lane_mesh`` state meant a
   breaker tripped by one serve cell's faults changed the OTHER cell's
   backend.  With per-cell :class:`~repro.core.engine.BackendScope`
   objects that is structurally impossible: injecting persistent
   backend faults into the prefill cell's scope leaves the decode
   scope's ladder order, resolved backend, breaker and resolved bytes
   identical to the healthy baseline — asserted directly on
   ``resolve_lanes`` and end-to-end on a scoped cell-pair run.
2. *Autoscale parity* — the :class:`AutoscaleConfig` grow/shrink rule
   is specified model-free in ``simulate_disagg``;
   ``daemon.AutoscaleController`` is the independent real-cell
   implementation.  A bounded SLO-mixed run must match tick-exactly on
   the per-tick limit trace, batches and per-request schedule, and the
   trace must replay byte-identically.
3. *Daemon lifecycle* — scenario-mode ``ServeDaemon`` re-emits the
   ``run_scenario`` trace byte-identically; drain-under-chaos completes
   with zero unhandled exceptions; hard shutdown conserves every
   request (``ingested == completed + shed + in_flight``); idle waits
   go through the shared clock protocol (a test never real-sleeps).
4. *Streaming traces* — ``TraceWriter`` chunks concatenate to a trace
   byte-identical (canonical JSON) to the in-memory path, and the
   reassembled trace replays like any recorded trace.
5. *Empty-population guards* — zero-request and shed-everything runs
   summarize to neutral values (the PR 7 convention), never a divide
   by zero.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.core import engine, faults
from repro.core.timing import DEFAULT_SYSTEM
from repro.kernels import lane_scan
from repro.models import model as M
from repro.serving.cells import DisaggServingEngine
from repro.serving.daemon import (AutoscaleController, ServeDaemon,
                                  TraceWriter)
from repro.serving.offload import OffloadPlanner
from repro.serving.scenarios import (SLO_LATENCY, SLO_THROUGHPUT,
                                     AutoscaleConfig, DisaggConfig,
                                     ScenarioSpec, assign_slo,
                                     make_scenario, replay_batches,
                                     run_scenario, simulate_disagg)

from test_engine import build_valid_stream, random_op_tuples

SCENARIO = dict(name="bursty", seed=3, slots=4, quick=True)
BOUNDED = DisaggConfig(prefill_budget=2, handoff_bound=3,
                       starvation_age=4)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _planner():
    return OffloadPlanner(ARCHS["mamba2-130m"])


def _lanes(seed: int, n: int = 4):
    rng = np.random.default_rng(seed)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    return [(cyc, build_valid_stream(random_op_tuples(rng, max_ops=30)))
            for _ in range(n)]


def _totals(lanes, scope=None):
    engine.lane_cache_clear()
    return [t for _, t in engine.resolve_lanes(lanes, need_issue=False,
                                               scope=scope)]


# ---------------------------------------------------------------------
# 1. Per-scope breakers: one cell's faults never touch the other cell
# ---------------------------------------------------------------------

def test_scope_fault_isolation_regression():
    """THE regression: persistent faults on the prefill scope's top
    rung trip the PREFILL breaker only — the decode scope's ladder
    order, backend, breaker state and resolved bytes stay identical to
    the healthy baseline (under module-global state they did not)."""
    lanes = _lanes(0)
    ref = _totals(lanes)                       # healthy default-scope run
    prefill = engine.BackendScope(mesh=1, name="prefill")
    decode = engine.BackendScope(name="decode")
    assert engine.ladder_rungs(prefill)[0] == "mesh"
    decode_rungs_before = engine.ladder_rungs(decode)
    decode_backend_before = engine.resolved_lane_backend(decode)

    inj = faults.FaultInjector()
    inj.arm("backend.mesh", count=-1, message="prefill-side chaos")
    with faults.fault_scope(inj), \
            faults.retry_scope(retries=0, clock=faults.VirtualClock()):
        for _ in range(3):                     # fail x3: trip the breaker
            assert _totals(lanes, scope=prefill) == ref   # degraded bytes
    assert prefill.scope_breaker().tripped("backend.mesh")

    # The decode scope is untouched in every observable way.
    assert engine.ladder_rungs(decode) == decode_rungs_before
    assert engine.resolved_lane_backend(decode) == decode_backend_before
    assert decode.scope_breaker().info()["open"] == []
    assert _totals(lanes, scope=decode) == ref
    # ...and so is the process default (the pre-fix casualty).
    assert faults.backend_breaker().info()["open"] == []
    assert engine.ladder_rungs() == decode_rungs_before


@pytest.mark.skipif(not lane_scan.pallas_lane_supported(),
                    reason="pallas lane kernel unsupported here")
def test_scope_isolation_across_heterogeneous_backends():
    """A pallas-backed scope degrades under fault while a sibling
    scan-backed scope and the default scope keep their ladders."""
    lanes = _lanes(1)
    ref = _totals(lanes)
    pal = engine.BackendScope(backend="pallas", name="pal")
    scan = engine.BackendScope(backend="scan", name="scan")
    inj = faults.FaultInjector()
    inj.arm("backend.pallas", count=-1)
    with faults.fault_scope(inj), \
            faults.retry_scope(retries=0, clock=faults.VirtualClock()):
        for _ in range(3):
            assert _totals(lanes, scope=pal) == ref
        assert _totals(lanes, scope=scan) == ref
    assert pal.scope_breaker().tripped("backend.pallas")
    assert scan.scope_breaker().info()["open"] == []
    assert engine.ladder_rungs(scan) == ["scan"]


def test_backend_scope_context_manager_nests_and_restores():
    s1 = engine.BackendScope(mesh=1, name="s1")
    assert engine.active_backend_scope() is engine.default_backend_scope()
    with engine.backend_scope(s1):
        assert engine.active_backend_scope() is s1
        assert engine.ladder_rungs() == ["mesh", "scan"]
        with engine.backend_scope(engine.BackendScope(name="s2")) as s2:
            assert engine.active_backend_scope() is s2
        assert engine.active_backend_scope() is s1
    assert engine.active_backend_scope() is engine.default_backend_scope()


def test_scoped_cell_pair_trace_matches_unscoped(small_lm):
    """End to end: a cell pair whose cells carry (default-behaving)
    scopes emits the identical trace — scopes change WHERE faults land,
    never bytes — plus the gated per-cell scope record."""
    cfg, params = small_lm
    spec = make_scenario(**SCENARIO)
    ref = run_scenario(spec, cfg, params, _planner(),
                       policy="hysteresis", disagg=True)
    got = run_scenario(spec, cfg, params, _planner(),
                       policy="hysteresis", disagg=True,
                       prefill_scope=engine.BackendScope(name="prefill"),
                       decode_scope=engine.BackendScope(name="decode"))
    scopes = got["disagg"].pop("scopes")
    assert scopes["prefill"]["name"] == "prefill"
    assert scopes["decode"]["breaker"]["open"] == []
    assert json.dumps(got, sort_keys=True) == json.dumps(ref,
                                                         sort_keys=True)


def test_scopes_require_disagg(small_lm):
    cfg, params = small_lm
    with pytest.raises(ValueError, match="scopes require disagg"):
        run_scenario(make_scenario(**SCENARIO), cfg, params, _planner(),
                     prefill_scope=engine.BackendScope(name="p"))


# ---------------------------------------------------------------------
# 2. Autoscaling: controller-vs-simulator tick-exact parity
# ---------------------------------------------------------------------

def test_autoscale_cells_vs_simulator_parity_and_replay(small_lm):
    cfg, params = small_lm
    spec = make_scenario(**SCENARIO)
    slo = assign_slo(spec)
    auto = AutoscaleConfig(min_slots=1)
    trace = run_scenario(spec, cfg, params, _planner(),
                         policy="hysteresis", disagg=BOUNDED, slo=slo,
                         autoscale=auto)
    sim = simulate_disagg(spec, disagg=BOUNDED, slo=slo, autoscale=auto)
    assert trace["autoscale"]["limits"] == sim["limits"]
    assert trace["per_tick_batch"] == sim["per_tick_batch"]
    req = trace["disagg"]["requests"]
    for key in ("prefill_ticks", "admit_ticks", "completion_ticks"):
        assert req[key] == {str(r): t for r, t in sim[key].items()}
    # Nontrivial: the rule actually grew and shrank on this workload.
    assert trace["autoscale"]["grows"] > 0
    assert trace["autoscale"]["shrinks"] > 0
    assert trace["autoscale"]["config"] == auto.to_record()
    # The autoscaled trace replays byte-identically from its record.
    replayed = run_scenario(ScenarioSpec.from_record(trace["scenario"]),
                            cfg, params, _planner(),
                            policy="hysteresis", disagg=BOUNDED, slo=slo,
                            autoscale=AutoscaleConfig.from_record(
                                trace["autoscale"]["config"]))
    assert json.dumps(replayed, sort_keys=True) == \
        json.dumps(trace, sort_keys=True)


def test_autoscale_limit_trace_is_sane():
    spec = make_scenario("bursty", seed=3, slots=4, quick=False)
    auto = AutoscaleConfig(min_slots=1, max_slots=3, cooldown=2)
    sim = simulate_disagg(spec, disagg=BOUNDED, slo=assign_slo(spec),
                          autoscale=auto)
    lims = sim["limits"]
    assert len(lims) == len(sim["per_tick_batch"])
    assert all(1 <= l <= 3 for l in lims)
    assert all(abs(b - a) <= 1 for a, b in zip(lims, lims[1:]))
    # Cooldown: after any action the limit holds for >= cooldown ticks.
    moves = [i for i, (a, b) in enumerate(zip(lims, lims[1:])) if a != b]
    assert all(b - a > auto.cooldown for a, b in zip(moves, moves[1:]))
    # Admissions respect the limit in force: no tick admits more fresh
    # requests than its limit allows (lame-duck busy slots may keep the
    # BATCH above the limit, but never new admissions).
    admits_at: dict[int, int] = {}
    for t in sim["admit_ticks"].values():
        admits_at[t] = admits_at.get(t, 0) + 1
    assert all(n <= lims[t] for t, n in admits_at.items())


def test_autoscale_requires_disagg(small_lm):
    cfg, params = small_lm
    with pytest.raises(ValueError, match="autoscale requires disagg"):
        run_scenario(make_scenario(**SCENARIO), cfg, params, _planner(),
                     autoscale=AutoscaleConfig())


def test_autoscale_config_validation_and_record_roundtrip():
    for bad in (dict(min_slots=0), dict(min_slots=2, max_slots=1),
                dict(start_slots=0), dict(idle_ticks=0),
                dict(cooldown=-1), dict(latency_wait=-1)):
        with pytest.raises(ValueError):
            AutoscaleConfig(**bad)
    cfg = AutoscaleConfig(min_slots=2, max_slots=5, start_slots=3)
    rec = cfg.to_record()
    assert AutoscaleConfig.from_record(rec) == cfg
    assert "max_slots" not in AutoscaleConfig().to_record()


def test_autoscale_controller_mirrors_limits_on_live_cells(small_lm):
    """Drive the cells by hand with an AutoscaleController and check
    the recorded limit trace against the simulator's, without the
    scenario driver in between."""
    cfg, params = small_lm
    spec = make_scenario("steady", seed=1, slots=3, quick=True)
    slo = {a.rid: SLO_THROUGHPUT for a in spec.arrivals}
    dcfg = DisaggConfig(prefill_budget=1, starvation_age=3)
    eng = DisaggServingEngine(cfg, params, slots=spec.slots, max_seq=64,
                              disagg=dcfg)
    auto = AutoscaleConfig(min_slots=1, idle_ticks=2)
    scaler = AutoscaleController(auto, eng)
    assert eng.decode_cell.limit == 1          # start = min_slots
    rng = np.random.default_rng(spec.seed + 1)
    from repro.serving.engine import Request
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    reqs = {a.rid: Request(rid=a.rid,
                           prompt=rng.integers(0, cfg.vocab,
                                               size=a.prompt_len),
                           max_new=a.max_new) for a in pending}
    i = t = 0
    while i < len(pending) or any(eng.active) or eng.waiting:
        while i < len(pending) and pending[i].step <= t:
            eng.submit(reqs[pending[i].rid], slo=slo[pending[i].rid])
            i += 1
        eng.step()
        scaler.observe(t)
        t += 1
        assert t < 10_000
    sim = simulate_disagg(spec, disagg=dcfg, slo=slo, autoscale=auto)
    assert scaler.limits == sim["limits"]
    assert scaler.report()["slot_ticks"] == sum(sim["limits"])


# ---------------------------------------------------------------------
# 3. Daemon lifecycle
# ---------------------------------------------------------------------

def test_daemon_scenario_mode_matches_run_scenario(small_lm):
    """A pure-scenario daemon run IS the scenario driver: the in-memory
    trace is byte-identical to ``run_scenario(disagg=True)``'s."""
    cfg, params = small_lm
    spec = make_scenario(**SCENARIO)
    ref = run_scenario(spec, cfg, params, _planner(),
                       policy="hysteresis", disagg=True)
    d = ServeDaemon(cfg, params, _planner(), scenario=spec,
                    policy="hysteresis")
    d.run()
    assert json.dumps(d.trace(), sort_keys=True) == \
        json.dumps(ref, sort_keys=True)
    acct = d.accounting()
    assert acct["ingested"] == len(spec.arrivals) == acct["completed"]
    assert acct["in_flight"] == acct["dropped"] == 0


def test_daemon_drain_under_chaos_unhandled_zero(small_lm):
    """Faults fire mid-drain and the daemon still drains clean: every
    ingested request completes, dropped arrivals are accounted, the
    breaker state is reported, and no exception escapes (the
    ``unhandled=0`` contract)."""
    cfg, params = small_lm
    spec = make_scenario(**SCENARIO)
    inj = faults.FaultInjector()
    holder = {}

    def on_tick(t, eng):
        faults.set_tick(t)
        if t == 4:
            holder["d"].drain()                # drain mid-traffic...
        if t in (5, 7):                        # ...then chaos mid-drain
            inj.arm("handoff", count=1)

    d = ServeDaemon(cfg, params, _planner(), scenario=spec,
                    disagg=BOUNDED, on_tick=on_tick)
    holder["d"] = d
    faults.reset_events()
    try:
        with faults.fault_scope(inj), \
                faults.retry_scope(retries=2,
                                   clock=faults.VirtualClock()):
            rep = d.run()
    finally:
        faults.set_tick(None)
    assert rep["draining"] and not rep["stopped"]
    acct = rep["accounting"]
    assert acct["dropped"] > 0                 # post-drain arrivals
    assert acct["ingested"] == acct["completed"] + acct["shed"]
    assert acct["in_flight"] == 0              # drained dry
    assert acct["dropped"] + acct["ingested"] == len(spec.arrivals)
    assert inj.injected > 0                    # chaos actually fired
    stalls = [e for e in faults.events()
              if e["site"] == "handoff" and e["kind"] == "stall"]
    assert stalls and all(e["tick"] >= 5 for e in stalls)   # mid-drain
    with pytest.raises(ValueError, match="draining"):
        d.inject(prompt_len=4, max_new=2)


def test_daemon_hard_shutdown_conserves_every_request(small_lm):
    cfg, params = small_lm
    spec = make_scenario(**SCENARIO)
    d = ServeDaemon(cfg, params, _planner(), scenario=spec)
    for _ in range(6):
        d.step()
    rid = d.inject(prompt_len=5, max_new=3, slo=SLO_THROUGHPUT)
    d.step()                                   # the injection is ingested
    d.shutdown()
    rid2_refused = pytest.raises(ValueError, d.inject, 4, 2)
    assert rid2_refused
    rep = d.run()                              # no-op: already stopped
    assert rep["stopped"]
    acct = rep["accounting"]
    assert acct["ingested"] == (acct["completed"] + acct["shed"]
                                + acct["in_flight"])
    assert acct["in_flight"] > 0               # stopped mid-flight...
    assert rid in d.slo                        # ...injection accounted
    total = (acct["completed"] + acct["shed"] + acct["in_flight"]
             + acct["dropped"] + (len(spec.arrivals) + 1
                                  - acct["ingested"] - acct["dropped"]))
    assert total == len(spec.arrivals) + 1     # nothing vanishes


def test_daemon_injected_arrivals_and_autodrain(small_lm):
    """Injection-only daemon (no scenario): injected requests serve to
    completion; ``max_requests`` auto-drains."""
    cfg, params = small_lm
    d = ServeDaemon(cfg, params, _planner(), max_seq=64, max_requests=2)
    for k in range(3):
        d.inject(prompt_len=4 + k, max_new=3)
    rep = d.run()
    assert rep["draining"]
    acct = rep["accounting"]
    assert acct["completed"] >= 2              # cap reached, then drained
    assert acct["ingested"] == acct["completed"]   # drain served all


def test_daemon_idle_waits_on_virtual_clock_never_sleeps():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    clk = faults.VirtualClock()
    d = ServeDaemon(cfg, params, _planner(), max_seq=64, clock=clk,
                    idle_wait=0.25)
    for _ in range(3):
        d.step()                               # nothing arrives: idle
    assert d.idle_ticks == 3
    assert clk.sleeps == [0.25, 0.25, 0.25]    # virtual, never real


def test_daemon_zero_request_run_is_neutral(small_lm):
    cfg, params = small_lm
    empty = ScenarioSpec(name="empty", seed=0, slots=2, arrivals=())
    d = ServeDaemon(cfg, params, _planner(), scenario=empty)
    rep = d.run()
    assert rep["accounting"] == dict(ingested=0, completed=0, shed=0,
                                     in_flight=0, dropped=0,
                                     queued_inbox=0)
    assert rep["handoff_wait"] == dict(pops=0, mean_wait=0.0, max_wait=0)
    for cls in (SLO_LATENCY, SLO_THROUGHPUT):
        assert rep["slo_wait"][cls] == dict(waiting=0, max_wait=0,
                                            mean_wait=0.0)
    trace = d.trace()
    assert trace["per_tick_batch"] == []
    assert trace["tokens"] == trace["steps"] == 0


# ---------------------------------------------------------------------
# 4. Streaming traces
# ---------------------------------------------------------------------

def test_streamed_trace_chunks_reassemble_byte_identical(small_lm,
                                                         tmp_path):
    """The golden-scenario daemon run streamed through TraceWriter in
    small chunks concatenates to EXACTLY the in-memory trace (canonical
    JSON), and the reassembled trace replays."""
    cfg, params = small_lm
    spec = make_scenario(**SCENARIO)
    d_mem = ServeDaemon(cfg, params, _planner(), scenario=spec,
                        policy="hysteresis")
    d_mem.run()
    in_memory = d_mem.trace()

    path = tmp_path / "trace.jsonl"
    writer = TraceWriter(path, chunk_records=8)
    d_str = ServeDaemon(cfg, params, _planner(), scenario=spec,
                        policy="hysteresis", writer=writer)
    d_str.run()
    assert writer.flushes >= 5                 # actually chunked
    loaded = TraceWriter.load(path)
    assert json.dumps(loaded, sort_keys=True) == \
        json.dumps(in_memory, sort_keys=True)
    # Replayable like any recorded trace (mirror config: the schedule
    # re-derives from the embedded scenario alone).
    assert replay_batches(loaded) == loaded["per_tick_batch"]
    with pytest.raises(ValueError, match="streaming"):
        d_str.trace()


def test_trace_writer_enforces_tick_order(tmp_path):
    w = TraceWriter(tmp_path / "t.jsonl", chunk_records=4)
    w.write_meta(scenario={"name": "x"})
    w.write_tick(0, 3)
    with pytest.raises(ValueError, match="tick-ordered"):
        w.write_tick(2, 1)
    w.close()


def test_trace_writer_bounded_buffer_and_roundtrip(tmp_path):
    path = tmp_path / "t.jsonl"
    with TraceWriter(path, chunk_records=4) as w:
        w.write_meta(policy="per-step", fence=True)
        for t in range(10):
            w.write_tick(t, t % 3)
            assert len(w._buf) < 4 + 1         # buffer never grows past
        w.write_summary(dict(steps=10, tokens=20))
    assert w.flushes >= 3
    out = TraceWriter.load(path)
    assert out == dict(policy="per-step", fence=True,
                       per_tick_batch=[t % 3 for t in range(10)],
                       steps=10, tokens=20)
    with pytest.raises(ValueError):
        TraceWriter(path, chunk_records=0)


def test_trace_writer_load_rejects_corrupt_stream(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(json.dumps(dict(kind="tick", tick=5, batch=1)) + "\n")
    with pytest.raises(ValueError, match="out of order"):
        TraceWriter.load(path)
    path.write_text(json.dumps(dict(kind="nope")) + "\n")
    with pytest.raises(ValueError, match="unknown trace record kind"):
        TraceWriter.load(path)


# ---------------------------------------------------------------------
# 5. Empty-population guards on the cell telemetry
# ---------------------------------------------------------------------

def test_zero_request_cell_pair_summaries_neutral(small_lm):
    cfg, params = small_lm
    eng = DisaggServingEngine(cfg, params, slots=2, max_seq=64)
    for _ in range(3):
        eng.step()
    assert eng.handoff.wait_report() == dict(pops=0, mean_wait=0.0,
                                             max_wait=0)
    for cls, per in eng.summary()["disagg"]["per_class"].items():
        assert per == dict(submitted=0, completed=0,
                           mean_admit_wait=0.0,
                           mean_completion_ticks=0.0)
    for cls, per in eng.wait_telemetry().items():
        assert per == dict(waiting=0, max_wait=0, mean_wait=0.0)


def test_all_shed_run_summaries_neutral(small_lm):
    """Submissions that all shed (capacity 1, never stepped) must
    summarize neutrally: zero completions, 0.0 means, sheds recorded."""
    cfg, params = small_lm
    from repro.serving.engine import Request
    eng = DisaggServingEngine(cfg, params, slots=2, max_seq=64,
                              disagg=DisaggConfig(admission_capacity=1))
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, size=4),
                           max_new=2), slo=SLO_LATENCY)
    assert len(eng.shed) == 3                  # capacity 1 kept one
    rec = eng.summary()["disagg"]
    per = rec["per_class"][SLO_LATENCY]
    assert per["submitted"] == 4 and per["completed"] == 0
    assert per["mean_admit_wait"] == 0.0
    assert per["mean_completion_ticks"] == 0.0
    assert eng.handoff.wait_report()["mean_wait"] == 0.0


def test_handoff_wait_report_tracks_pops(small_lm):
    cfg, params = small_lm
    eng = DisaggServingEngine(cfg, params, slots=2, max_seq=64,
                              disagg=DisaggConfig(prefill_budget=4))
    from repro.serving.engine import Request
    rng = np.random.default_rng(1)
    for i in range(4):                         # 4 prefills, 2 slots:
        eng.submit(Request(rid=i,              # two wait in the handoff
                           prompt=rng.integers(0, cfg.vocab, size=4),
                           max_new=3), slo=SLO_LATENCY)
    eng.run(max_steps=50)
    rep = eng.handoff.wait_report()
    assert rep["pops"] == 4
    assert rep["max_wait"] >= 1                # the queued pair waited
    assert rep["mean_wait"] > 0.0


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
