"""Per-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned architectures: instantiate the reduced
same-family config, run forward + one train step + prefill/decode, assert
output shapes and finiteness (no NaNs).  Also checks causality (a suffix
change never affects earlier logits) and prefill/decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import model as M
from repro.training.optimizer import adamw_init, adamw_update

ARCH_IDS = list(ARCHS)


def _smoke_batch(cfg, rng, b=2, s=32):
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32) * 0.1
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    else:
        toks = s - cfg.prefix_patches
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, toks)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, toks)), jnp.int32)
        if cfg.prefix_patches:
            batch["patches"] = jnp.asarray(
                rng.standard_normal((b, cfg.prefix_patches, cfg.d_model)),
                jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(ARCHS[arch])
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, rng)
    logits, aux = jax.jit(
        lambda p, b: M.forward(cfg, p, b, remat=False))(params, batch)
    n_out = batch["labels"].shape[1]
    assert logits.shape == (2, n_out, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch, remat=False)[0]))(params)
    assert bool(jnp.isfinite(loss))
    opt = adamw_init(params)
    params2, opt2 = adamw_update(params, grads, opt, lr=1e-3)
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree.map(lambda a, b: a - b, params, params2), 0.0)
    assert moved > 0
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.isfinite(g).all(), grads))
    assert all(bool(x) for x in leaves), "non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode logits == full-forward logits."""
    cfg = smoke_config(ARCHS[arch])
    rng = np.random.default_rng(1)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 24
    batch = _smoke_batch(cfg, rng, b, s)
    full_logits, _ = M.forward(cfg, params, batch, remat=False)

    cache = M.init_cache(cfg, b, s + 8, dtype=jnp.float32)
    if cfg.input_mode == "embeddings":
        prompt = {"embeds": batch["embeds"][:, :-1]}
        last = batch["embeds"][:, -1:]
        n_tok = s
    else:
        prompt = {"tokens": batch["tokens"][:, :-1]}
        if cfg.prefix_patches:
            prompt["patches"] = batch["patches"]
        last = batch["tokens"][:, -1:]
        n_tok = batch["tokens"].shape[1]
    logits_p, cache = M.prefill(cfg, params, prompt, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, -2]),
        rtol=2e-4, atol=2e-4)

    pos = jnp.asarray(s - 1 if cfg.input_mode == "embeddings"
                      else s - 1, jnp.int32)
    pos = jnp.asarray((cfg.prefix_patches + n_tok) - 1, jnp.int32)
    logits_d, cache = M.decode_step(cfg, params, cache, last, pos)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full_logits[:, -1]),
        rtol=2e-4, atol=2e-4)


def test_moe_decode_drops_batch_rows():
    """Regression test for the (fixed) prefill/decode MoE divergence.

    A decode-shaped call (B, S=1) flattens to N = B tokens; under the
    legacy per-call GShard capacity (ceil(B * k * cf / e)) the
    position-in-expert cumsum across flattened *batch* rows overflowed
    the tiny per-step capacity and rows > 0 were silently dropped.
    Capacity now derives from the flattened token count so it never
    binds: identical inputs in one decode batch produce identical
    outputs, and prefill/decode agree (the flipped strict xfails in
    test_prefill_decode_consistency are the other half of this signal).
    """
    from repro.models import moe as MOE
    d, e, ff = 16, 4, 32
    params = MOE.moe_init(jax.random.PRNGKey(0), d, ff, e, "gelu")
    row = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    x = jnp.broadcast_to(row, (4, 1, d))        # decode-shaped batch
    y, _ = MOE.moe_apply(params, x, top_k=1, capacity_factor=1.0,
                         mlp_kind="gelu")
    y = np.asarray(y)
    assert np.abs(y[0]).sum() > 0, "row 0 must route normally"
    np.testing.assert_allclose(y[3], y[0], rtol=1e-6, atol=1e-6,
                               err_msg="batch row 3 was capacity-dropped")


def test_moe_drop_tokens_mode_keeps_capacity_bound():
    """drop_tokens=True retains the legacy bounded dispatch buffer: with
    cap = ceil(n*k*cf/e) = 1, duplicate rows routed to one expert must
    drop — the memory-bound training tradeoff stays available."""
    from repro.models import moe as MOE
    d, e, ff = 16, 4, 32
    params = MOE.moe_init(jax.random.PRNGKey(0), d, ff, e, "gelu")
    row = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    x = jnp.broadcast_to(row, (4, 1, d))
    y, _ = MOE.moe_apply(params, x, top_k=1, capacity_factor=1.0,
                         mlp_kind="gelu", drop_tokens=True)
    y = np.asarray(y)
    assert np.abs(y[0]).sum() > 0
    assert np.abs(y[3]).sum() == 0, "row 3 should drop under cap=1"


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma3-4b", "mamba2-130m",
                                  "hymba-1.5b"])
def test_causality(arch):
    """Changing a future token never changes past logits."""
    cfg = smoke_config(ARCHS[arch])
    rng = np.random.default_rng(2)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    batch = _smoke_batch(cfg, rng, 1, 24)
    l1, _ = M.forward(cfg, params, batch, remat=False)
    if cfg.input_mode == "embeddings":
        e = np.array(batch["embeds"])
        e[:, -1] += 10.0
        batch2 = dict(batch, embeds=jnp.asarray(e))
    else:
        t = np.array(batch["tokens"])
        t[:, -1] = (t[:, -1] + 7) % cfg.vocab
        batch2 = dict(batch, tokens=jnp.asarray(t))
    l2, _ = M.forward(cfg, params, batch2, remat=False)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]), rtol=1e-4,
                               atol=1e-4)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


def test_flash_matches_dense():
    """Blockwise streaming attention == quadratic attention, with and
    without causal block skipping (§Perf flash-skip variant)."""
    from repro.models import layers as LAY
    rng = np.random.default_rng(3)
    b, s, hq, hkv, hd = 2, 300, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    for skip in (False, True):
        LAY.FLASH_SKIP_BLOCKS = skip
        try:
            for window in (None, 64):
                d = LAY.dense_attention(q, k, v, window=window)
                f = LAY.flash_attention(q, k, v, window=window,
                                        block_q=64, block_k=96)
                np.testing.assert_allclose(np.asarray(d), np.asarray(f),
                                           rtol=2e-5, atol=2e-5)
        finally:
            LAY.FLASH_SKIP_BLOCKS = False


def test_gemma_local_global_pattern():
    cfg = ARCHS["gemma3-4b"]
    kinds = np.asarray(M.layer_kinds(cfg))
    assert kinds.sum() == cfg.n_layers // cfg.global_every
    assert kinds[cfg.global_every - 1] == 1 and kinds[0] == 0


def test_param_counts_sane():
    """Param counts are in the architecture's advertised ballpark."""
    expect = {"qwen2-72b": (65e9, 85e9), "granite-8b": (7e9, 10e9),
              "gemma3-4b": (3e9, 6e9), "granite-20b": (18e9, 22e9),
              "musicgen-large": (1.2e9, 2.5e9),
              "granite-moe-3b-a800m": (2.5e9, 4.5e9),
              "dbrx-132b": (115e9, 145e9), "hymba-1.5b": (1.2e9, 2.2e9),
              "internvl2-26b": (18e9, 28e9), "mamba2-130m": (0.1e9, 0.2e9)}
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
