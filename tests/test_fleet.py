"""Fleet execution core: batched parity, dedupe, and compile behavior.

The contract of the fleet request API: ``run_many`` is bit-identical to
the per-call paths, the result cache dedupes across query styles, points
with *different* ``SystemSpec`` timing resolve correctly inside one fleet
batch, and — because the timing configuration is traced, not baked in —
running the same workload under many spec variants costs exactly one
engine compilation per stream-length bucket.
"""
import numpy as np

from repro.core import engine
from repro.core.pimsim import PimSimulator
from repro.core.timing import (DEFAULT_SYSTEM, LpddrTimings, PimSpec,
                               SystemSpec)
from repro.pimkernel.executor import GemvRequest, PimExecutor
from repro.pimkernel.tileconfig import PimDType

from test_engine import build_valid_stream, random_op_tuples

# A (H, W, dtype, fence, reshape) grid covering both tile groups, the
# reshape regime and the fence path.
GRID = [
    (256, 1024, PimDType.W8A8, False, False),
    (256, 1024, PimDType.W8A8, False, True),
    (512, 4096, PimDType.W8A16, True, False),
    (1024, 512, PimDType.W4A4, False, False),
    (1024, 2048, PimDType.W4A16, True, True),
    (2048, 2048, PimDType.FP_W8A8, True, False),
    (4096, 1024, PimDType.FP_W8A16, False, False),
    (4096, 4096, PimDType.W4A8, False, False),
]


def _same_result(a, b):
    assert a.cycles == b.cycles
    assert a.ns == b.ns
    assert a.flops == b.flops
    assert a.weight_bytes == b.weight_bytes
    np.testing.assert_array_equal(a.counts, b.counts)
    assert a.energy == b.energy


def test_run_many_bit_identical_to_run_gemv():
    ex = PimExecutor(DEFAULT_SYSTEM)
    reqs = [GemvRequest.pim(h, w, dt, fence=f, reshape=r)
            for (h, w, dt, f, r) in GRID]
    batched = ex.run_many(reqs)
    for req, res in zip(reqs, batched):
        solo = ex.run_gemv(req.H, req.W, req.dtype, fence=req.fence,
                           reshape=req.reshape)
        _same_result(res, solo)


def test_run_many_baseline_bit_identical():
    ex = PimExecutor(DEFAULT_SYSTEM)
    reqs = [GemvRequest.baseline(h, w, dt) for (h, w, dt, _f, _r) in GRID]
    batched = ex.run_many(reqs)
    for req, res in zip(reqs, batched):
        _same_result(res, ex.run_baseline(req.H, req.W, req.dtype))


def test_run_baseline_times_every_channel():
    """All num_channels streams flow through the engine (not 1 scaled)."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    res = ex.run_baseline(1024, 1024, PimDType.W8A8)
    per_ch = res.energy["channels"]
    assert len(per_ch) == DEFAULT_SYSTEM.num_channels
    # identical replicated streams -> identical per-channel energy
    assert all(d == per_ch[0] for d in per_ch[1:])
    total = 1024 * 1024 * PimDType.W8A8.w_bits // 8
    assert res.weight_bytes == total


def test_run_many_dedupes_and_preserves_order():
    ex = PimExecutor(DEFAULT_SYSTEM)
    r1 = GemvRequest.pim(256, 1024, PimDType.W8A8)
    r2 = GemvRequest.baseline(256, 1024, PimDType.W8A8)
    res = ex.run_many([r1, r2, r1, r1, r2])
    assert res[0] is res[2] and res[0] is res[3]
    assert res[1] is res[4]
    assert res[0].meta.get("kind") != "baseline"
    assert res[1].meta.get("kind") == "baseline"


def test_simulator_cache_shared_across_query_styles():
    sim = PimSimulator()
    sw = sim.sweep([1024, 2048], [PimDType.W8A8])["W8A8"]
    # speedup() must come straight from the cache (same keys)
    assert sim.speedup(4096, 1024, PimDType.W8A8) == sw[0]
    assert sim.speedup(4096, 2048, PimDType.W8A8) == sw[1]
    direct = (sim.baseline(4096, 1024, PimDType.W8A8).ns
              / sim.gemv(4096, 1024, PimDType.W8A8).ns)
    assert direct == sw[0]


def test_multi_spec_fleet_resolves_each_spec():
    """Points with different TimingCycles share one fleet batch."""
    rng = np.random.default_rng(7)
    stream = build_valid_stream(random_op_tuples(rng))
    specs = [SystemSpec(timings=LpddrTimings(tRCD=18.0 + 2 * i))
             for i in range(4)]
    points = [(sp.derive_cycles(), [stream, stream]) for sp in specs]
    fleet = engine.resolve_fleet(points)
    totals = set()
    for sp, fr in zip(specs, fleet):
        _, solo = engine.run_streams(sp.derive_cycles(), [stream, stream])
        np.testing.assert_array_equal(solo, fr.totals)
        totals.add(int(fr.totals[0]))
    assert len(totals) > 1, "spec variants must resolve differently"


def test_one_compilation_across_spec_variants():
    """>= 8 SystemSpec variants, same workload: zero extra compiles.

    The timing configuration is traced fleet data, so the jit cache keys
    only on (num_banks, fleet bucket, length bucket) — the first variant
    pays one compilation per stream-length bucket, the rest pay none.
    """
    variants = [
        SystemSpec(timings=LpddrTimings(tRCD=16.0 + i, tRP=17.0 + i),
                   pim=PimSpec(mac_interval_ck=2 + (i % 3)),
                   fence_ns=100.0 + 10 * i)
        for i in range(8)
    ]
    cycs = [sp.derive_cycles() for sp in variants]
    assert len(set(cycs)) == 8, "variants must be distinct configs"

    rng = np.random.default_rng(3)
    streams = [build_valid_stream(random_op_tuples(rng))
               for _ in range(4)]

    engine.resolve_fleet([(cycs[0], streams)])   # compile the buckets
    warm = engine.compile_cache_size()
    totals = []
    for cyc in cycs:
        fr = engine.resolve_fleet([(cyc, streams)])[0]
        totals.append(int(fr.totals.max()))
    assert engine.compile_cache_size() == warm, \
        "spec variants must not trigger recompilation"
    assert len(set(totals)) > 1


def test_compilations_bounded_by_length_buckets():
    """Distinct stream-length buckets compile once each; repeats reuse."""
    cyc = DEFAULT_SYSTEM.derive_cycles()
    rng = np.random.default_rng(5)
    streams = {}
    for target in (20, 200):
        while True:
            s = build_valid_stream(random_op_tuples(rng))
            if s.shape[0] and engine._length_bucket(s.shape[0]) not in \
                    streams and s.shape[0] >= target:
                streams[engine._length_bucket(s.shape[0])] = s
                break
    for s in streams.values():          # compile each bucket once
        engine.resolve_fleet([(cyc, [s])])
    warm = engine.compile_cache_size()
    for s in streams.values():          # same buckets again -> no compile
        engine.resolve_fleet([(cyc, [s])])
    assert engine.compile_cache_size() == warm
