"""Fleet execution core: batched parity, dedupe, and compile behavior.

The contract of the fleet request API: ``run_many`` is bit-identical to
the per-call paths, the result cache dedupes across query styles, points
with *different* ``SystemSpec`` timing resolve correctly inside one fleet
batch, and — because the timing configuration is traced, not baked in —
running the same workload under many spec variants costs exactly one
engine compilation per stream-length bucket.
"""
import numpy as np

from repro.core import engine
from repro.core.pimsim import PimSimulator
from repro.core.timing import (DEFAULT_SYSTEM, LpddrTimings, PimSpec,
                               SystemSpec)
from repro.pimkernel.executor import GemvRequest, PimExecutor
from repro.pimkernel.tileconfig import PimDType

from test_engine import build_valid_stream, random_op_tuples

# A (H, W, dtype, fence, reshape) grid covering both tile groups, the
# reshape regime and the fence path.
GRID = [
    (256, 1024, PimDType.W8A8, False, False),
    (256, 1024, PimDType.W8A8, False, True),
    (512, 4096, PimDType.W8A16, True, False),
    (1024, 512, PimDType.W4A4, False, False),
    (1024, 2048, PimDType.W4A16, True, True),
    (2048, 2048, PimDType.FP_W8A8, True, False),
    (4096, 1024, PimDType.FP_W8A16, False, False),
    (4096, 4096, PimDType.W4A8, False, False),
]


def _same_result(a, b):
    assert a.cycles == b.cycles
    assert a.ns == b.ns
    assert a.flops == b.flops
    assert a.weight_bytes == b.weight_bytes
    np.testing.assert_array_equal(a.counts, b.counts)
    assert a.energy == b.energy


def test_run_many_bit_identical_to_run_gemv():
    ex = PimExecutor(DEFAULT_SYSTEM)
    reqs = [GemvRequest.pim(h, w, dt, fence=f, reshape=r)
            for (h, w, dt, f, r) in GRID]
    batched = ex.run_many(reqs)
    for req, res in zip(reqs, batched):
        solo = ex.run_gemv(req.H, req.W, req.dtype, fence=req.fence,
                           reshape=req.reshape)
        _same_result(res, solo)


def test_run_many_baseline_bit_identical():
    ex = PimExecutor(DEFAULT_SYSTEM)
    reqs = [GemvRequest.baseline(h, w, dt) for (h, w, dt, _f, _r) in GRID]
    batched = ex.run_many(reqs)
    for req, res in zip(reqs, batched):
        _same_result(res, ex.run_baseline(req.H, req.W, req.dtype))


def test_run_baseline_times_every_channel():
    """All num_channels streams flow through the engine (not 1 scaled)."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    res = ex.run_baseline(1024, 1024, PimDType.W8A8)
    per_ch = res.energy["channels"]
    assert len(per_ch) == DEFAULT_SYSTEM.num_channels
    # identical replicated streams -> identical per-channel energy
    assert all(d == per_ch[0] for d in per_ch[1:])
    total = 1024 * 1024 * PimDType.W8A8.w_bits // 8
    assert res.weight_bytes == total


def test_run_many_dedupes_and_preserves_order():
    ex = PimExecutor(DEFAULT_SYSTEM)
    r1 = GemvRequest.pim(256, 1024, PimDType.W8A8)
    r2 = GemvRequest.baseline(256, 1024, PimDType.W8A8)
    res = ex.run_many([r1, r2, r1, r1, r2])
    assert res[0] is res[2] and res[0] is res[3]
    assert res[1] is res[4]
    assert res[0].meta.get("kind") != "baseline"
    assert res[1].meta.get("kind") == "baseline"


def test_simulator_cache_shared_across_query_styles():
    sim = PimSimulator()
    sw = sim.sweep([1024, 2048], [PimDType.W8A8])["W8A8"]
    # speedup() must come straight from the cache (same keys)
    assert sim.speedup(4096, 1024, PimDType.W8A8) == sw[0]
    assert sim.speedup(4096, 2048, PimDType.W8A8) == sw[1]
    direct = (sim.baseline(4096, 1024, PimDType.W8A8).ns
              / sim.gemv(4096, 1024, PimDType.W8A8).ns)
    assert direct == sw[0]


def test_multi_spec_fleet_resolves_each_spec():
    """Points with different TimingCycles share one fleet batch."""
    rng = np.random.default_rng(7)
    stream = build_valid_stream(random_op_tuples(rng))
    specs = [SystemSpec(timings=LpddrTimings(tRCD=18.0 + 2 * i))
             for i in range(4)]
    points = [(sp.derive_cycles(), [stream, stream]) for sp in specs]
    fleet = engine.resolve_fleet(points)
    totals = set()
    for sp, fr in zip(specs, fleet):
        _, solo = engine.run_streams(sp.derive_cycles(), [stream, stream])
        np.testing.assert_array_equal(solo, fr.totals)
        totals.add(int(fr.totals[0]))
    assert len(totals) > 1, "spec variants must resolve differently"


def test_one_compilation_across_spec_variants():
    """>= 8 SystemSpec variants, same workload: zero extra compiles.

    The timing configuration is traced fleet data, so the jit cache keys
    only on (num_banks, fleet bucket, length bucket) — the first variant
    pays one compilation per stream-length bucket, the rest pay none.
    """
    variants = [
        SystemSpec(timings=LpddrTimings(tRCD=16.0 + i, tRP=17.0 + i),
                   pim=PimSpec(mac_interval_ck=2 + (i % 3)),
                   fence_ns=100.0 + 10 * i)
        for i in range(8)
    ]
    cycs = [sp.derive_cycles() for sp in variants]
    assert len(set(cycs)) == 8, "variants must be distinct configs"

    rng = np.random.default_rng(3)
    streams = [build_valid_stream(random_op_tuples(rng))
               for _ in range(4)]

    engine.resolve_fleet([(cycs[0], streams)])   # compile the buckets
    warm = engine.compile_cache_size()
    totals = []
    for cyc in cycs:
        fr = engine.resolve_fleet([(cyc, streams)])[0]
        totals.append(int(fr.totals.max()))
    assert engine.compile_cache_size() == warm, \
        "spec variants must not trigger recompilation"
    assert len(set(totals)) > 1


def _timing_variants(n: int) -> list[SystemSpec]:
    """n distinct timing-only variants: identical command streams, so
    compile behavior can be asserted independently of stream content."""
    return [SystemSpec(timings=LpddrTimings(tRCD=20.0 + i, tRP=19.0 + i),
                       pim=PimSpec(mac_interval_ck=2 + (i % 3)),
                       fence_ns=120.0 + 10 * i)
            for i in range(n)]


# Acceptance grid: >= 4 shapes incl. baseline/fence/reshape coverage.
HET_SHAPES = [
    ("pim", 256, 1024, PimDType.W8A8, False, False),
    ("pim", 512, 4096, PimDType.W8A16, True, False),
    ("pim", 1024, 512, PimDType.W4A8, False, True),
    ("pim", 2048, 2048, PimDType.FP_W8A8, False, False),
    ("base", 1024, 1024, PimDType.W8A8, False, False),
]


def _het_grid(specs) -> list[GemvRequest]:
    return [GemvRequest.pim(h, w, dt, fence=f, reshape=r, spec=sp)
            if kind == "pim" else GemvRequest.baseline(h, w, dt, spec=sp)
            for sp in specs for (kind, h, w, dt, f, r) in HET_SHAPES]


def test_heterogeneous_spec_grid_one_fleet_call():
    """>= 3 SystemSpec variants x >= 4 shapes through ONE run_many,
    bit-identical to per-spec executor instances."""
    specs = [DEFAULT_SYSTEM] + _timing_variants(3)
    batched = PimExecutor().run_many(_het_grid(specs))
    it = iter(batched)
    distinct = set()
    for sp in specs:
        ex = PimExecutor(sp)
        for (kind, h, w, dt, f, r) in HET_SHAPES:
            solo = ex.run_gemv(h, w, dt, fence=f, reshape=r) \
                if kind == "pim" else ex.run_baseline(h, w, dt)
            res = next(it)
            _same_result(res, solo)
            distinct.add((sp is specs[0], res.cycles))
    # the variants genuinely time differently (not one spec replicated)
    assert len({c for _d, c in distinct}) > len(HET_SHAPES)


def test_spec_variants_do_not_grow_compile_cache():
    """compile_cache_size() is independent of the NUMBER of spec
    variants: swapping one heterogeneous variant set for another (same
    shapes, same fleet width) compiles nothing new."""
    grid_a = _het_grid(_timing_variants(4))
    grid_b = _het_grid(_timing_variants(8)[4:])
    ex = PimExecutor()
    res_a = ex.run_many(grid_a)              # pays the bucket compiles
    warm = engine.compile_cache_size()
    res_b = ex.run_many(grid_b)              # 4 brand-new specs
    assert engine.compile_cache_size() == warm, \
        "new spec variants must not trigger recompilation"
    assert {r.cycles for r in res_a} != {r.cycles for r in res_b}


def test_run_many_spec_none_resolves_to_default():
    """Spec-less requests run under the executor default and dedupe
    against explicitly-spec'd twins."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    bare = GemvRequest.pim(256, 1024, PimDType.W8A8)
    explicit = GemvRequest.pim(256, 1024, PimDType.W8A8,
                               spec=DEFAULT_SYSTEM)
    res = ex.run_many([bare, explicit])
    assert res[0] is res[1]


def test_simulator_sweep_specs_grid():
    """sweep(specs=[...]) resolves the (spec x dtype x dim) surface in
    one batch and matches per-spec sweeps exactly."""
    sim = PimSimulator()
    specs = _timing_variants(3)
    surface = sim.sweep([1024, 2048], [PimDType.W8A8], specs=specs)
    assert set(surface) == {0, 1, 2}
    for i, sp in enumerate(specs):
        solo = PimSimulator(sp).sweep([1024, 2048], [PimDType.W8A8])
        assert surface[i] == solo
    vals = {tuple(surface[i]["W8A8"]) for i in surface}
    assert len(vals) == 3, "variants must produce distinct surfaces"


def test_offload_plan_grid_matches_per_spec_planners():
    from repro.configs import ARCHS
    from repro.serving.offload import OffloadPlanner
    cfg = ARCHS["mamba2-130m"]
    specs = [DEFAULT_SYSTEM] + _timing_variants(2)
    planner = OffloadPlanner(cfg)
    grid = planner.plan_grid(specs)
    assert len(grid) == len(specs)
    for sp, decisions in zip(specs, grid):
        solo = OffloadPlanner(cfg, PimSimulator(sp)).plan()
        assert [(d.site.name, d.pim_ns, d.host_ns,
                 d.offload_below_batch) for d in decisions] == \
               [(d.site.name, d.pim_ns, d.host_ns,
                 d.offload_below_batch) for d in solo]
    # cached: a repeat issues no new engine work (same objects back)
    assert planner.plan_grid(specs)[0][0] is grid[0][0]


def test_length_buckets_are_three_quarter_refined():
    """Stream lengths pad to the {2^k, 1.5 * 2^(k-1)} bucket series with
    <= 1.5x tail waste, and the refinement doesn't regress compiles."""
    assert [engine._length_bucket(n)
            for n in (1, 16, 17, 24, 25, 33, 48, 49, 64, 65)] == \
        [16, 16, 24, 24, 32, 48, 48, 64, 64, 96]
    for n in range(1, 4096):
        b = engine._length_bucket(n)
        assert b >= max(n, 16)
        assert b <= 1.5 * max(n, 11), (n, b)
        # buckets are stable: every length in [n, bucket] shares one pad
        assert engine._length_bucket(b) == b
    # two lengths inside one 3/4 bucket share a single executable
    cyc = DEFAULT_SYSTEM.derive_cycles()
    s = build_valid_stream(random_op_tuples(np.random.default_rng(11),
                                            max_ops=30))
    n = s.shape[0]
    bucket = engine._length_bucket(n)
    engine.resolve_fleet([(cyc, [s])])
    warm = engine.compile_cache_size()
    engine.resolve_fleet([(cyc, [s[: max(1, n - 2)]])])
    if engine._length_bucket(max(1, n - 2)) == bucket:
        assert engine.compile_cache_size() == warm


def test_compilations_bounded_by_length_buckets():
    """Distinct stream-length buckets compile once each; repeats reuse."""
    cyc = DEFAULT_SYSTEM.derive_cycles()
    rng = np.random.default_rng(5)
    streams = {}
    for target in (20, 200):
        while True:
            s = build_valid_stream(random_op_tuples(rng))
            if s.shape[0] and engine._length_bucket(s.shape[0]) not in \
                    streams and s.shape[0] >= target:
                streams[engine._length_bucket(s.shape[0])] = s
                break
    for s in streams.values():          # compile each bucket once
        engine.resolve_fleet([(cyc, [s])])
    warm = engine.compile_cache_size()
    for s in streams.values():          # same buckets again -> no compile
        engine.resolve_fleet([(cyc, [s])])
    assert engine.compile_cache_size() == warm
