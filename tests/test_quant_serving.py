"""Quantized-serving (§Perf W8/W4) correctness tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import model as M
from repro.models import quant as Q


@pytest.fixture(autouse=True)
def _reset_quant():
    yield
    M.QUANT_BITS = 0


@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_dequant_roundtrip_error(bits):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
    b = Q._quantize_leaf(w, bits)
    back = Q.dequant_leaf(b, bits, jnp.float32)
    rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
    assert rel < (0.01 if bits == 8 else 0.12), rel


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma3-4b", "mamba2-130m",
                                  "dbrx-132b"])
def test_w8_serving_matches_bf16(arch):
    """W8 prefill logits ~= full-precision logits (top-1 agreement)."""
    cfg = smoke_config(ARCHS[arch])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    if cfg.prefix_patches:
        batch = {"tokens": toks,
                 "patches": jnp.asarray(
                     rng.standard_normal((2, cfg.prefix_patches,
                                          cfg.d_model)), jnp.float32)}
    else:
        batch = {"tokens": toks}
    cache = M.init_cache(cfg, 2, 40, jnp.float32)
    l0, _ = M.prefill(cfg, params, batch, cache)
    qp = M.quantize_for_serving(params, 8)
    M.QUANT_BITS = 8
    cache2 = M.init_cache(cfg, 2, 40, jnp.float32)
    l1, _ = M.prefill(cfg, qp, batch, cache2)
    M.QUANT_BITS = 0
    cos = float(jnp.sum(l0 * l1) /
                (jnp.linalg.norm(l0) * jnp.linalg.norm(l1)))
    assert cos > 0.995, cos
    if cfg.family != "moe":
        # MoE routing on random-init weights flips experts under tiny
        # perturbations (near-uniform logits) — cosine is the gate there.
        agree = float(jnp.mean((jnp.argmax(l0, -1) ==
                                jnp.argmax(l1, -1)).astype(jnp.float32)))
        assert agree >= 0.9, agree


def test_quantized_logical_tree_aligns():
    """quantize_logical mirrors quantize_params structurally."""
    cfg = smoke_config(ARCHS["qwen2-72b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qp = Q.quantize_params(params, 8)
    ql = Q.quantize_logical(M.param_logical(cfg))
    s1 = jax.tree.structure(jax.tree.map(lambda x: 0, qp))
    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    s2 = jax.tree.structure(jax.tree.map(lambda x: 0, ql,
                                         is_leaf=is_leaf))
    assert s1 == s2


def test_param_bytes_shrink():
    from repro.configs import SHAPES
    from repro.distribution.sharding import state_bytes_per_device
    cfg = ARCHS["qwen2-72b"]
    shape = SHAPES["decode_32k"]
    base = state_bytes_per_device(cfg, shape)["params"]
    M.QUANT_BITS = 8
    q8 = state_bytes_per_device(cfg, shape)["params"]
    M.QUANT_BITS = 0
    assert q8 < 0.6 * base


def test_kv8_cache_decode_matches_fp():
    """int8 KV cache (prefill-time scales): decode ~= fp cache."""
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)

    def run(kvq):
        M.KV_QUANT = kvq
        cache = M.init_cache(cfg, 2, 32, jnp.float32)
        M.KV_QUANT = False
        lp, cache = M.prefill(cfg, params, {"tokens": toks[:, :-1]},
                              cache)
        ld, _ = M.decode_step(cfg, params, cache, toks[:, -1:],
                              jnp.asarray(11, jnp.int32))
        return lp, ld

    lp0, ld0 = run(False)
    lp1, ld1 = run(True)
    np.testing.assert_allclose(np.asarray(lp0), np.asarray(lp1),
                               atol=1e-4)
    cos = float(jnp.sum(ld0 * ld1) /
                (jnp.linalg.norm(ld0) * jnp.linalg.norm(ld1)))
    assert cos > 0.999, cos
