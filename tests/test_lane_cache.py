"""Resolved-lane LRU regression tests, serving-replan flavored.

The lane cache is what makes adaptive replanning affordable (PR 3:
~50-60x on serve replans), and its counters are now a *policy input* —
the sticky policy treats a growing miss count as "the memoized timing
world went cold" and re-plans.  These tests pin the counter semantics
across repeated planner replans, the disabled (capacity 0) path, the
eviction counter, and the headline property: a sticky-policy replan
against a warm cache does ZERO fleet resolves.
"""
import pytest

from repro.configs import ARCHS
from repro.core import engine
from repro.core.pimsim import PimSimulator
from repro.serving.offload import OffloadPlanner
from repro.serving.policy import OffloadController
from repro.serving.scenarios import make_scenario, occupancy_trace

ARCH = "mamba2-130m"


@pytest.fixture(autouse=True)
def fresh_lane_cache():
    engine.configure_lane_cache(4096)
    engine.lane_cache_reset()
    yield
    engine.configure_lane_cache(4096)
    engine.lane_cache_reset()


def fresh_planner() -> OffloadPlanner:
    return OffloadPlanner(ARCHS[ARCH], PimSimulator())


def test_replan_hit_miss_counters():
    """First plan misses (cold lanes), every replan after it only hits."""
    planner = fresh_planner()
    planner.plan()
    info = engine.lane_cache_info()
    assert info["misses"] > 0 and info["size"] > 0
    for _ in range(3):
        planner.invalidate()
        planner.plan()
    info2 = engine.lane_cache_info()
    assert info2["misses"] == info["misses"], "warm replan missed"
    assert info2["hits"] > info["hits"]
    assert info2["evictions"] == 0


def test_disabled_lane_cache_counts_nothing_and_agrees():
    planner = fresh_planner()
    warm = {d.site.name: (d.pim_ns, d.host_ns) for d in planner.plan()}
    engine.configure_lane_cache(0)
    planner = fresh_planner()
    cold = {d.site.name: (d.pim_ns, d.host_ns) for d in planner.plan()}
    info = engine.lane_cache_info()
    assert info == dict(size=0, maxsize=0, hits=0, misses=0, evictions=0)
    assert cold == warm, "lane cache must not change telemetry"


def test_eviction_counter_under_capacity_pressure():
    engine.configure_lane_cache(2)
    fresh_planner().plan()      # far more unique lanes than 2 entries
    info = engine.lane_cache_info()
    assert info["evictions"] > 0
    assert info["size"] <= 2


def test_sticky_replans_do_zero_fleet_resolves_when_warm():
    """The acceptance property: a sticky refresh-replan re-derives the
    whole plan through the simulator, and with a warm lane cache that
    costs dict lookups — the miss counter does not move."""
    planner = fresh_planner()
    controller = OffloadController(planner, policy="sticky")
    trace = occupancy_trace(make_scenario("drain-refill", seed=0))
    controller.observe(trace[0])            # first plan warms the lanes
    warm = engine.lane_cache_info()
    for b in trace[1:]:
        controller.observe(b)
    assert controller.replans >= 1, "drain-refill must trigger replans"
    for b in (1, 4, 8):                     # forced full refresh replans
        controller.replan(b, refresh=True)
    info = engine.lane_cache_info()
    assert info["misses"] == warm["misses"], \
        "sticky replan did fleet resolves against a warm cache"
    assert info["hits"] > warm["hits"]


def test_sticky_cold_lane_cache_triggers_refresh_replan():
    """A lane-cache miss between steps (someone resolved fresh lanes —
    the memo went cold) makes the sticky policy re-plan through the
    planner on the next observation."""
    planner = fresh_planner()
    controller = OffloadController(planner, policy="sticky")
    controller.observe(2)
    controller.observe(2)
    assert controller.replans == 0
    # an unrelated fresh resolve bumps the global miss counter
    PimSimulator().gemv(48, 320, "W8A8")
    assert engine.lane_cache_info()["misses"] > 0
    controller.observe(2)
    assert controller.replans == 1
    # the refresh started a new epoch rebased on the current miss
    # count, so a stable cache does not re-trigger
    controller.observe(2)
    assert controller.replans == 1
