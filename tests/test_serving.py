"""Serving engine + PIM offload planner tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.core.pimsim import PimSimulator
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import OffloadPlanner, decode_gemv_sites


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_completes(small_lm):
    cfg, params = small_lm
    eng = ServingEngine(cfg, params, slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=5 + i),
                    max_new=4 + i % 3) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 4 for r in reqs)
    assert stats["prefills"] == 7
    assert stats["tokens"] > 0


def test_batched_decode_matches_single(small_lm):
    """Ragged batched decode == one-by-one decode (slot isolation)."""
    cfg, params = small_lm
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab, size=6)
    p2 = rng.integers(0, cfg.vocab, size=9)

    def greedy(prompt, n=3):
        cache = M.init_cache(cfg, 1, 64, jnp.float32)
        logits, cache = M.prefill(cfg, params,
                                  {"tokens": jnp.asarray(prompt)[None]},
                                  cache)
        toks = [int(jnp.argmax(logits[0]))]
        pos = len(prompt)
        for _ in range(n - 1):
            logits, cache = M.decode_step(
                cfg, params, cache,
                jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray(pos, jnp.int32))
            toks.append(int(jnp.argmax(logits[0])))
            pos += 1
        return toks

    want1, want2 = greedy(p1), greedy(p2)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    r1 = Request(rid=1, prompt=p1, max_new=3)
    r2 = Request(rid=2, prompt=p2, max_new=3)
    eng.submit(r1)
    eng.submit(r2)
    eng.run(max_steps=50)
    assert r1.out == want1, (r1.out, want1)
    assert r2.out == want2, (r2.out, want2)


def test_zero_request_summary_is_neutral(small_lm):
    """A run that completes nothing (no submissions, or a step budget of
    zero) summarizes to neutral values — no raise, no 0/0: completed 0,
    in_flight counts the queue, tokens_per_step 0.0."""
    cfg, params = small_lm
    eng = ServingEngine(cfg, params, slots=2, max_seq=32)
    assert eng.step() is False         # idle tick: admits nothing, no act
    out = eng.run(max_steps=3)
    assert out["steps"] == 0 and out["tokens"] == 0
    assert out["prefills"] == 0 and out["completed"] == 0
    assert out["in_flight"] == 0 and out["tokens_per_step"] == 0.0
    assert out["batch_occupancy"] == {}
    # queued-but-never-stepped requests count as in flight
    eng2 = ServingEngine(cfg, params, slots=2, max_seq=32)
    eng2.submit(Request(rid=0, prompt=np.zeros(4, np.int32), max_new=2))
    out2 = eng2.summary()
    assert out2["in_flight"] == 1 and out2["completed"] == 0
    assert out2["tokens_per_step"] == 0.0


def test_engine_records_admit_and_completion_ticks(small_lm):
    """Tick accounting: idle ticks advance the clock, admission and
    completion ticks land per request — the record the disaggregated
    cell pair (serving/cells.py) is diffed against."""
    cfg, params = small_lm
    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    eng.step()                                    # idle tick 0
    eng.submit(Request(rid=7, prompt=np.arange(3, dtype=np.int32),
                       max_new=3))
    while any(eng.active) or eng.waiting:
        eng.step()
    assert eng.admit_ticks == {7: 1}
    assert eng.completions == {7: 2}              # max(1, 3-1) decode steps
    assert eng.ticks == 3


def test_offload_sites_cover_arch_families():
    dense = decode_gemv_sites(ARCHS["qwen2-72b"])
    names = {s.name for s in dense}
    assert {"attn.wq", "attn.wo", "mlp.wo", "lm_head"} <= names
    moe = decode_gemv_sites(ARCHS["dbrx-132b"])
    assert any(s.name.startswith("moe.") for s in moe)
    ssm = decode_gemv_sites(ARCHS["mamba2-130m"])
    assert {"ssm.in_proj", "ssm.out_proj"} <= {s.name for s in ssm}
    assert not any(s.name.startswith("attn") for s in ssm)


def test_offload_planner_small_batch_wins():
    """PIM offload accelerates batch-1 decode; large batch favors host."""
    sim = PimSimulator()
    planner = OffloadPlanner(ARCHS["granite-8b"], sim)
    r1 = planner.decode_speedup(batch=1)
    r64 = planner.decode_speedup(batch=64)
    assert r1["speedup"] > 3.0, r1
    assert r1["offloaded"], "nothing offloaded at batch 1"
    assert r64["speedup"] <= r1["speedup"]


def test_occupancy_weighted_speedup_empty_histogram():
    """No decode steps observed -> neutral speedup 1.0 over 0 steps (the
    old 0/1e-9 guard collapsed to 0.0, reading as 'PIM infinitely bad')."""
    planner = OffloadPlanner(ARCHS["mamba2-130m"])
    tel = planner.occupancy_weighted_speedup({})
    assert tel == dict(steps=0, host_ns=0.0, mixed_ns=0.0, speedup=1.0,
                       per_batch_speedup={})


def test_offload_reshape_regime_for_moe():
    """granite-moe per-expert d_ff=512 < 2048 -> reshape engaged."""
    planner = OffloadPlanner(ARCHS["granite-moe-3b-a800m"])
    plan = planner.plan()
    small = [d for d in plan if d.site.h < 2048]
    assert small and all(d.reshape for d in small)
