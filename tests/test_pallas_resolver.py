"""Differential suite for the Pallas lane-resolver backend.

The Pallas kernel (``kernels/lane_scan.py``) is the fourth lane backend
(single-device scan, threaded multi-device, ``shard_map`` mesh, Pallas).
Its contract is bit-identity with the scan resolver — and therefore with
``RefEngine`` — on every lane, plus clean selection semantics:
``configure_lane_backend``/``REPRO_LANE_BACKEND`` pick it, capability
probing falls back to scan instead of breaking resolution, and the
engine's dedupe/LRU/slab plumbing is backend-oblivious.  The scan-unroll
satellite lives here too: unroll={1,2,4,8} must be bit-identical.
"""
import numpy as np
import pytest

from repro.core import engine
from repro.core.engine_ref import RefEngine
from repro.core.timing import DEFAULT_SYSTEM

from test_conformance import assert_fleet_matches_ref, fleet_from_seed
from test_engine import build_valid_stream, random_op_tuples

from repro.kernels import lane_scan

PALLAS_OK = lane_scan.pallas_lane_supported()
needs_pallas = pytest.mark.skipif(
    not PALLAS_OK, reason="pallas lane resolver unsupported here")


def _lanes(seed: int, n: int = 6, max_ops: int = 40):
    rng = np.random.default_rng(seed)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    return [(cyc, build_valid_stream(random_op_tuples(rng,
                                                      max_ops=max_ops)))
            for _ in range(n)]


# ---------------------------------------------------------------------
# Bit-identity: pallas vs scan vs RefEngine
# ---------------------------------------------------------------------

@needs_pallas
@pytest.mark.parametrize("seed", range(4))
def test_pallas_bit_identical_to_scan(seed):
    lanes = _lanes(seed)
    engine.lane_cache_reset()
    ref = engine.resolve_lanes(lanes)
    engine.lane_cache_reset()
    with engine.lane_backend_scope("pallas"):
        got = engine.resolve_lanes(lanes)
    for (iss_a, tot_a), (iss_b, tot_b) in zip(ref, got):
        assert tot_a == tot_b
        np.testing.assert_array_equal(iss_a, iss_b)


@needs_pallas
def test_pallas_multi_spec_fleet_matches_ref():
    """The conformance corpus (mixed bank counts, fuzzed timings)
    straight through the Pallas backend against the Python oracle."""
    with engine.lane_backend_scope("pallas"):
        assert_fleet_matches_ref(fleet_from_seed(17))


@needs_pallas
def test_pallas_resolver_direct_matches_ref():
    """The raw ``make_lane_resolver`` output (no engine plumbing) against
    ``RefEngine`` on a hand-rolled fleet batch."""
    cyc = DEFAULT_SYSTEM.derive_cycles()
    rng = np.random.default_rng(3)
    streams = [build_valid_stream(random_op_tuples(rng, max_ops=24))
               for _ in range(3)]
    n = max(s.shape[0] for s in streams)
    batch = np.zeros((len(streams), n, 4), dtype=np.int32)
    for i, s in enumerate(streams):
        batch[i, : s.shape[0]] = s
    cycs = engine.stack_cycles([cyc] * len(streams))
    issue, total = lane_scan.make_lane_resolver(cyc.num_banks)(cycs, batch)
    ref = RefEngine(cyc, validate=False)
    for i, s in enumerate(streams):
        iss_ref, tot_ref = ref.run(s)
        np.testing.assert_array_equal(
            iss_ref, np.asarray(issue)[i, : s.shape[0]].astype(np.int64))
        assert tot_ref == int(total[i])


# ---------------------------------------------------------------------
# Selection semantics: config > env > default, with capability fallback
# ---------------------------------------------------------------------

def test_backend_config_precedence(monkeypatch):
    # Env-neutral: the pallas CI job exports REPRO_LANE_BACKEND.
    monkeypatch.delenv("REPRO_LANE_BACKEND", raising=False)
    assert engine.lane_backend() == "scan"      # default
    monkeypatch.setenv("REPRO_LANE_BACKEND", "pallas")
    assert engine.lane_backend() == "pallas"
    engine.configure_lane_backend("scan")       # config wins over env
    assert engine.lane_backend() == "scan"
    engine.configure_lane_backend(None)
    assert engine.lane_backend() == "pallas"


def test_backend_invalid_names_rejected(monkeypatch):
    with pytest.raises(ValueError):
        engine.configure_lane_backend("cuda")
    monkeypatch.setenv("REPRO_LANE_BACKEND", "nonsense")
    assert engine.lane_backend() == "scan"   # invalid env value ignored


def test_backend_scope_restores_on_error(monkeypatch):
    monkeypatch.delenv("REPRO_LANE_BACKEND", raising=False)
    with pytest.raises(RuntimeError):
        with engine.lane_backend_scope("pallas"):
            raise RuntimeError("boom")
    assert engine.lane_backend() == "scan"


def test_pallas_falls_back_to_scan_when_unsupported(monkeypatch):
    """An unsupported probe must degrade pallas/auto to the scan path —
    resolution keeps working, nothing raises."""
    monkeypatch.setattr(lane_scan, "pallas_lane_supported", lambda: False)
    with engine.lane_backend_scope("pallas"):
        assert engine.resolved_lane_backend() == "scan"
        lanes = _lanes(0, n=2, max_ops=12)
        engine.lane_cache_reset()
        res = engine.resolve_lanes(lanes)
    assert len(res) == 2


@needs_pallas
def test_auto_backend_selects_pallas_when_supported():
    with engine.lane_backend_scope("auto"):
        assert engine.resolved_lane_backend() == "pallas"


@needs_pallas
def test_pallas_backend_shares_lane_cache():
    """Dedupe/LRU is backend-oblivious: a lane resolved under scan is a
    cache hit under pallas (same key space, bit-identical values)."""
    lanes = _lanes(11, n=3)
    keys = [("pallas-share", i) for i in range(3)]
    engine.lane_cache_reset()
    engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    before = engine.lane_cache_info()
    with engine.lane_backend_scope("pallas"):
        engine.resolve_lanes(lanes, keys=keys, need_issue=False)
    after = engine.lane_cache_info()
    assert after["misses"] == before["misses"]
    assert after["hits"] == before["hits"] + 3


# ---------------------------------------------------------------------
# Scan-unroll satellite: env-configurable, bit-identical across values
# ---------------------------------------------------------------------

def test_scan_unroll_default_and_env(monkeypatch):
    assert engine.scan_unroll() == 4
    monkeypatch.setenv("REPRO_SCAN_UNROLL", "2")
    assert engine.scan_unroll() == 2
    assert engine.configure_scan_unroll(8) == 8   # config wins over env
    engine.configure_scan_unroll(None)
    assert engine.scan_unroll() == 2
    with pytest.raises(ValueError):
        engine.configure_scan_unroll(0)


@pytest.mark.parametrize("unroll", [1, 2, 4, 8])
def test_scan_unroll_bit_identical(unroll):
    lanes = _lanes(23, n=4)
    engine.lane_cache_reset()
    baseline = engine.resolve_lanes(lanes)
    engine.configure_scan_unroll(unroll)
    engine.lane_cache_reset()
    got = engine.resolve_lanes(lanes)
    for (iss_a, tot_a), (iss_b, tot_b) in zip(baseline, got):
        assert tot_a == tot_b
        np.testing.assert_array_equal(iss_a, iss_b)


@needs_pallas
@pytest.mark.parametrize("unroll", [1, 8])
def test_pallas_unroll_bit_identical(unroll):
    """The kernel body honours the unroll knob too — same lanes out."""
    lanes = _lanes(29, n=3)
    engine.lane_cache_reset()
    baseline = engine.resolve_lanes(lanes)
    engine.configure_scan_unroll(unroll)
    with engine.lane_backend_scope("pallas"):
        engine.lane_cache_reset()
        got = engine.resolve_lanes(lanes)
    for (iss_a, tot_a), (iss_b, tot_b) in zip(baseline, got):
        assert tot_a == tot_b
        np.testing.assert_array_equal(iss_a, iss_b)


def test_unroll_keys_separate_compile_cache_entries():
    """Distinct unroll values are distinct resolver cache keys — no
    silent reuse of a mismatched compilation."""
    lanes = _lanes(31, n=2, max_ops=16)
    nb = DEFAULT_SYSTEM.derive_cycles().num_banks
    # Pin the scan backend: under REPRO_LANE_BACKEND=pallas the resolves
    # would route through the pallas kernel and never key _RESOLVERS.
    with engine.lane_backend_scope("scan"):
        engine.configure_scan_unroll(1)
        engine.lane_cache_reset()
        engine.resolve_lanes(lanes)
        engine.configure_scan_unroll(2)
        engine.lane_cache_reset()
        engine.resolve_lanes(lanes)
    assert {(nb, 1), (nb, 2)} <= set(engine._RESOLVERS)
