"""End-to-end system behaviour tests.

The integration points: simulator -> offload planner -> serving engine;
trainer -> checkpoint -> elastic restart; paper-number regression gates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, shapes_for, smoke_config
from repro.core.pimsim import PimSimulator
from repro.pimkernel.tileconfig import PimDType


@pytest.fixture(scope="module")
def sim():
    return PimSimulator()


class TestPaperClaims:
    """Regression gates on the paper's published numbers (±10%)."""

    def test_large_tile_speedups(self, sim):
        for dt in (PimDType.W8A8, PimDType.W4A4, PimDType.FP_W8A8):
            s = sim.speedup(4096, 4096, dt)
            assert 5.5 <= s <= 6.8, (dt, s)

    def test_small_tile_speedups(self, sim):
        for dt in (PimDType.W8A16, PimDType.FP_W8A16, PimDType.W4A16):
            s = sim.speedup(4096, 4096, dt)
            assert 5.2 <= s <= 6.3, (dt, s)

    def test_fenced_w4a16_drop(self, sim):
        s = sim.speedup(4096, 4096, PimDType.W4A16, fence=True)
        assert 3.7 <= s <= 4.8, s      # paper: 4.1x

    def test_fenced_others_hold_5x(self, sim):
        for dt in (PimDType.W8A8, PimDType.W4A4, PimDType.W8A16):
            assert sim.speedup(4096, 4096, dt, fence=True) >= 5.0

    def test_speedup_monotone_in_dims(self, sim):
        for axis in ("activation", "output"):
            sw = sim.sweep([1024, 2048, 4096, 8192],
                           [PimDType.W8A8], axis=axis)["W8A8"]
            assert all(b >= a - 0.02 for a, b in zip(sw, sw[1:])), (axis,
                                                                    sw)

    def test_reshape_gain_band(self, sim):
        g = sim.gemv(1024, 4096, PimDType.W8A8).ns / \
            sim.gemv(1024, 4096, PimDType.W8A8, reshape=True).ns
        assert 1.4 <= g <= 1.9        # paper: up to 1.65x

    def test_fences_never_help(self, sim):
        for dt in PimDType:
            assert sim.gemv(2048, 2048, dt, fence=True).ns >= \
                sim.gemv(2048, 2048, dt).ns


class TestEnergyModel:
    def test_pim_saves_io_energy(self, sim):
        p = sim.gemv(4096, 4096, PimDType.W8A8)
        b = sim.baseline(4096, 4096, PimDType.W8A8)
        assert p.energy["pj_per_op"] < b.energy["pj_per_op"]

    def test_energy_positive_components(self, sim):
        e = sim.gemv(1024, 1024, PimDType.W8A8).energy["channels"][0]
        for k in ("act_pj", "io_pj", "mac_pj", "background_pj"):
            assert e[k] >= 0
        assert e["total_pj"] > 0


class TestShapeMatrix:
    def test_40_cells_defined(self):
        cells = [(a, s) for a, c in ARCHS.items() for s in shapes_for(c)]
        # 10 archs x 3 universal shapes + 3 sub-quadratic long_500k
        assert len(cells) == 33
        long_archs = {a for a, s in cells if s == "long_500k"}
        assert long_archs == {"mamba2-130m", "hymba-1.5b", "gemma3-4b"}
        skipped = [(a, "long_500k") for a in ARCHS
                   if a not in long_archs]
        assert len(cells) + len(skipped) == 40

    def test_smoke_configs_small(self):
        for name, cfg in ARCHS.items():
            sc = smoke_config(cfg)
            assert sc.param_count() < 5e6, (name, sc.param_count())
            assert sc.family == cfg.family


def test_offload_end_to_end_consistency(sim):
    """Planner's per-site times equal direct simulator queries."""
    from repro.serving.offload import OffloadPlanner
    planner = OffloadPlanner(ARCHS["granite-8b"], sim)
    plan = planner.plan(fence=True)
    site = next(d for d in plan if d.site.name == "mlp.wo")
    direct = sim.gemv(site.site.h, site.site.w, PimDType.W8A8,
                      fence=True, reshape=site.reshape)
    assert site.pim_ns == direct.ns
