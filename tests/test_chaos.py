"""Chaos battery: fault injection, the degradation ladder, load shedding.

Four layers, mirroring the harness's own structure:

1. *Primitives* (``core/faults.py``) — clocks (no test here ever
   real-sleeps), deterministic injector schedules, breaker trip exactly
   at the K-th consecutive failure, retry backoff sequences.
2. *Seams* — each injection site exercised in isolation: ladder rungs
   (transient absorbed / persistent degraded / terminal propagates),
   lane-cache poison caught on the hit path and by the scrub, planner
   timeouts degrading to host-only offload, handoff pressure stalling
   instead of crashing, SLO-aware admission shedding (order spec +
   cells-vs-simulator parity).
3. *Choreography* (``serving/chaos.py``) — deterministic timelines and
   the byte-parity contract: a faulted serve run (breaker trips, ladder
   steps down) emits a trace byte-identical to a healthy run driven by
   the fault-free shadow timeline, because every rung is bit-identical
   and non-scheduling faults never move work between ticks.
4. *Golden* — one seeded chaos incident (disagg cells, shedding,
   handoff pressure, cache storms) pinned byte-exactly in
   ``tests/golden/chaos_trace.json``; regenerate deliberately with
   ``python tests/test_chaos.py``.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.core import engine, faults
from repro.core.timing import DEFAULT_SYSTEM
from repro.kernels import lane_scan
from repro.models import model as M
from repro.serving import cells
from repro.serving.chaos import (NEUTRAL_ACTIONS, ChaosAction,
                                 baseline_timeline, make_chaos_timeline,
                                 run_chaos_scenario)
from repro.serving.engine import Request
from repro.serving.offload import OffloadPlanner
from repro.serving.policy import OffloadController
from repro.serving.scenarios import (SLO_LATENCY, SLO_THROUGHPUT,
                                     DisaggConfig, ScenarioDrainError,
                                     ScenarioSpec, _shed_pick, assign_slo,
                                     make_scenario, run_scenario,
                                     simulate_batches, simulate_disagg)
from repro.training.fault import HeartbeatMonitor

from test_engine import build_valid_stream, random_op_tuples

GOLDEN = pathlib.Path(__file__).parent / "golden" / "chaos_trace.json"
GOLDEN_SCENARIO = dict(name="chaos", seed=5, slots=4, quick=True)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _planner():
    # Fresh per run: the planner's internal plan cache would otherwise
    # hide re-resolves from the chaos drills.
    return OffloadPlanner(ARCHS["mamba2-130m"])


def _lanes(seed: int, n: int = 5):
    rng = np.random.default_rng(seed)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    return [(cyc, build_valid_stream(random_op_tuples(rng, max_ops=30)))
            for _ in range(n)]


def _keys(n: int = 5):
    return [("chaos", i) for i in range(n)]


@pytest.fixture(autouse=True)
def _fresh_lane_cache():
    engine.lane_cache_reset()
    yield
    engine.lane_cache_reset()


# ---------------------------------------------------------------------
# Clocks: the one shared virtual-clock helper
# ---------------------------------------------------------------------

def test_virtual_clock_protocol():
    clk = faults.VirtualClock(5.0)
    assert clk() == clk.now() == 5.0
    clk.advance(2.5)
    assert clk() == 7.5
    clk.sleep(1.5)
    assert clk.sleeps == [1.5] and clk() == 9.0


def test_heartbeat_monitor_on_virtual_clock_never_sleeps():
    clk = faults.VirtualClock()
    mon = HeartbeatMonitor(3, timeout_s=10.0, clock=clk)
    clk.advance(6.0)
    mon.beat(0)
    mon.beat(2)
    clk.advance(5.0)                     # host 1 silent for 11 ticks
    assert mon.sweep() == [1]
    assert mon.alive_hosts == [0, 2]
    clk.advance(6.0)                     # now 0 and 2 are silent too
    assert sorted(mon.sweep()) == [0, 2]
    mon.beat(1)                          # a beat revives
    assert mon.alive_hosts == [1]
    assert clk.sleeps == []              # liveness without one real sleep


def test_retry_backoff_sequence_on_virtual_clock():
    clk = faults.VirtualClock()
    inj = faults.FaultInjector()
    inj.arm("planner", count=3)
    with faults.fault_scope(inj):
        out = faults.retry_call(lambda: "ok", "planner", retries=3,
                                backoff=0.05, clock=clk)
    assert out == "ok"
    assert clk.sleeps == [0.05, 0.1, 0.2]       # b * 2**attempt

    inj2 = faults.FaultInjector()
    inj2.arm("planner", count=-1)
    clk2 = faults.VirtualClock()
    with faults.fault_scope(inj2):
        with pytest.raises(faults.InjectedFault):
            faults.retry_call(lambda: "ok", "planner", retries=2,
                              backoff=0.01, clock=clk2)
    assert clk2.sleeps == [0.01, 0.02]          # exhausted, then raised


# ---------------------------------------------------------------------
# Injector: schedules are exact call indices, never clocks or RNG
# ---------------------------------------------------------------------

def test_injector_schedule_fires_exact_calls():
    inj = faults.FaultInjector()
    inj.arm("x", count=2)                       # calls 0, 1
    inj.arm("x", count=1, start=4)              # call 4
    fired = [inj.should_fail("x") is not None for _ in range(6)]
    assert fired == [True, True, False, False, True, False]
    assert inj.injected == 3
    assert inj.should_fail("y") is None


def test_maybe_fail_seam():
    faults.maybe_fail("backend.scan")           # no injector: no-op
    with faults.fault_scope(faults.FaultInjector()) as inj:
        faults.maybe_fail("backend.scan")       # nothing armed: no-op
        inj.arm("backend.scan", count=1, message="boom")
        with pytest.raises(faults.InjectedFault, match="boom"):
            faults.maybe_fail("backend.scan")
    assert faults.injector() is None            # scope restored
    ev = faults.events()[-1]
    assert ev["site"] == "backend.scan" and ev["kind"] == "inject"


def test_event_tick_tagging():
    faults.reset_events()
    faults.set_tick(7)
    try:
        faults.record_event("handoff", "stall", "pressure")
    finally:
        faults.set_tick(None)
    faults.record_event("handoff", "stall", "untagged")
    tagged, untagged = faults.events()
    assert tagged["tick"] == 7
    assert "tick" not in untagged


# ---------------------------------------------------------------------
# Circuit breaker: trip exactly at K consecutive failures
# ---------------------------------------------------------------------

def test_breaker_trips_exactly_at_threshold():
    br = faults.CircuitBreaker(3)
    assert br.record_failure("x") is False
    assert br.record_failure("x") is False
    assert not br.tripped("x")
    assert br.record_failure("x") is True       # the K-th, exactly
    assert br.tripped("x")
    assert br.record_failure("x") is False      # already open
    br.record_success("x")
    assert not br.tripped("x") and br.failures["x"] == 0


def test_breaker_success_resets_streak():
    br = faults.CircuitBreaker(3)
    br.record_failure("x")
    br.record_failure("x")
    br.record_success("x")                      # streak broken
    br.record_failure("x")
    assert br.record_failure("x") is False
    assert br.record_failure("x") is True       # 3 consecutive again


def test_breaker_threshold_boundaries():
    b1 = faults.CircuitBreaker(1)
    assert b1.record_failure("y") is True       # K=1: first failure trips
    with pytest.raises(ValueError):
        faults.CircuitBreaker(0)


# ---------------------------------------------------------------------
# The degradation ladder on resolve_lanes
# ---------------------------------------------------------------------

def _scan_reference(lanes):
    engine.lane_cache_clear()
    ref = engine.resolve_lanes(lanes, need_issue=False)
    return [t for _, t in ref]


def test_ladder_terminal_rung_is_always_scan():
    rungs = engine.ladder_rungs()
    assert rungs and rungs[-1] == "scan"
    assert len(set(rungs)) == len(rungs)


def test_ladder_transient_fault_absorbed_byte_exact():
    lanes = _lanes(0)
    ref = _scan_reference(lanes)
    inj = faults.FaultInjector()
    inj.arm("backend." + engine.ladder_rungs()[0], count=1)
    engine.lane_cache_clear()
    clk = faults.VirtualClock()
    with faults.fault_scope(inj), faults.retry_scope(clock=clk):
        got = engine.resolve_lanes(lanes, need_issue=False)
    assert [t for _, t in got] == ref
    kinds = [e["kind"] for e in faults.events()]
    assert "retry" in kinds and "degrade" not in kinds
    assert clk.sleeps                         # backed off, virtually
    assert not faults.backend_breaker().info()["open"]


@pytest.mark.skipif(not lane_scan.pallas_lane_supported(),
                    reason="pallas lane kernel unsupported here")
def test_ladder_persistent_fault_degrades_byte_exact():
    lanes = _lanes(1)
    ref = _scan_reference(lanes)
    with engine.lane_backend_scope("pallas"):
        assert engine.ladder_rungs()[0] == "pallas"
        inj = faults.FaultInjector()
        inj.arm("backend.pallas", count=-1)
        engine.lane_cache_clear()
        with faults.fault_scope(inj), \
                faults.retry_scope(clock=faults.VirtualClock()):
            got = engine.resolve_lanes(lanes, need_issue=False)
    assert [t for _, t in got] == ref         # degraded bytes == healthy
    kinds = [e["kind"] for e in faults.events()]
    assert "degrade" in kinds


@pytest.mark.skipif(not lane_scan.pallas_lane_supported(),
                    reason="pallas lane kernel unsupported here")
def test_ladder_breaker_trips_then_skips_rung():
    lanes = _lanes(2)
    ref = _scan_reference(lanes)
    with engine.lane_backend_scope("pallas"):
        faults.configure_breaker(2)
        inj = faults.FaultInjector()
        inj.arm("backend.pallas", count=-1)
        with faults.fault_scope(inj), \
                faults.retry_scope(retries=0, clock=faults.VirtualClock()):
            for _ in range(3):                # fail, trip, then skip
                engine.lane_cache_clear()
                got = engine.resolve_lanes(lanes, need_issue=False)
                assert [t for _, t in got] == ref
    kinds = [e["kind"] for e in faults.events()]
    assert "trip" in kinds and "skip" in kinds
    assert faults.backend_breaker().tripped("backend.pallas")


def test_terminal_rung_failure_propagates():
    engine.configure_lane_devices(1)          # ladder is exactly [scan]
    assert engine.ladder_rungs() == ["scan"]
    inj = faults.FaultInjector()
    inj.arm("backend.scan", count=-1)
    engine.lane_cache_clear()
    with faults.fault_scope(inj), \
            faults.retry_scope(clock=faults.VirtualClock()):
        with pytest.raises(faults.InjectedFault):
            engine.resolve_lanes(_lanes(3, n=2), need_issue=False)


# ---------------------------------------------------------------------
# Lane-cache poison: detected on the hit path and by the scrub
# ---------------------------------------------------------------------

def test_poison_detected_on_hit_path_falls_back_cold():
    lanes = _lanes(4)
    ref = engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    assert engine.lane_cache_poison(2, seed=0) == 2
    faults.reset_events()
    before = engine.lane_cache_info()["misses"]
    got = engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    assert [t for _, t in got] == [t for _, t in ref]   # never stale
    detects = [e for e in faults.events() if e["kind"] == "detect"]
    assert len(detects) == 2
    assert engine.lane_cache_info()["misses"] == before + 2


def test_poison_scrub_detects_unread_entries():
    engine.resolve_lanes(_lanes(5), keys=_keys(), need_issue=False)
    assert engine.lane_cache_poison(3, seed=1) == 3
    faults.reset_events()
    assert engine.lane_cache_verify() == 3
    detects = [e for e in faults.events() if e["kind"] == "detect"]
    assert len(detects) == 3
    assert engine.lane_cache_verify() == 0    # sweep is idempotent


def test_poison_empty_cache_is_noop():
    engine.lane_cache_clear()
    assert engine.lane_cache_poison(4) == 0
    assert engine.lane_cache_verify() == 0


# ---------------------------------------------------------------------
# Planner faults: absorbed by retry, or degraded to host-only offload
# ---------------------------------------------------------------------

def test_planner_transient_fault_absorbed():
    ctrl = OffloadController(_planner(), policy="sticky")
    inj = faults.FaultInjector()
    inj.arm("planner", count=1)
    with faults.fault_scope(inj), \
            faults.retry_scope(clock=faults.VirtualClock()):
        ctrl.observe(2)
    assert not ctrl.planner_degraded
    assert "planner_degraded" not in ctrl.report()
    kinds = [e["kind"] for e in faults.events()]
    assert "retry" in kinds and "degrade" not in kinds


def test_planner_persistent_fault_degrades_host_only():
    ctrl = OffloadController(_planner(), policy="sticky")
    inj = faults.FaultInjector()
    inj.arm("planner", count=-1)
    with faults.fault_scope(inj), \
            faults.retry_scope(clock=faults.VirtualClock()):
        ctrl.observe(2)
        ctrl.observe(3)
    assert ctrl.planner_degraded
    assert ctrl.decisions == []               # host-only offload set
    rep = ctrl.report()
    assert rep["planner_degraded"] is True
    assert "degrade" in [e["kind"] for e in faults.events()]


# ---------------------------------------------------------------------
# Handoff pressure: stall, never the overrun crash
# ---------------------------------------------------------------------

def test_handoff_pressure_stalls_gracefully():
    q = cells.KVHandoffQueue(bound=2)
    inj = faults.FaultInjector()
    inj.arm("handoff", count=2)
    with faults.fault_scope(inj):
        assert q.room() is False
        assert q.room() is False
        assert q.room() is True               # pressure passed
    kinds = [e["kind"] for e in faults.events()]
    assert kinds.count("stall") == 2 and kinds.count("inject") == 2
    assert q.room() is True                   # no injector: bound rules


# ---------------------------------------------------------------------
# SLO-aware admission shedding
# ---------------------------------------------------------------------

def test_shed_pick_order_spec():
    t, age = 10, 8
    waiting = [(0, 0, 0, SLO_THROUGHPUT),     # starved (protected)
               (5, 1, 1, SLO_LATENCY),
               (7, 2, 2, SLO_LATENCY),
               (6, 3, 3, SLO_THROUGHPUT),     # fresh
               (8, 4, 4, SLO_THROUGHPUT)]     # fresh, youngest
    assert waiting[_shed_pick(waiting, t, age)][2] == 4
    del waiting[4]
    assert waiting[_shed_pick(waiting, t, age)][2] == 3
    del waiting[3]
    assert waiting[_shed_pick(waiting, t, age)][2] == 2   # youngest latency
    del waiting[2]
    assert waiting[_shed_pick(waiting, t, age)][2] == 1
    del waiting[1]
    assert waiting[_shed_pick(waiting, t, age)][2] == 0   # only then starved


def test_admission_queue_shed_matches_sim_spec():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 10))
        q = cells.AdmissionQueue(starvation_age=4)
        waiting = []
        for i in range(n):
            enq = int(rng.integers(0, 10))
            slo = SLO_LATENCY if rng.random() < 0.5 else SLO_THROUGHPUT
            q.push(Request(rid=i, prompt=np.arange(4), max_new=3), slo, enq)
            waiting.append((enq, i, i, slo))
        t = 12
        while waiting:
            want = waiting.pop(_shed_pick(waiting, t, 4))[2]
            got, _, _ = q.shed(t)
            assert got.rid == want


def test_disagg_shedding_cells_vs_sim_parity(small_lm):
    cfg, params = small_lm
    spec = make_scenario("chaos", seed=3, slots=4, quick=True)
    dcfg = DisaggConfig(prefill_budget=2, handoff_bound=3,
                        starvation_age=4, admission_capacity=5)
    slo = assign_slo(spec, 0.5)
    sim = simulate_disagg(spec, dcfg, slo)
    assert sim["shed_ticks"], "scenario must actually shed"
    trace = run_scenario(spec, cfg, params, _planner(), policy="sticky",
                         disagg=dcfg, slo=slo)
    d = trace["disagg"]
    assert d["shed"] == {str(r): t
                         for r, t in sorted(sim["shed_ticks"].items())}
    assert d["requests"]["completion_ticks"] == \
        {str(r): t for r, t in sorted(sim["completion_ticks"].items())}
    shed, done = set(sim["shed_ticks"]), set(sim["completion_ticks"])
    assert not (shed & done)                  # shed XOR completed,
    assert shed | done == {a.rid for a in spec.arrivals}   # exhaustively
    # shed events carry their tick in the structured log
    evs = [e for e in faults.events()
           if e["site"] == "admission" and e["kind"] == "shed"]
    assert len(evs) == len(shed) and all("rid=" in e["detail"] for e in evs)


def test_unbounded_admission_never_sheds_and_omits_keys(small_lm):
    cfg, params = small_lm
    spec = make_scenario("chaos", seed=3, slots=4, quick=True)
    sim = simulate_disagg(spec, DisaggConfig.mirror())
    assert sim["shed_ticks"] == {}
    rec = DisaggConfig.mirror().to_record()
    assert "admission_capacity" not in rec    # golden-trace byte stability
    trace = run_scenario(spec, cfg, params, _planner(), disagg=True)
    assert "shed" not in trace["disagg"]
    assert "shed" not in trace["disagg"]["prefill"]


def test_boundary_configs_still_drain():
    spec = make_scenario("chaos", seed=1, slots=2, quick=True)
    rids = {a.rid for a in spec.arrivals}
    for dcfg in (DisaggConfig(prefill_budget=1),
                 DisaggConfig(handoff_bound=1),
                 DisaggConfig(prefill_budget=1, handoff_bound=1,
                              admission_capacity=1, starvation_age=2)):
        sim = simulate_disagg(spec, dcfg, assign_slo(spec, 0.5))
        if dcfg.handoff_bound is not None:
            assert sim["max_handoff_depth"] <= dcfg.handoff_bound
        if dcfg.admission_capacity is not None:
            assert sim["shed_ticks"]          # capacity 1 must shed here
        done, shed = set(sim["completion_ticks"]), set(sim["shed_ticks"])
        assert done | shed == rids and not (done & shed)


def test_disagg_config_validation_boundaries():
    DisaggConfig(prefill_budget=1, handoff_bound=1, admission_capacity=1)
    for bad in (dict(prefill_budget=0), dict(handoff_bound=0),
                dict(admission_capacity=0), dict(starvation_age=-1)):
        with pytest.raises(ValueError):
            DisaggConfig(**bad)


# ---------------------------------------------------------------------
# Drain diagnostics: a wedged run is diagnosable from the exception
# ---------------------------------------------------------------------

def test_drain_error_carries_queue_diagnostics():
    spec = make_scenario("steady", seed=0, slots=1, quick=True)
    with pytest.raises(ScenarioDrainError) as ei:
        simulate_batches(spec, max_ticks=2)
    err = ei.value
    assert err.name == "steady" and err.tick == 2
    assert set(err.queues) == {"waiting", "pending"}
    msg = str(err)
    assert "queue depths" in msg and "oldest queued request age" in msg
    assert "last-tick batch" in msg

    with pytest.raises(ScenarioDrainError) as ei2:
        simulate_disagg(spec, max_ticks=2)
    assert set(ei2.value.queues) == {"waiting", "handoff", "pending"}


# ---------------------------------------------------------------------
# Chaos timelines
# ---------------------------------------------------------------------

def test_timeline_deterministic_sorted_and_complete():
    a = make_chaos_timeline(4, horizon=30, rungs=["pallas", "scan"])
    assert a == make_chaos_timeline(4, horizon=30, rungs=["pallas", "scan"])
    assert a == sorted(a, key=lambda x: (x.tick, x.action))
    acts = {x.action for x in a}
    assert {"planner", "backend.pallas", "lane_cache.poison",
            "lane_cache.scrub", "lane_cache.storm", "replan",
            "handoff"} <= acts
    assert any(x.action == "backend.pallas" and x.count == -1 for x in a)
    # every storm is paired with a replan at the same tick, storm first
    for x in a:
        if x.action == "replan":
            assert ChaosAction(x.tick, "lane_cache.storm", 0) in a


def test_single_rung_timeline_has_no_persistent_burst():
    c = make_chaos_timeline(4, horizon=30, rungs=["scan"])
    assert not any(x.count < 0 for x in c)


def test_baseline_timeline_is_the_neutral_shadow():
    tl = make_chaos_timeline(9, horizon=24, rungs=["pallas", "scan"])
    base = baseline_timeline(tl)
    assert base and all(x.action in NEUTRAL_ACTIONS for x in base)
    assert not any(x.action.startswith("backend.") for x in base)
    assert [x for x in tl if x.action in NEUTRAL_ACTIONS] == base


def test_chaos_action_record_roundtrip():
    act = ChaosAction(3, "backend.mesh", -1, "note")
    assert ChaosAction.from_record(json.loads(
        json.dumps(act.to_record()))) == act


# ---------------------------------------------------------------------
# End to end: the byte-parity contract under a full fault schedule
# ---------------------------------------------------------------------

def _strip_chaos(trace: dict) -> str:
    t = {k: v for k, v in trace.items() if k != "chaos"}
    return json.dumps(t, sort_keys=True)


@pytest.mark.skipif(not lane_scan.pallas_lane_supported(),
                    reason="pallas lane kernel unsupported here")
def test_chaos_run_byte_identical_to_healthy_baseline(small_lm):
    """The tentpole contract: a serve run whose fault schedule trips the
    breaker and steps the ladder down (pallas -> scan) completes the
    same requests with a trace byte-identical to a healthy run driven by
    the fault-free shadow timeline."""
    cfg, params = small_lm
    spec = make_scenario("chaos", seed=2, slots=4, quick=True)
    horizon = max(a.step for a in spec.arrivals) + 1
    tl = make_chaos_timeline(2, horizon=max(horizon, 8),
                             rungs=["pallas", "scan"], scheduling=False)

    engine.lane_cache_reset()
    with engine.lane_backend_scope("pallas"):
        faulted = run_chaos_scenario(cfg, params, _planner(),
                                     scenario=spec, timeline=tl)
    kinds = {e["kind"] for e in faulted["chaos"]["events"]}
    assert {"inject", "fault", "retry", "degrade",
            "trip", "skip", "detect"} <= kinds
    assert "backend.pallas" in faulted["chaos"]["breaker"]["open"]
    assert faulted["chaos"]["backoff_sleeps"]          # no real sleeps
    assert faulted["chaos"]["injected"] > 0

    faults.reset()
    engine.lane_cache_reset()
    baseline = run_chaos_scenario(cfg, params, _planner(), scenario=spec,
                                  timeline=baseline_timeline(tl))
    assert not baseline["chaos"]["injected"]
    assert _strip_chaos(faulted) == _strip_chaos(baseline)


def test_zero_request_chaos_run(small_lm):
    cfg, params = small_lm
    spec = ScenarioSpec(name="chaos", seed=0, slots=2, arrivals=())
    trace = run_chaos_scenario(cfg, params, _planner(), scenario=spec)
    assert trace["steps"] == 0 and trace["per_tick_batch"] == []
    assert trace["chaos"]["timeline"]          # armed, nothing to hit
    assert trace["controller"]["steps"] == 0


# ---------------------------------------------------------------------
# Golden chaos incident: pinned byte-exactly
# ---------------------------------------------------------------------

def _golden_chaos_trace(small_lm) -> dict:
    cfg, params = small_lm
    engine.configure_lane_devices(1)      # platform-independent ladder
    engine.lane_cache_reset()
    faults.reset()
    spec = make_scenario(**GOLDEN_SCENARIO)
    horizon = max(a.step for a in spec.arrivals) + 1
    tl = make_chaos_timeline(GOLDEN_SCENARIO["seed"],
                             horizon=max(horizon, 8), rungs=["scan"],
                             scheduling=True)
    dcfg = DisaggConfig(prefill_budget=2, handoff_bound=3,
                        starvation_age=4, admission_capacity=6)
    return run_chaos_scenario(
        cfg, params, _planner(), scenario=spec, timeline=tl,
        disagg=dcfg, slo=assign_slo(spec, 0.6))


def test_golden_chaos_trace_exact(small_lm):
    """One seeded incident — cache storms, forced replans, handoff
    pressure, admission shedding — through the disagg cells, its full
    trace INCLUDING the chaos record (timeline, event log, breaker
    state, backoff sleeps) diffed exactly against the committed fixture.
    Regenerate deliberately with ``python tests/test_chaos.py``."""
    fixture = json.loads(GOLDEN.read_text())
    current = json.loads(json.dumps(_golden_chaos_trace(small_lm)))
    assert set(current) == set(fixture)
    for key in fixture:
        assert current[key] == fixture[key], f"golden chaos drift at {key}"


def test_golden_chaos_trace_records_degradations():
    """The committed incident record is self-contained: sheds and stalls
    appear both in the structured event log and the disagg telemetry."""
    fixture = json.loads(GOLDEN.read_text())
    rec = fixture["chaos"]
    kinds = [e["kind"] for e in rec["events"]]
    assert "shed" in kinds and "stall" in kinds and "inject" in kinds
    assert fixture["disagg"]["shed"]
    shed_evs = [e for e in rec["events"] if e["kind"] == "shed"]
    assert len(shed_evs) == len(fixture["disagg"]["shed"])
    for ev in rec["events"]:
        assert "tick" in ev               # every event is tick-tagged
    # the embedded timeline round-trips through ChaosAction records
    acts = [ChaosAction.from_record(a) for a in rec["timeline"]]
    assert acts == sorted(acts, key=lambda a: (a.tick, a.action))
    assert any(a.action == "handoff" for a in acts)


if __name__ == "__main__":               # regenerate the committed fixture
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(_golden_chaos_trace((cfg, params)),
                                 indent=1, sort_keys=True))
    print(f"wrote {GOLDEN}")
