"""Disaggregated prefill/decode cells: the differential serving battery.

The scheduling semantics of the cell pair are specified ONCE, model-free,
in ``serving/scenarios.py`` (``simulate_disagg`` / ``_admission_pick``);
``serving/cells.py`` is the independent real-model implementation.  This
suite holds the two together and pins the pair against the monolithic
engine:

1. *Mirror conformance* — under ``DisaggConfig.mirror()`` the cell pair
   replays the pinned golden bursty trace (``tests/golden/
   serve_trace.json``) byte-identically on every shared key, across
   ``{scan, pallas}`` lane backends and mesh sizes ``{1, 2}``, and an
   engine-vs-cells lockstep run demands identical per-request
   admission/completion ticks, batch occupancy and token streams.
2. *Admission control properties* — hypothesis-fuzzed (deterministic
   seeded corpus when hypothesis is absent): request conservation,
   occupancy recomputable from the per-request records, FIFO within an
   SLO class, no throughput starvation under latency bursts, the
   KV-handoff bound and prefill budget never exceeded; plus a direct
   ``AdmissionQueue``-vs-``_admission_pick`` pick-order diff.
3. *Cells-vs-simulator parity* — on every scenario shape, a bounded
   SLO-mixed cell pair (real model decode) matches ``simulate_disagg``
   tick-exactly on batches and per-request prefill/admit/completion.
4. *Golden disagg fixture* — one bounded SLO run's full telemetry is
   pinned byte-exactly in ``tests/golden/disagg_trace.json``;
   regenerate deliberately with ``python tests/test_disagg.py``.
5. *Neutral-zero + warm handoff* — zero-request runs summarize to
   neutral values everywhere, and a warm prefill→decode handoff does
   zero lane re-resolves while holding >= 0.95x oracle efficiency.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

from repro.configs import ARCHS, smoke_config
from repro.core import engine
from repro.kernels import lane_scan
from repro.models import model as M
from repro.serving.cells import AdmissionQueue, DisaggServingEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import OffloadPlanner
from repro.serving.scenarios import (SCENARIOS, SLO_CLASSES, SLO_LATENCY,
                                     SLO_THROUGHPUT, DisaggConfig,
                                     ScenarioSpec, _admission_pick,
                                     assign_slo, make_scenario,
                                     run_scenario, simulate_batches,
                                     simulate_disagg)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
SERVE_GOLDEN = GOLDEN_DIR / "serve_trace.json"
DISAGG_GOLDEN = GOLDEN_DIR / "disagg_trace.json"

# Same pinned workload as the monolithic golden trace — the mirror test
# diffs the two, so they must stay in lockstep.
GOLDEN_SCENARIO = dict(name="bursty", seed=3, slots=4, quick=True)
GOLDEN_POLICY = "hysteresis"
GOLDEN_DISAGG = DisaggConfig(prefill_budget=2, handoff_bound=3,
                             starvation_age=4)
GOLDEN_SLO_FRAC = 0.6


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def planner():
    return OffloadPlanner(ARCHS["mamba2-130m"])


def _nontrivial_cfg() -> DisaggConfig:
    """Bounded + budgeted + aged: every scheduling knob active."""
    return DisaggConfig(prefill_budget=1, handoff_bound=2,
                        starvation_age=3)


# ---------------------------------------------------------------------
# 1. Mirror conformance: cells replay the golden monolithic trace
# ---------------------------------------------------------------------

@pytest.mark.parametrize("mesh_size", [1, 2])
@pytest.mark.parametrize("backend", ["scan", "pallas"])
def test_disagg_mirror_replays_golden_trace(small_lm, backend, mesh_size):
    """A mirror-configured cell pair re-emits the pinned monolithic
    bursty trace byte-identically on every shared key — per-tick
    batches, occupancy histogram, controller report, per-step speedups —
    under each lane backend and mesh size (lane resolution is
    bit-identical across all of them by contract)."""
    if backend == "pallas" and not lane_scan.pallas_lane_supported():
        pytest.skip("pallas lane kernel unsupported here")
    if mesh_size > len(engine.lane_devices()):
        pytest.skip(f"mesh size {mesh_size} needs more host devices")
    cfg, params = small_lm
    fixture = json.loads(SERVE_GOLDEN.read_text())
    engine.lane_cache_clear()      # force THIS combo to resolve lanes
    fresh_planner = OffloadPlanner(ARCHS["granite-8b"])
    with engine.lane_backend_scope(backend):
        trace = run_scenario(make_scenario(**GOLDEN_SCENARIO), cfg,
                             params, fresh_planner, policy=GOLDEN_POLICY,
                             mesh=mesh_size, disagg=True)
    trace = json.loads(json.dumps(trace))
    assert set(trace) == set(fixture) | {"disagg"}
    for key in fixture:
        assert trace[key] == fixture[key], f"disagg mirror drift at {key}"
    # the mirror run's own record: unbounded pair, pure FIFO, and every
    # request prefills+admits+completes
    rec = trace["disagg"]
    assert rec["config"] == DisaggConfig.mirror().to_record()
    n = len(fixture["scenario"]["arrivals"])
    assert len(rec["requests"]["completion_ticks"]) == n


def test_mirror_pair_matches_monolithic_engine_lockstep(small_lm, planner):
    """Engine-level differential: the monolithic engine and the mirror
    cell pair, driven tick-for-tick on one schedule, agree on admission
    ticks, completion ticks, batch occupancy, step batches and the full
    decoded token stream of every request."""
    cfg, params = small_lm
    spec = make_scenario("bursty", seed=1, slots=3, quick=True)
    max_seq = max(64, 2 * max(a.prompt_len + a.max_new
                              for a in spec.arrivals))
    mono = ServingEngine(cfg, params, slots=spec.slots, max_seq=max_seq)
    pair = DisaggServingEngine(cfg, params, slots=spec.slots,
                               max_seq=max_seq)

    def reqs():
        rng = np.random.default_rng(spec.seed + 1)
        return {a.rid: Request(rid=a.rid,
                               prompt=rng.integers(0, cfg.vocab,
                                                   size=a.prompt_len),
                               max_new=a.max_new) for a in spec.arrivals}

    reqs_mono, reqs_pair = reqs(), reqs()
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    for eng, rs in ((mono, reqs_mono), (pair, reqs_pair)):
        i, t = 0, 0
        while i < len(pending) or any(eng.active) or eng.waiting:
            while i < len(pending) and pending[i].step <= t:
                eng.submit(rs[pending[i].rid])
                i += 1
            eng.step()
            t += 1
    assert mono.completions == pair.completions
    assert mono.admit_ticks == pair.decode_cell.admit_ticks
    assert mono.step_batches == pair.step_batches
    assert mono.batch_occupancy == pair.decode_cell.batch_occupancy
    for rid in reqs_mono:
        assert reqs_mono[rid].out == reqs_pair[rid].out, rid
    # and both match the model-free simulators
    sim = simulate_disagg(spec)
    assert sim["per_tick_batch"] == simulate_batches(spec)
    assert pair.completions == sim["completion_ticks"]


# ---------------------------------------------------------------------
# 2. Admission control: fuzzed properties + the pick-order diff
# ---------------------------------------------------------------------

def _assert_queue_matches_spec(seed: int):
    """Random push/pop interleavings: ``cells.AdmissionQueue`` and the
    ``scenarios._admission_pick`` spec emit the same rid at every pop."""
    rng = np.random.default_rng(seed)
    age = int(rng.integers(0, 6))
    queue = AdmissionQueue(starvation_age=age)
    waiting: list[tuple] = []          # the spec-side mirror
    seq = 0
    next_rid = 0
    for t in range(int(rng.integers(5, 25))):
        for _ in range(int(rng.integers(0, 4))):
            slo = (SLO_LATENCY if rng.random() < 0.5 else SLO_THROUGHPUT)
            queue.push(Request(rid=next_rid,
                               prompt=np.zeros(1, np.int32)), slo, t)
            waiting.append((t, seq, next_rid, slo))
            seq += 1
            next_rid += 1
        for _ in range(int(rng.integers(0, 3))):
            if not waiting:
                break
            want = waiting.pop(_admission_pick(waiting, t, age))
            req, slo, enq = queue.pop(t)
            assert (req.rid, slo, enq) == (want[2], want[3], want[0])
    assert len(queue) == len(waiting)


@pytest.mark.parametrize("seed", range(10))
def test_admission_queue_matches_pick_spec(seed):
    _assert_queue_matches_spec(seed)


def test_admission_queue_rejects_unknown_class():
    q = AdmissionQueue()
    with pytest.raises(ValueError, match="unknown SLO class"):
        q.push(Request(rid=0, prompt=np.zeros(1, np.int32)), "batch", 0)


def _assert_disagg_invariants(spec: ScenarioSpec, dcfg: DisaggConfig,
                              slo: dict):
    sim = simulate_disagg(spec, dcfg, slo)
    rids = {a.rid for a in spec.arrivals}
    arrive = {a.rid: a.step for a in spec.arrivals}
    steps = {a.rid: a.decode_steps() for a in spec.arrivals}
    pf, ad, cp = (sim["prefill_ticks"], sim["admit_ticks"],
                  sim["completion_ticks"])
    # conservation: every request prefills, admits and completes, in
    # causal order, holding its slot for exactly its decode budget
    assert set(pf) == set(ad) == set(cp) == rids
    for r in rids:
        assert arrive[r] <= pf[r] <= ad[r] <= cp[r], r
        assert cp[r] - ad[r] == steps[r] - 1, r
    # occupancy is recomputable from the per-request records and never
    # exceeds the slot count
    for t, b in enumerate(sim["per_tick_batch"]):
        assert b == sum(1 for r in rids if ad[r] <= t <= cp[r])
        assert b <= spec.slots
    # the handoff bound and prefill budget hold at every tick
    if dcfg.handoff_bound is not None:
        assert sim["max_handoff_depth"] <= dcfg.handoff_bound
        assert max(sim["handoff_depth"], default=0) <= dcfg.handoff_bound
    if dcfg.prefill_budget is not None:
        assert max(sim["per_tick_prefills"],
                   default=0) <= dcfg.prefill_budget
    assert sum(sim["per_tick_prefills"]) == len(rids)
    # FIFO within an SLO class: enqueue order implies prefill order
    for cls in SLO_CLASSES:
        order = sorted((r for r in rids
                        if slo.get(r, SLO_LATENCY) == cls),
                       key=lambda r: (arrive[r], r))
        ticks = [pf[r] for r in order]
        assert ticks == sorted(ticks), cls
    # no starvation: once a throughput request has aged past the
    # threshold, no latency request may be prefilled before it
    for r in rids:
        if slo.get(r, SLO_LATENCY) != SLO_THROUGHPUT:
            continue
        for q in rids:
            if slo.get(q, SLO_LATENCY) == SLO_LATENCY:
                assert not (arrive[r] + dcfg.starvation_age
                            <= pf[q] < pf[r]), (r, q)
    # the mirror degenerate case equals the monolithic queue model
    mirror = simulate_disagg(spec)
    assert mirror["per_tick_batch"] == simulate_batches(spec)


def _corpus_case(seed: int):
    rng = np.random.default_rng(1000 + seed)
    name = sorted(SCENARIOS)[seed % len(SCENARIOS)]
    spec = make_scenario(name, seed=int(rng.integers(0, 1000)),
                         slots=int(rng.integers(1, 6)), quick=True)
    dcfg = DisaggConfig(
        prefill_budget=(None if rng.random() < 0.3
                        else int(rng.integers(1, 5))),
        handoff_bound=(None if rng.random() < 0.3
                       else int(rng.integers(1, 6))),
        starvation_age=int(rng.integers(0, 10)))
    slo = assign_slo(spec, frac_latency=float(rng.random()),
                     seed=int(rng.integers(0, 1000)))
    return spec, dcfg, slo


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(name=st.sampled_from(sorted(SCENARIOS)),
           seed=st.integers(0, 10_000), slots=st.integers(1, 6),
           budget=st.one_of(st.none(), st.integers(1, 4)),
           bound=st.one_of(st.none(), st.integers(1, 5)),
           age=st.integers(0, 10),
           frac=st.floats(0.0, 1.0))
    def test_fuzzed_admission_invariants(name, seed, slots, budget,
                                         bound, age, frac):
        spec = make_scenario(name, seed=seed, slots=slots, quick=True)
        dcfg = DisaggConfig(prefill_budget=budget, handoff_bound=bound,
                            starvation_age=age)
        _assert_disagg_invariants(spec, dcfg,
                                  assign_slo(spec, frac_latency=frac))
else:                      # deterministic fallback when hypothesis absent
    @pytest.mark.parametrize("seed", range(15))
    def test_fuzzed_admission_invariants(seed):
        _assert_disagg_invariants(*_corpus_case(seed))


def test_disagg_config_validation():
    with pytest.raises(ValueError, match="prefill_budget"):
        DisaggConfig(prefill_budget=0)
    with pytest.raises(ValueError, match="handoff_bound"):
        DisaggConfig(handoff_bound=-1)
    with pytest.raises(ValueError, match="starvation_age"):
        DisaggConfig(starvation_age=-1)
    rec = json.loads(json.dumps(_nontrivial_cfg().to_record()))
    assert DisaggConfig.from_record(rec) == _nontrivial_cfg()


def test_assign_slo_deterministic():
    spec = make_scenario("steady", seed=4, quick=True)
    assert assign_slo(spec, 0.5) == assign_slo(spec, 0.5)
    assert set(assign_slo(spec, 0.5)) == {a.rid for a in spec.arrivals}
    assert set(assign_slo(spec, 1.0).values()) == {SLO_LATENCY}
    assert set(assign_slo(spec, 0.0).values()) == {SLO_THROUGHPUT}


# ---------------------------------------------------------------------
# 3. Cells vs simulator: every scenario shape, bounded + SLO-mixed
# ---------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_cells_match_simulator_on_every_scenario(small_lm, planner, name):
    """The real cell pair (model decode included) and the model-free
    simulator agree tick-exactly — batches, prefill/admit/completion
    ticks, peak handoff depth — under active budget/bound/SLO knobs."""
    cfg, params = small_lm
    spec = make_scenario(name, seed=2, slots=3, quick=True)
    dcfg = _nontrivial_cfg()
    slo = assign_slo(spec, frac_latency=0.6)
    sim = simulate_disagg(spec, dcfg, slo)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step",
                         disagg=dcfg, slo=slo)
    assert trace["per_tick_batch"] == sim["per_tick_batch"]
    rec = trace["disagg"]
    for key in ("prefill_ticks", "admit_ticks", "completion_ticks"):
        assert rec["requests"][key] == {str(r): t for r, t
                                        in sim[key].items()}, key
    assert rec["handoff"]["max_depth"] == sim["max_handoff_depth"]
    assert rec["handoff"]["handoffs"] == len(spec.arrivals)
    assert rec["handoff"]["depth"] == 0          # drained
    per_class = rec["per_class"]
    for cls in SLO_CLASSES:
        want = sum(1 for s in slo.values() if s == cls)
        assert per_class[cls]["submitted"] == want
        assert per_class[cls]["completed"] == want


# ---------------------------------------------------------------------
# 4. Golden disagg fixture
# ---------------------------------------------------------------------

def _golden_disagg_trace(small_lm) -> dict:
    cfg, params = small_lm
    spec = make_scenario(**GOLDEN_SCENARIO)
    fresh_planner = OffloadPlanner(ARCHS["granite-8b"])
    return run_scenario(spec, cfg, params, fresh_planner,
                        policy=GOLDEN_POLICY, disagg=GOLDEN_DISAGG,
                        slo=assign_slo(spec, GOLDEN_SLO_FRAC))


def test_golden_disagg_trace_exact(small_lm):
    """The bounded SLO-mixed disagg run's full telemetry — scheduling,
    handoff, per-class waits, controller report — diffed EXACTLY against
    the committed fixture.  Regenerate deliberately with
    `python tests/test_disagg.py`."""
    fixture = json.loads(DISAGG_GOLDEN.read_text())
    current = json.loads(json.dumps(_golden_disagg_trace(small_lm)))
    assert set(current) == set(fixture)
    for key in fixture:
        assert current[key] == fixture[key], f"golden drift at {key}"


def test_golden_disagg_trace_replays_without_model():
    """The committed disagg trace is self-describing: the embedded
    schedule + DisaggConfig + SLO map re-derive every scheduling record
    through the model-free simulator, and the pinned efficiency floor
    holds."""
    fixture = json.loads(DISAGG_GOLDEN.read_text())
    spec = ScenarioSpec.from_record(fixture["scenario"])
    rec = fixture["disagg"]
    dcfg = DisaggConfig.from_record(rec["config"])
    slo = {int(r): s for r, s in rec["slo"].items()}
    assert dcfg == GOLDEN_DISAGG
    assert slo == assign_slo(spec, GOLDEN_SLO_FRAC)
    sim = simulate_disagg(spec, dcfg, slo)
    assert fixture["per_tick_batch"] == sim["per_tick_batch"]
    for key in ("prefill_ticks", "admit_ticks", "completion_ticks"):
        assert rec["requests"][key] == {str(r): t for r, t
                                        in sim[key].items()}, key
    assert rec["handoff"]["max_depth"] == sim["max_handoff_depth"]
    assert rec["handoff"]["max_depth"] <= dcfg.handoff_bound
    assert fixture["controller"]["efficiency"] >= 0.95


# ---------------------------------------------------------------------
# 5. Neutral zero-request summaries + warm-handoff lane accounting
# ---------------------------------------------------------------------

def test_zero_request_disagg_summary_is_neutral(small_lm, planner):
    cfg, params = small_lm
    eng = DisaggServingEngine(cfg, params, slots=2, max_seq=32,
                              planner=planner)
    assert eng.step() is False
    out = eng.run(max_steps=3)
    assert out["steps"] == 0 and out["tokens"] == 0
    assert out["prefills"] == 0 and out["completed"] == 0
    assert out["in_flight"] == 0 and out["tokens_per_step"] == 0.0
    assert out["batch_occupancy"] == {}
    rec = out["disagg"]
    assert rec["handoff"]["depth"] == 0
    assert rec["handoff"]["max_depth"] == 0
    for cls in SLO_CLASSES:
        per = rec["per_class"][cls]
        assert per == dict(submitted=0, completed=0, mean_admit_wait=0.0,
                           mean_completion_ticks=0.0)


@pytest.mark.parametrize("disagg", [False, True])
def test_zero_request_scenario_run_is_neutral(small_lm, planner, disagg):
    """An empty arrival schedule runs end to end — no raise, no 0/0 —
    through both the monolithic engine and the cell pair."""
    cfg, params = small_lm
    spec = ScenarioSpec(name="steady", seed=0, slots=2, arrivals=())
    trace = run_scenario(spec, cfg, params, planner,
                         policy="hysteresis", disagg=disagg)
    assert trace["steps"] == 0 and trace["tokens"] == 0
    assert trace["per_tick_batch"] == []
    assert trace["occupancy"] == {}
    assert trace["controller"]["efficiency"] == 1.0


def test_warm_handoff_does_zero_lane_reresolves(small_lm):
    """Both cells share the process-global resolved-lane LRU: once the
    planner's fleet query has warmed it, a full disaggregated serve —
    every prefill→decode handoff included — adds zero lane-cache misses,
    and the policy still holds the efficiency floor."""
    cfg, params = small_lm
    engine.lane_cache_reset()
    warm_planner = OffloadPlanner(ARCHS["granite-8b"])
    warm_planner.plan()                    # the one fleet resolve
    before = engine.lane_cache_info()["misses"]
    assert before > 0, "planner warm-up should populate the lane LRU"
    spec = make_scenario("bursty", seed=1, slots=3, quick=True)
    trace = run_scenario(spec, cfg, params, warm_planner,
                         policy="hysteresis", disagg=GOLDEN_DISAGG,
                         slo=assign_slo(spec, 0.5))
    assert engine.lane_cache_info()["misses"] == before, \
        "warm prefill→decode handoff must not re-resolve lanes"
    assert trace["controller"]["efficiency"] >= 0.95


if __name__ == "__main__":          # regenerate the committed fixture
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    DISAGG_GOLDEN.write_text(json.dumps(
        _golden_disagg_trace((cfg, params)), indent=1, sort_keys=True))
    print(f"wrote {DISAGG_GOLDEN}")
