"""Warm-start persistence: on-disk lane LRU + cache counter semantics.

The snapshot contract is replay-exactness and crash-tolerance: a saved
lane LRU loaded into a FRESH process must reproduce ``resolve_lanes``
results byte-identically with zero fleet resolves, and *no* corrupt,
truncated or mismatched snapshot may ever raise — every failure mode
degrades to a cold cache.  The ``configure_lane_cache`` counter fix
(unchanged capacity preserves hits/misses/evictions) is pinned here too.
"""
import os
import pickle
import subprocess
import sys

import numpy as np
import pytest

from repro.core import engine, faults, warmstart
from repro.core.timing import DEFAULT_SYSTEM

from test_engine import build_valid_stream, random_op_tuples


def _lanes(seed: int, n: int = 5):
    rng = np.random.default_rng(seed)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    return [(cyc, build_valid_stream(random_op_tuples(rng, max_ops=30)))
            for _ in range(n)]


def _keys(n: int = 5):
    return [("warm", i) for i in range(n)]


@pytest.fixture(autouse=True)
def _fresh_lane_cache():
    engine.lane_cache_reset()
    yield
    engine.lane_cache_reset()


# ---------------------------------------------------------------------
# Round trip: save -> (fresh state) -> load -> replay with zero resolves
# ---------------------------------------------------------------------

def test_snapshot_round_trip_zero_misses(tmp_path):
    lanes = _lanes(0)
    ref = engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    assert warmstart.save_lane_snapshot(str(tmp_path)) == 5

    engine.lane_cache_reset()                 # simulate a fresh process
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 5
    info = engine.lane_cache_info()
    assert info["misses"] == 0 and info["hits"] == 0   # import is silent

    got = engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    info = engine.lane_cache_info()
    assert info["misses"] == 0, "warm replay must not resolve"
    assert info["hits"] == 5
    assert [t for _, t in ref] == [t for _, t in got]


def test_snapshot_round_trip_fresh_process(tmp_path):
    """The real thing: a separate interpreter loads the snapshot and
    replays byte-identically with zero fleet resolves."""
    lanes = _lanes(7)
    ref = engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    warmstart.save_lane_snapshot(str(tmp_path))

    child = (
        "import json, sys\n"
        "sys.path.insert(0, %r)\n"
        "import numpy as np\n"
        "from repro.core import engine, warmstart\n"
        "from repro.core.timing import DEFAULT_SYSTEM\n"
        "from test_engine import build_valid_stream, random_op_tuples\n"
        "warmstart.load_lane_snapshot(sys.argv[1])\n"
        "rng = np.random.default_rng(7)\n"
        "cyc = DEFAULT_SYSTEM.derive_cycles()\n"
        "lanes = [(cyc, build_valid_stream(random_op_tuples(rng,"
        " max_ops=30))) for _ in range(5)]\n"
        "keys = [('warm', i) for i in range(5)]\n"
        "res = engine.resolve_lanes(lanes, keys=keys, need_issue=False)\n"
        "info = engine.lane_cache_info()\n"
        "print(json.dumps(dict(totals=[int(t) for _, t in res],"
        " misses=info['misses'])))\n"
    ) % os.path.dirname(os.path.abspath(__file__))
    # Propagate this interpreter's import roots: the child must find
    # `repro` even when the repo runs from a src-layout checkout without
    # a pip install (pytest injects src/ via pyproject pythonpath, which
    # subprocesses do not inherit).
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", child, str(tmp_path)],
                         capture_output=True, text=True, check=True,
                         env=env)
    import json
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["misses"] == 0
    assert rep["totals"] == [int(t) for _, t in ref]


def test_snapshot_save_is_atomic_and_empty_cache_saves(tmp_path):
    assert warmstart.save_lane_snapshot(str(tmp_path)) == 0
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 0
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert not leftovers, f"tmp files left behind: {leftovers}"


# ---------------------------------------------------------------------
# Corruption / version tolerance: cold start, never a crash
# ---------------------------------------------------------------------

def _saved_snapshot(tmp_path):
    engine.resolve_lanes(_lanes(1), keys=_keys(), need_issue=False)
    warmstart.save_lane_snapshot(str(tmp_path))
    return warmstart.lane_snapshot_path(str(tmp_path))


def test_missing_snapshot_is_cold(tmp_path):
    assert warmstart.load_lane_snapshot(str(tmp_path / "nowhere")) == 0


def test_truncated_snapshot_is_cold(tmp_path):
    path = _saved_snapshot(tmp_path)
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[: len(data) // 2])
    engine.lane_cache_reset()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 0
    assert engine.lane_cache_info()["size"] == 0


def test_garbage_snapshot_is_cold(tmp_path):
    path = _saved_snapshot(tmp_path)
    with open(path, "wb") as f:
        f.write(b"not a pickle at all")
    engine.lane_cache_reset()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 0


def test_version_mismatch_is_cold(tmp_path):
    path = _saved_snapshot(tmp_path)
    payload = pickle.load(open(path, "rb"))
    payload["version"] = warmstart.SNAPSHOT_VERSION + 1
    with open(path, "wb") as f:
        pickle.dump(payload, f)
    engine.lane_cache_reset()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 0


def test_fingerprint_mismatch_is_cold(tmp_path):
    path = _saved_snapshot(tmp_path)
    payload = pickle.load(open(path, "rb"))
    payload["fingerprint"] = "0" * 32
    with open(path, "wb") as f:
        pickle.dump(payload, f)
    engine.lane_cache_reset()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 0


def test_malformed_payload_shapes_are_cold(tmp_path):
    path = warmstart.lane_snapshot_path(str(tmp_path))
    os.makedirs(tmp_path, exist_ok=True)
    for payload in (["a", "list"], {"magic": b"wrong"},
                    {"magic": warmstart._MAGIC,
                     "version": warmstart.SNAPSHOT_VERSION,
                     "fingerprint": warmstart.snapshot_fingerprint(),
                     "entries": "not-a-list"}):
        with open(path, "wb") as f:
            pickle.dump(payload, f)
        assert warmstart.load_lane_snapshot(str(tmp_path)) == 0


# ---------------------------------------------------------------------
# Crash-mid-write: the previous snapshot must survive a dying writer
# ---------------------------------------------------------------------

def test_injected_crash_mid_write_preserves_previous_snapshot(tmp_path):
    """A writer that dies after fsync but before the atomic rename (the
    armed ``warmstart`` seam) leaves the previous snapshot intact and no
    tmp litter behind."""
    engine.resolve_lanes(_lanes(8), keys=_keys(), need_issue=False)
    assert warmstart.save_lane_snapshot(str(tmp_path)) == 5

    engine.resolve_lanes(_lanes(9), keys=[("v2", i) for i in range(5)],
                         need_issue=False)
    inj = faults.FaultInjector()
    inj.arm("warmstart", count=1, message="crash mid-write")
    with faults.fault_scope(inj):
        with pytest.raises(faults.InjectedFault):
            warmstart.save_lane_snapshot(str(tmp_path))

    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert not leftovers, f"tmp files left behind: {leftovers}"
    engine.lane_cache_reset()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 5  # v1 intact


def test_hard_kill_mid_write_preserves_previous_snapshot(tmp_path):
    """The real thing: a separate interpreter is hard-killed (os._exit)
    at the injection seam — after the tmp file is written and fsynced,
    before ``os.replace``.  The parent must still load the previous
    snapshot; a leftover tmp file is acceptable crash litter but must
    never shadow the real snapshot."""
    engine.resolve_lanes(_lanes(10), keys=_keys(), need_issue=False)
    assert warmstart.save_lane_snapshot(str(tmp_path)) == 5
    ref = engine.lane_cache_export()

    child = (
        "import os, sys\n"
        "sys.path.insert(0, %r)\n"
        "import numpy as np\n"
        "from repro.core import engine, faults, warmstart\n"
        "from repro.core.timing import DEFAULT_SYSTEM\n"
        "from test_engine import build_valid_stream, random_op_tuples\n"
        "rng = np.random.default_rng(11)\n"
        "cyc = DEFAULT_SYSTEM.derive_cycles()\n"
        "lanes = [(cyc, build_valid_stream(random_op_tuples(rng,"
        " max_ops=30))) for _ in range(3)]\n"
        "engine.resolve_lanes(lanes, keys=[('kill', i) for i in range(3)],"
        " need_issue=False)\n"
        "faults.maybe_fail = lambda site: os._exit(9)\n"
        "warmstart.faults.maybe_fail = faults.maybe_fail\n"
        "warmstart.save_lane_snapshot(sys.argv[1])\n"
    ) % os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", child, str(tmp_path)],
                         capture_output=True, text=True, env=env)
    assert out.returncode == 9, out.stderr

    engine.lane_cache_reset()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 5
    assert engine.lane_cache_export() == ref


def test_save_warm_start_absorbs_failure(tmp_path, monkeypatch):
    """A failing save is advisory: ``save_warm_start`` returns -1 and
    records a structured ``fault`` event instead of raising."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    inj = faults.FaultInjector()
    inj.arm("warmstart", count=1)
    with faults.fault_scope(inj):
        assert warmstart.save_warm_start() == -1
    evs = [e for e in faults.events()
           if e["site"] == "warmstart" and e["kind"] == "fault"]
    assert evs and "snapshot save failed" in evs[0]["detail"]


def test_rejected_snapshot_records_detect_event(tmp_path):
    path = _saved_snapshot(tmp_path)
    with open(path, "wb") as f:
        f.write(b"garbage, not a pickle")
    engine.lane_cache_reset()
    faults.reset_events()
    assert warmstart.load_lane_snapshot(str(tmp_path)) == 0
    evs = [e for e in faults.events()
           if e["site"] == "warmstart" and e["kind"] == "detect"]
    assert evs and "cold start" in evs[0]["detail"]


# ---------------------------------------------------------------------
# enable/save_warm_start wiring + env knob
# ---------------------------------------------------------------------

def test_enable_warm_start_no_dir_is_noop(monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    rep = warmstart.enable_warm_start()
    assert rep == {"cache_dir": None, "compile_cache": False, "lanes": 0}
    assert warmstart.save_warm_start() == -1


def test_env_cache_dir_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    engine.resolve_lanes(_lanes(2), keys=_keys(), need_issue=False)
    assert warmstart.save_warm_start() == 5
    engine.lane_cache_reset()
    rep = warmstart.enable_warm_start()
    assert rep["cache_dir"] == str(tmp_path) and rep["lanes"] == 5


def test_import_respects_capacity(tmp_path):
    engine.resolve_lanes(_lanes(3), keys=_keys(), need_issue=False)
    warmstart.save_lane_snapshot(str(tmp_path))
    engine.configure_lane_cache(2)            # shrink (clears)
    try:
        kept = warmstart.load_lane_snapshot(str(tmp_path))
        assert kept == 2                      # newest 2 survive
        info = engine.lane_cache_info()
        assert info["size"] == 2 and info["evictions"] == 0
    finally:
        engine.configure_lane_cache(4096)


def test_import_disabled_cache_keeps_nothing(tmp_path):
    engine.resolve_lanes(_lanes(4), keys=_keys(), need_issue=False)
    warmstart.save_lane_snapshot(str(tmp_path))
    engine.configure_lane_cache(0)
    try:
        assert warmstart.load_lane_snapshot(str(tmp_path)) == 0
    finally:
        engine.configure_lane_cache(4096)


# ---------------------------------------------------------------------
# configure_lane_cache counter semantics (satellite fix)
# ---------------------------------------------------------------------

def test_reconfigure_same_capacity_preserves_state():
    lanes = _lanes(5)
    engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    before = engine.lane_cache_info()
    assert before["hits"] == 5 and before["misses"] == 5

    engine.configure_lane_cache(before["maxsize"])   # unchanged: no-op
    assert engine.lane_cache_info() == before

    engine.resolve_lanes(lanes, keys=_keys(), need_issue=False)
    assert engine.lane_cache_info()["hits"] == 10    # entries survived


def test_reconfigure_new_capacity_still_clears():
    engine.resolve_lanes(_lanes(6), keys=_keys(), need_issue=False)
    engine.configure_lane_cache(1024)                # change: clears
    try:
        info = engine.lane_cache_info()
        assert info == dict(size=0, maxsize=1024, hits=0, misses=0,
                            evictions=0)
    finally:
        engine.configure_lane_cache(4096)
