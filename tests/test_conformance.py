"""Differential conformance suite: fleet engine vs the Python oracle.

PIMSIM-NN-style reference-model conformance for the spec-vectorized
facade: fuzzed *multi-spec* fleets — points varying bank counts, JEDEC
timings, PIM knobs and stream lengths — resolve through ONE batched
``engine.resolve_fleet`` call and every lane must match ``RefEngine``
cycle-exactly.  The same discipline is applied one layer up
(``PimExecutor.run_many`` over heterogeneous ``SystemSpec``s, and the
batched functional path), and a committed golden fixture pins the
cycle/energy outputs of a small (spec x shape) grid so facade refactors
cannot silently drift.

When hypothesis is unavailable the fuzz tests fall back to a
deterministic seeded corpus (CI runs both flavors).
"""
import json
import pathlib

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

from repro.core import engine
from repro.core.engine_ref import RefEngine
from repro.core.timing import (DEFAULT_SYSTEM, LpddrTimings, PimSpec,
                               SystemSpec)
from repro.pimkernel.executor import (FunctionalGemv, GemvRequest,
                                      PimExecutor)
from repro.pimkernel.tileconfig import PimDType

from test_engine import build_valid_stream, random_op_tuples

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fleet_parity.json"


# ---------------------------------------------------------------------
# Spec + fleet generators (shared by hypothesis and the fallback corpus)
# ---------------------------------------------------------------------

def make_spec(bankgroups: int, t_rcd: float, t_rp: float, t_ras: float,
              mac_i: int, srf_i: int, fence_ns: float) -> SystemSpec:
    """One fuzzed design point (num_banks = 4 * bankgroups)."""
    return SystemSpec(
        timings=LpddrTimings(num_bankgroups=bankgroups, tRCD=t_rcd,
                             tRP=t_rp, tRAS=t_ras),
        pim=PimSpec(mac_interval_ck=mac_i, srf_wr_interval_ck=srf_i),
        fence_ns=fence_ns)


def clamp_banks(ops, nb: int):
    """Restrict op-tuple bank ids to the spec's bank count."""
    return [(kind, bank % nb, row, n) for (kind, bank, row, n) in ops]


def fleet_from_seed(seed: int, n_points: int = 4):
    """Deterministic multi-spec fleet: (spec, streams) points."""
    rng = np.random.default_rng(seed)
    points = []
    for _ in range(n_points):
        spec = make_spec(
            bankgroups=int(rng.integers(2, 5)),
            t_rcd=float(rng.integers(12, 31)),
            t_rp=float(rng.integers(12, 31)),
            t_ras=float(rng.integers(30, 55)),
            mac_i=int(rng.integers(1, 7)),
            srf_i=int(rng.integers(8, 21)),
            fence_ns=float(rng.integers(50, 301)))
        nb = spec.timings.num_banks
        n_ch = int(rng.integers(1, 4))
        streams = [build_valid_stream(
            clamp_banks(random_op_tuples(rng, max_ops=30), nb))
            for _ in range(n_ch)]
        points.append((spec, streams))
    return points


def assert_fleet_matches_ref(points):
    """One resolve_fleet dispatch; every lane checked against RefEngine."""
    fleet = engine.resolve_fleet(
        [(spec.derive_cycles(), streams) for spec, streams in points])
    for (spec, streams), fr in zip(points, fleet):
        ref = RefEngine(spec.derive_cycles(), validate=False)
        for ci, s in enumerate(streams):
            iss_ref, tot_ref = ref.run(s)
            np.testing.assert_array_equal(
                iss_ref, fr.issue[ci].astype(np.int64),
                err_msg=f"issue divergence: spec={spec}, lane={ci}")
            assert tot_ref == int(fr.totals[ci]), \
                f"total divergence: spec={spec}, lane={ci}"


# ---------------------------------------------------------------------
# Fuzzed multi-spec fleets (engine layer)
# ---------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    def _point_strategy():
        spec = st.builds(
            make_spec,
            bankgroups=st.integers(2, 4),
            t_rcd=st.integers(12, 30).map(float),
            t_rp=st.integers(12, 30).map(float),
            t_ras=st.integers(30, 54).map(float),
            mac_i=st.integers(1, 6),
            srf_i=st.integers(8, 20),
            fence_ns=st.integers(50, 300).map(float))
        ops = st.lists(st.tuples(st.integers(0, 2), st.integers(0, 15),
                                 st.integers(0, 127), st.integers(0, 30)),
                       min_size=1, max_size=30)
        return spec.flatmap(lambda sp: st.tuples(
            st.just(sp),
            st.lists(ops.map(lambda o: build_valid_stream(
                clamp_banks(o, sp.timings.num_banks))),
                min_size=1, max_size=3)))

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(st.lists(_point_strategy(), min_size=1, max_size=4))
    def test_fuzzed_multi_spec_fleet_matches_ref(points):
        assert_fleet_matches_ref(points)
else:                      # deterministic fallback when hypothesis absent
    @pytest.mark.parametrize("seed", range(10))
    def test_fuzzed_multi_spec_fleet_matches_ref(seed):
        assert_fleet_matches_ref(fleet_from_seed(seed))


# ---------------------------------------------------------------------
# Backend battery: the SAME conformance corpus through every selectable
# lane backend — the Pallas resolver must match RefEngine lane-for-lane
# exactly like the scan path (bit-identity is the backend contract).
# ---------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("seed", range(3))
def test_backend_fleet_matches_ref(backend, seed):
    if backend == "pallas":
        from repro.kernels import lane_scan
        if not lane_scan.pallas_lane_supported():
            pytest.skip("pallas lane resolver unsupported here")
    with engine.lane_backend_scope(backend):
        assert engine.resolved_lane_backend() == backend
        assert_fleet_matches_ref(fleet_from_seed(seed))


def test_mixed_bank_counts_share_one_dispatch():
    """8/12/16-bank design points resolve correctly in one fleet batch
    (one resolver per bank count, grouped under the hood)."""
    points = []
    rng = np.random.default_rng(99)
    for bg in (2, 3, 4, 2, 4):
        spec = make_spec(bg, 18.0, 18.0, 42.0, 3, 14, 150.0)
        nb = spec.timings.num_banks
        points.append((spec, [build_valid_stream(
            clamp_banks(random_op_tuples(rng, max_ops=25), nb))]))
    assert_fleet_matches_ref(points)


# ---------------------------------------------------------------------
# Facade layer: heterogeneous run_many lanes vs RefEngine
# ---------------------------------------------------------------------

FACADE_SPECS = [
    DEFAULT_SYSTEM,
    SystemSpec(timings=LpddrTimings(tRCD=24.0, tRP=22.0),
               pim=PimSpec(mac_interval_ck=2)),
    SystemSpec(timings=LpddrTimings(num_bankgroups=2, tRAS=48.0),
               fence_ns=250.0),
]
FACADE_SHAPES = [(64, 512, PimDType.W8A8, False, False),
                 (128, 256, PimDType.W8A16, True, False),
                 (130, 512, PimDType.W4A8, False, True),
                 (64, 1024, PimDType.W8A8, False, False)]


def test_facade_multi_spec_lanes_match_ref():
    """Every lane of a heterogeneous run_many fleet — built streams
    under 3 spec variants x 4 shapes — matches RefEngine cycle-exactly,
    including the reported max-channel cycle count."""
    ex = PimExecutor()
    reqs = [GemvRequest.pim(h, w, dt, fence=f, reshape=r, spec=sp)
            for sp in FACADE_SPECS
            for (h, w, dt, f, r) in FACADE_SHAPES]
    results = ex.run_many(reqs)
    planned = ex.plan_many(reqs)
    for p, res in zip(planned, results):
        ref = RefEngine(p.ctx.cyc, validate=False)
        ref_totals = [ref.run(s)[1] for s in p.streams]
        assert res.cycles == max(ref_totals), \
            f"facade/ref divergence for {p.req}"


def test_functional_batch_multi_spec():
    """Batched HW/SW co-simulation: one timing dispatch, every lane
    correct — y must equal W @ x for every item, across heterogeneous
    specs, and the batch must be bit-identical to the one-item path."""
    rng = np.random.default_rng(5)
    items = []
    for spec in (DEFAULT_SYSTEM, FACADE_SPECS[1]):
        for (h, w) in ((64, 512), (96, 700)):
            wts = rng.integers(-128, 128, size=(h, w)).astype(np.int32)
            x = rng.integers(-128, 128, size=(w,)).astype(np.int32)
            items.append(FunctionalGemv(wts, x, PimDType.W8A8, spec=spec))
    ex = PimExecutor()
    batched = ex.run_functional_many(items)
    for it, (y, res) in zip(items, batched):
        np.testing.assert_array_equal(
            y, it.weights.astype(np.int64) @ it.x.astype(np.int64))
        y1, res1 = ex.run_gemv_functional(it.weights, it.x, it.dtype,
                                          spec=it.spec)
        np.testing.assert_array_equal(y, y1)
        assert res.cycles == res1.cycles and res.energy == res1.energy


# ---------------------------------------------------------------------
# Golden parity: committed fixtures pin the PR-1 cycle/energy numbers
# ---------------------------------------------------------------------

GOLDEN_SPECS = {
    "lp5x-9600": DEFAULT_SYSTEM,
    "rcd24-mac2": SystemSpec(timings=LpddrTimings(tRCD=24.0),
                             pim=PimSpec(mac_interval_ck=2)),
}
GOLDEN_SHAPES = [("pim", 256, 1024, PimDType.W8A8, False, False),
                 ("pim", 512, 2048, PimDType.W8A16, True, False),
                 ("pim", 1024, 512, PimDType.W4A8, False, True),
                 ("base", 1024, 1024, PimDType.W8A8, False, False)]


def _golden_requests():
    return [(f"{sname}/{kind}-{h}x{w}-{dt.name}"
             + ("-fence" if f else "") + ("-reshape" if r else ""),
             GemvRequest.pim(h, w, dt, fence=f, reshape=r, spec=sp)
             if kind == "pim" else GemvRequest.baseline(h, w, dt, spec=sp))
            for sname, sp in GOLDEN_SPECS.items()
            for (kind, h, w, dt, f, r) in GOLDEN_SHAPES]


def _snapshot():
    labels, reqs = zip(*_golden_requests())
    results = PimExecutor().run_many(list(reqs))
    return {label: dict(cycles=res.cycles, ns=res.ns, flops=res.flops,
                        weight_bytes=res.weight_bytes,
                        utilization=res.utilization, split=res.split,
                        counts=[int(c) for c in res.counts],
                        energy=res.energy)
            for label, res in zip(labels, results)}


def test_golden_parity_exact():
    """Cycle/energy outputs for the fixed (spec x shape) grid are diffed
    EXACTLY against the committed fixture — any drift is a regression
    (regenerate deliberately with `python tests/test_conformance.py`)."""
    fixture = json.loads(GOLDEN.read_text())
    # JSON round-trip normalizes float repr on both sides of the diff.
    current = json.loads(json.dumps(_snapshot()))
    assert set(current) == set(fixture)
    for label in fixture:
        assert current[label] == fixture[label], \
            f"golden drift at {label}"


if __name__ == "__main__":          # regenerate the committed fixture
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(_snapshot(), indent=1, sort_keys=True))
    print(f"wrote {GOLDEN}")
