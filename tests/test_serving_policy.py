"""Adaptive offload controller + policy tests (planner-stub level).

The controller only needs a planner that yields ``OffloadDecision``s, so
everything here runs against a stub — no engine, no model — which is
what lets the hysteresis state machine be *fuzzed*: random site
crossovers, random occupancy traces, random (k, band) knobs, with the
policy's contract checked exhaustively per trace:

* per-site flips never exceed the trace's crossings of that site's
  threshold;
* flips committed inside the hysteresis band are further bounded by the
  K-consecutive-step rule (disjoint streak windows);
* every step outside the band decides identically to per-step
  recompute, and ``band=1.0`` collapses the whole policy to per-step.

When hypothesis is unavailable the fuzz test falls back to a
deterministic seeded corpus (CI runs both flavors), matching
``tests/test_conformance.py`` conventions.
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

import numpy as np

from repro.serving.offload import (GemvSite, OffloadDecision, offload_set,
                                   step_cost)
from repro.serving.policy import (HysteresisPolicy, OffloadController,
                                  POLICIES, StickyPolicy, make_policy)


class StubPlanner:
    """The minimal planner surface the controller depends on."""

    def __init__(self, decisions):
        self._decisions = list(decisions)
        self.plans = 0
        self.invalidations = 0

    def plan(self, fence=True, spec=None):
        self.plans += 1
        return list(self._decisions)

    def invalidate(self):
        self.invalidations += 1


def make_decisions(crossovers, counts=None):
    """One site per crossover batch; pim_ns fixed, host_ns = pim * b*."""
    decisions = []
    for i, c in enumerate(crossovers):
        pim = 100.0
        site = GemvSite(name=f"s{i}", h=1024, w=1024,
                        count=(counts or [1] * len(crossovers))[i])
        decisions.append(OffloadDecision(
            site=site, pim_ns=pim, host_ns=pim * c, reshape=False,
            offload_below_batch=max(1, int(c))))
    return decisions


def drive(decisions, batches, policy, **kw):
    controller = OffloadController(StubPlanner(decisions), policy=policy,
                                   **kw)
    for b in batches:
        controller.observe(int(b))
    return controller


# ---------------------------------------------------------------------
# Fuzzed hysteresis contract (shared by hypothesis and the corpus)
# ---------------------------------------------------------------------

def check_hysteresis_properties(crossovers, batches, k, band):
    decisions = make_decisions(crossovers)
    pol = HysteresisPolicy(k=k, band=band)
    controller = drive(decisions, batches, pol)
    T = len(batches)
    assert len(controller.set_log) == T

    flips: dict[str, list[int]] = {d.site.name: [] for d in decisions}
    for entry in controller.switch_log:
        for name in entry["on"] + entry["off"]:
            flips[name].append(entry["step"])

    for d in decisions:
        name = d.site.name
        desired = [d.offload_at(b) for b in batches]
        crossings = sum(1 for a, b in zip(desired, desired[1:]) if a != b)
        # (a) flips bounded by threshold crossings of the trace
        assert len(flips[name]) <= crossings, (name, flips, batches)
        # (b) in-band flips bounded by the disjoint K-window rule
        in_band_flips = [t for t in flips[name] if pol.in_band(
            d, batches[t])]
        assert len(in_band_flips) <= max(0, T - 1) // k, \
            (name, in_band_flips, batches)
        # (c) out-of-band steps decide exactly like per-step recompute
        for t, b in enumerate(batches):
            if not pol.in_band(d, b):
                assert (name in controller.set_log[t]) == desired[t], \
                    (name, t, b, batches)

    # switches are set-level changes; each needs at least one site flip
    assert controller.switches == len(controller.switch_log)
    assert controller.planner_queries == 1     # one startup derivation


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(
        crossovers=st.lists(
            st.integers(5, 100).map(lambda x: x / 10.0),
            min_size=1, max_size=6),
        batches=st.lists(st.integers(1, 12), min_size=1, max_size=80),
        k=st.integers(1, 5),
        band=st.sampled_from([1.0, 1.25, 1.5, 2.0]))
    def test_fuzzed_hysteresis_properties(crossovers, batches, k, band):
        check_hysteresis_properties(crossovers, batches, k, band)
else:                      # deterministic fallback when hypothesis absent
    @pytest.mark.parametrize("seed", range(12))
    def test_fuzzed_hysteresis_properties(seed):
        rng = np.random.default_rng(seed)
        crossovers = [float(x) / 10.0
                      for x in rng.integers(5, 101, rng.integers(1, 7))]
        batches = [int(b) for b in
                   rng.integers(1, 13, rng.integers(1, 81))]
        k = int(rng.integers(1, 6))
        band = float(rng.choice([1.0, 1.25, 1.5, 2.0]))
        check_hysteresis_properties(crossovers, batches, k, band)


def test_hysteresis_band_one_is_per_step():
    """band=1.0 empties the band: every step is 'outside' and the policy
    degenerates to per-step recompute, set for set."""
    decisions = make_decisions([1.8, 3.4, 6.2])
    batches = [1, 2, 5, 7, 2, 1, 8, 3, 3, 4, 6, 1]
    hyst = drive(decisions, batches, "hysteresis", k=4, band=1.0)
    per = drive(decisions, batches, "per-step")
    assert hyst.set_log == per.set_log
    assert hyst.report()["efficiency"] == 1.0


def test_hysteresis_converges_after_k_stable_steps():
    """Pure streak mode (huge band): after k same-side steps the state
    matches the oracle, however it oscillated before."""
    decisions = make_decisions([4.0])
    batches = [1, 8, 1, 8, 1, 8, 8, 8, 8]
    controller = drive(decisions, batches, "hysteresis", k=3, band=1e9)
    assert "s0" not in controller.set_log[-1]   # settled on host side
    oracle = offload_set(decisions, batches[-1])
    assert controller.set_log[-1] == oracle


def test_per_step_policy_is_oracle():
    decisions = make_decisions([1.5, 3.0, 5.5], counts=[2, 4, 1])
    batches = [1, 3, 6, 2, 8, 4, 1]
    controller = drive(decisions, batches, "per-step")
    rep = controller.report()
    assert rep["efficiency"] == 1.0
    assert rep["realized_speedup"] == rep["oracle_speedup"]
    assert rep["planner_queries"] == len(batches)
    for t, b in enumerate(batches):
        assert controller.set_log[t] == offload_set(decisions, b)


def test_sticky_replans_on_mean_drift():
    decisions = make_decisions([3.5])
    batches = [2] * 6 + [5] * 8        # slow shift past the crossover
    controller = drive(decisions, batches, "sticky",
                       jump=100.0, drift=0.75, min_epoch=3,
                       watch_lane_cache=False)
    rep = controller.report()
    assert rep["replans"] >= 1
    assert "s0" in controller.set_log[0]        # PIM wins at batch 2
    assert "s0" not in controller.set_log[-1]   # host wins at batch 5
    assert rep["planner_queries"] < rep["steps"]


def test_sticky_replans_on_jump():
    decisions = make_decisions([3.5])
    batches = [2, 2, 2, 8, 8, 8]
    controller = drive(decisions, batches, "sticky",
                       jump=2.0, drift=100.0, watch_lane_cache=False)
    assert controller.report()["replans"] == 1
    assert controller.set_log[3] == offload_set(decisions, 8)


def test_sticky_without_triggers_never_replans():
    decisions = make_decisions([3.5])
    controller = drive(decisions, [2, 3, 2, 3, 2, 3], "sticky",
                       jump=100.0, drift=100.0, watch_lane_cache=False)
    rep = controller.report()
    assert rep["replans"] == 0 and rep["planner_queries"] == 1


def test_controller_switch_log_names_flipped_sites():
    decisions = make_decisions([2.5, 6.0])
    controller = drive(decisions, [1, 8, 8, 8, 8], "hysteresis",
                       k=2, band=1.0)
    assert controller.switches == 1
    entry = controller.switch_log[0]
    assert entry["step"] == 1 and entry["batch"] == 8
    assert entry["off"] == ["s0", "s1"] and entry["on"] == []


def test_empty_controller_report_is_neutral():
    controller = OffloadController(StubPlanner(make_decisions([2.0])))
    rep = controller.report()
    assert rep["steps"] == 0
    assert rep["realized_speedup"] == rep["oracle_speedup"] == 1.0
    assert rep["efficiency"] == 1.0


def test_policy_factory_validation():
    assert set(POLICIES) == {"per-step", "hysteresis", "sticky"}
    with pytest.raises(ValueError, match="unknown offload policy"):
        make_policy("nope")
    with pytest.raises(ValueError):
        HysteresisPolicy(k=0)
    with pytest.raises(ValueError):
        HysteresisPolicy(band=0.5)
    assert isinstance(make_policy("sticky", drift=2.0), StickyPolicy)


def test_step_cost_and_offload_set_agree():
    """The shared decision API: the oracle set minimizes step_cost, and
    costing the empty set reproduces the host-only total."""
    decisions = make_decisions([1.2, 3.7, 8.0], counts=[3, 1, 2])
    for batch in (1, 2, 4, 7, 11):
        oracle = offload_set(decisions, batch)
        host, best = step_cost(decisions, batch, oracle)
        assert host == step_cost(decisions, batch, frozenset())[1]
        for other in (frozenset(), frozenset(d.site.name
                                             for d in decisions)):
            assert best <= step_cost(decisions, batch, other)[1] + 1e-12


def test_controller_replan_refresh_invalidates_planner():
    stub = StubPlanner(make_decisions([3.0]))
    controller = OffloadController(stub, policy="per-step")
    controller.observe(2)
    assert stub.plans == 1
    controller.replan(2, refresh=True)
    assert stub.invalidations == 1 and stub.plans == 2
    assert controller.replans == 1
