"""Mesh-sharded lane execution: differential parity battery.

The contract of ``engine.configure_lane_mesh``: resolving any fleet as
ONE shard_map program per bucketed slab over a 1-D ``lanes`` mesh is
*bit-identical* to the threaded multi-device dispatch and to the
single-device fallback — at every mesh size — with
``engine.compile_cache_size()`` independent of both the mesh size and
the number of ``SystemSpec`` variants.  Three layers:

1. *Engine* — fuzzed multi-spec fleets (hypothesis when available, a
   deterministic seeded corpus otherwise) resolved at mesh size 1
   in-process, and at mesh sizes {1, 2, 4} in a forced-4-host-device
   subprocess (the existing 4-device pattern), lane-exact against both
   fallback paths.
2. *Padding/masking* — for random lane counts and mesh sizes, the
   slab→shard padding (``engine._mesh_width``) always yields equal
   power-of-two per-shard buckets, and padded tail lanes never leak
   into results or the lane LRU.
3. *Serve cell* — the pinned golden serve trace replays byte-equal
   through ``replay_trace(..., mesh=...)`` (the mesh serve cell), and
   the facade (``run_many``) is result-identical under a mesh.

Plus the module-state regression: ``lane_devices()`` must track
``configure_lane_devices`` reconfiguration (the autouse conftest
fixture keeps per-test state clean; this asserts the tracking itself).
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

import jax

from repro.core import engine
from repro.core.timing import DEFAULT_SYSTEM, LpddrTimings, SystemSpec

from test_conformance import fleet_from_seed
from test_engine import build_valid_stream, random_op_tuples

GOLDEN = pathlib.Path(__file__).parent / "golden" / "serve_trace.json"


@pytest.fixture(autouse=True)
def _fresh_lane_cache():
    engine.configure_lane_cache(4096)
    engine.lane_cache_reset()
    yield
    engine.configure_lane_cache(4096)
    engine.lane_cache_reset()


def _local_mesh_size() -> int:
    """Largest mesh this process can build (1 under stock CPU tier-1,
    4 under the CI mesh job's forced host devices)."""
    return min(4, len(jax.devices()))


# ---------------------------------------------------------------------
# Engine layer: fuzzed parity at mesh size 1, in-process
# ---------------------------------------------------------------------

def assert_mesh_matches_fallbacks(points, mesh_size: int = 1):
    """Resolve one multi-spec fleet three ways; demand bit-identity."""
    pts = [(spec.derive_cycles(), streams) for spec, streams in points]
    engine.configure_lane_mesh(None)
    threaded = engine.resolve_fleet(pts)
    engine.lane_cache_clear()
    engine.configure_lane_devices(1)
    solo = engine.resolve_fleet(pts)
    engine.configure_lane_devices(None)
    engine.lane_cache_clear()
    with engine.lane_mesh_scope(mesh_size):
        meshed = engine.resolve_fleet(pts)
    for a, b, c in zip(threaded, solo, meshed):
        np.testing.assert_array_equal(a.totals, c.totals)
        np.testing.assert_array_equal(b.totals, c.totals)
        for ia, ic in zip(a.issue, c.issue):
            np.testing.assert_array_equal(ia, ic)


if HAVE_HYPOTHESIS:
    from test_conformance import _point_strategy

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(st.lists(_point_strategy(), min_size=1, max_size=3))
    def test_fuzzed_mesh_parity(points):
        assert_mesh_matches_fallbacks(points)
else:                      # deterministic fallback when hypothesis absent
    @pytest.mark.parametrize("seed", range(6))
    def test_fuzzed_mesh_parity(seed):
        assert_mesh_matches_fallbacks(fleet_from_seed(seed, n_points=3))


def test_mesh_compile_cache_spec_invariant():
    """Under a mesh, new SystemSpec variants on warmed shapes compile
    nothing — the traced-timing story survives shard_map.  (The fresh
    variants keep each point's bank count: num_banks is static metadata,
    so changing it is SUPPOSED to compile.)"""
    fleet = fleet_from_seed(17, n_points=3)
    points = [(sp.derive_cycles(), streams) for sp, streams in fleet]
    with engine.lane_mesh_scope(1):
        engine.resolve_fleet(points)                 # pay bucket compiles
        warm = engine.compile_cache_size()
        swapped = [
            (SystemSpec(timings=LpddrTimings(
                num_bankgroups=sp.timings.num_bankgroups,
                tRCD=26.0 + i)).derive_cycles(), streams)
            for i, (sp, streams) in enumerate(fleet)]
        engine.resolve_fleet(swapped)
        assert engine.compile_cache_size() == warm, \
            "spec variants recompiled under mesh"


# ---------------------------------------------------------------------
# Padding/masking properties
# ---------------------------------------------------------------------

def test_mesh_width_padding_properties():
    """For random (lane count, mesh size): the global width is a
    multiple of the mesh size, covers every lane, and every shard gets
    one identical power-of-two (>= 4) bucket — so ONE program shape per
    (banks, bucket) serves any mesh size."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        n = int(rng.integers(1, 700))
        m = int(rng.integers(1, 9))
        w = engine._mesh_width(n, m)
        per = w // m
        assert w % m == 0 and w >= n
        assert per >= 4 and per & (per - 1) == 0, (n, m, per)
        assert per == engine._fleet_bucket(-(-n // m))
        # padding is bounded: the per-shard bucket is < 2x the per-shard
        # lane share (except at the minimum bucket of 4)
        assert per == 4 or per < 2 * (-(-n // m)), (n, m, per)


@pytest.mark.parametrize("n_lanes", [1, 2, 3, 5, 7, 12, 19])
def test_padded_lanes_are_masked(n_lanes):
    """Random slab counts on the local mesh: per-lane results are an
    in-order match of the unpadded (threaded) resolve, and the padded
    tail rows never pollute totals or the lane LRU."""
    rng = np.random.default_rng(100 + n_lanes)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    lanes = [(cyc, build_valid_stream(random_op_tuples(rng, max_ops=25)))
             for _ in range(n_lanes)]
    keys = [("pad", n_lanes, i) for i in range(len(lanes))]
    plain = engine.resolve_lanes(lanes, keys=keys)
    engine.lane_cache_reset()                # reset counters + entries
    with engine.lane_mesh_scope(_local_mesh_size()):
        meshed = engine.resolve_lanes(lanes, keys=keys)
    info = engine.lane_cache_info()
    assert info["size"] <= len(lanes), \
        "padded tail rows leaked into the lane cache"
    for (ia, ta), (ib, tb) in zip(plain, meshed):
        assert ta == tb
        np.testing.assert_array_equal(ia, ib)


def test_mesh_handles_width_beyond_one_slab():
    """> _MAX_WIDTH x mesh lanes split into multiple shard_map slabs."""
    rng = np.random.default_rng(7)
    cyc = DEFAULT_SYSTEM.derive_cycles()
    base = build_valid_stream(random_op_tuples(rng, max_ops=12))
    # many distinct lanes in ONE length bucket: vary the (timing-inert)
    # column field so every lane has distinct bytes but equal length
    lanes = []
    for i in range(engine._MAX_WIDTH + 9):
        s = base.copy()
        s[:, 3] = i
        lanes.append((cyc, s))
    plain = engine.resolve_lanes(lanes, need_issue=False)
    engine.lane_cache_clear()
    with engine.lane_mesh_scope(_local_mesh_size()):
        meshed = engine.resolve_lanes(lanes, need_issue=False)
    assert [t for _i, t in plain] == [t for _i, t in meshed]


# ---------------------------------------------------------------------
# Forced 4-host-device subprocess: mesh sizes {1, 2, 4}
# ---------------------------------------------------------------------

_CHILD = r"""
import json, sys
import numpy as np
sys.path.insert(0, __TESTDIR__)

import jax
assert jax.device_count() == 4, jax.device_count()

from repro.core import engine
from repro.core.timing import DEFAULT_SYSTEM, LpddrTimings, SystemSpec
from test_conformance import fleet_from_seed
from test_engine import build_valid_stream, random_op_tuples

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    from test_conformance import _point_strategy
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

engine.configure_lane_cache(0)           # measure real resolution

MESH_SIZES = (1, 2, 4)


def check(points):
    pts = [(sp.derive_cycles(), streams) for sp, streams in points]
    engine.configure_lane_mesh(None)
    engine.configure_lane_devices(1)
    solo = engine.resolve_fleet(pts)
    engine.configure_lane_devices(None)
    threaded = engine.resolve_fleet(pts)
    for m in MESH_SIZES:
        with engine.lane_mesh_scope(m):
            meshed = engine.resolve_fleet(pts)
        for a, b, c in zip(solo, threaded, meshed):
            np.testing.assert_array_equal(a.totals, c.totals)
            np.testing.assert_array_equal(b.totals, c.totals)
            for ia, ic in zip(a.issue, c.issue):
                np.testing.assert_array_equal(ia, ic)


# Compile-cache flatness FIRST, while every per-mesh resolver is cold:
# resolving the SAME fleet at every mesh size compiles the SAME number
# of executables (per-shard width bucketing), and swapping in new spec
# variants — same bank counts, new timings — compiles nothing at any
# size.
fleet = fleet_from_seed(23, n_points=4)
points = [(sp.derive_cycles(), streams) for sp, streams in fleet]
deltas = {}
for m in MESH_SIZES:
    with engine.lane_mesh_scope(m):
        before = engine.compile_cache_size()
        engine.resolve_fleet(points)
        deltas[m] = engine.compile_cache_size() - before
        warm = engine.compile_cache_size()
        swapped = [
            (SystemSpec(timings=LpddrTimings(
                num_bankgroups=sp.timings.num_bankgroups,
                tRCD=27.0 + m + i)).derive_cycles(), streams)
            for i, (sp, streams) in enumerate(fleet)]
        engine.resolve_fleet(swapped)
        assert engine.compile_cache_size() == warm, \
            f"spec variants recompiled at mesh {m}"
assert len(set(deltas.values())) == 1, \
    f"compile count depends on mesh size: {deltas}"

if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(st.lists(_point_strategy(), min_size=1, max_size=3))
    def fuzz(points):
        check(points)
    fuzz()
else:
    for seed in range(5):
        check(fleet_from_seed(seed, n_points=3))

# Padding property across mesh sizes: random slab counts, in-order
# equality with the unpadded threaded resolve.
rng = np.random.default_rng(5)
cyc = DEFAULT_SYSTEM.derive_cycles()
for n in (1, 2, 5, 9, 17):
    lanes = [(cyc, build_valid_stream(random_op_tuples(rng, max_ops=20)))
             for _ in range(n)]
    engine.configure_lane_mesh(None)
    plain = engine.resolve_lanes(lanes)
    for m in MESH_SIZES:
        with engine.lane_mesh_scope(m):
            meshed = engine.resolve_lanes(lanes)
        for (ia, ta), (ib, tb) in zip(plain, meshed):
            assert ta == tb, (n, m)
            np.testing.assert_array_equal(ia, ib)

print(json.dumps({"ok": True, "hypothesis": HAVE_HYPOTHESIS,
                  "compiles_per_mesh": deltas[4]}))
"""


def test_mesh_parity_forced_four_devices():
    """Forced 4-host-device child: fuzzed fleets bit-identical across
    mesh sizes {1, 2, 4} vs both fallback paths, compile count
    mesh-size- and spec-variant-invariant, padding masked."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _CHILD.replace("__TESTDIR__", repr(os.path.dirname(__file__)))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]


# ---------------------------------------------------------------------
# Serve cell: golden trace replay + facade parity under a mesh
# ---------------------------------------------------------------------

def test_golden_trace_replays_bit_identically_on_mesh():
    """The pinned serve trace, replayed through the mesh serve cell, is
    byte-equal to the recording — scheduling, offload sets, telemetry
    and realized speedup included.  Runs at mesh size 1 under stock
    tier-1 and at mesh size 4 under the CI mesh job's forced devices."""
    from repro.configs import ARCHS, smoke_config
    from repro.models import model as M
    from repro.serving.offload import OffloadPlanner
    from repro.serving.scenarios import replay_trace

    fixture = json.loads(GOLDEN.read_text())
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    planner = OffloadPlanner(ARCHS["granite-8b"])
    mesh = _local_mesh_size()
    fresh = json.loads(json.dumps(
        replay_trace(fixture, cfg, params, planner, mesh=mesh)))
    assert engine.lane_mesh() is None, "mesh scope must not leak"
    assert set(fresh) == set(fixture)
    for key in fixture:
        assert fresh[key] == fixture[key], \
            f"mesh replay drift at {key} (mesh={mesh})"


def test_run_many_identical_under_mesh():
    """Facade layer: a heterogeneous (spec x shape) run_many grid under
    a mesh matches the threaded resolution field by field."""
    from repro.pimkernel.executor import GemvRequest, PimExecutor
    from repro.pimkernel.tileconfig import PimDType

    specs = [DEFAULT_SYSTEM,
             SystemSpec(timings=LpddrTimings(tRCD=24.0, tRP=22.0))]
    reqs = [r for sp in specs
            for r in (GemvRequest.pim(256, 1024, PimDType.W8A8, spec=sp),
                      GemvRequest.pim(512, 512, PimDType.W4A8, fence=True,
                                      spec=sp),
                      GemvRequest.baseline(256, 1024, PimDType.W8A8,
                                           spec=sp))]
    plain = PimExecutor().run_many(reqs)
    engine.lane_cache_clear()
    with engine.lane_mesh_scope(_local_mesh_size()):
        meshed = PimExecutor().run_many(reqs)
    for a, b in zip(plain, meshed):
        assert a.cycles == b.cycles and a.ns == b.ns
        assert a.energy == b.energy
        np.testing.assert_array_equal(a.counts, b.counts)


# ---------------------------------------------------------------------
# Module-state hygiene (the sticky configure_lane_devices regression)
# ---------------------------------------------------------------------

def test_lane_devices_tracks_reconfiguration():
    """lane_devices() follows configure_lane_devices immediately — a
    forced cap does not stick once reset to None (the autouse fixture
    in conftest.py relies on exactly this)."""
    all_devs = jax.devices()
    assert engine.lane_devices() == all_devs[:len(engine.lane_devices())]
    engine.configure_lane_devices(1)
    assert engine.lane_devices() == all_devs[:1]
    engine.configure_lane_devices(None)
    default = engine.lane_devices()
    n_env = int(os.environ.get("REPRO_LANE_DEVICES", "0") or 0)
    expect = all_devs[:n_env] if n_env else all_devs
    assert default == expect, "configure_lane_devices(None) stuck"


def test_configure_lane_mesh_validation_and_scope():
    devs = jax.devices()
    with pytest.raises(ValueError, match="lane mesh size"):
        engine.configure_lane_mesh(0)
    with pytest.raises(ValueError, match="lane mesh size"):
        engine.configure_lane_mesh(len(devs) + 1)
    from jax.sharding import Mesh
    if len(devs) >= 2:
        two_d = Mesh(np.array(devs[:2]).reshape(2, 1), ("a", "b"))
        with pytest.raises(ValueError, match="1-D"):
            engine.configure_lane_mesh(two_d)
    # the scope restores the previous backend even on exceptions
    assert engine.lane_mesh() is None
    with pytest.raises(RuntimeError, match="boom"):
        with engine.lane_mesh_scope(1):
            assert engine.lane_mesh() is not None
            raise RuntimeError("boom")
    assert engine.lane_mesh() is None
    # nested scopes restore the outer mesh, not None
    with engine.lane_mesh_scope(1):
        outer = engine.lane_mesh()
        with engine.lane_mesh_scope(None):
            assert engine.lane_mesh() is None
        assert engine.lane_mesh() is outer
