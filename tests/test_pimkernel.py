"""PIM Kernel software-layer tests: address-mapping bijectivity, Data
Mapper pack/unpack round trip, codegen, end-to-end behavioral fidelity
(command streams interpreted by the device model == numpy GEMV)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

from repro.core.pimsim import PimSimulator
from repro.core.timing import DEFAULT_SYSTEM, PimSpec, SystemSpec
from repro.pimkernel import addrmap, codegen
from repro.pimkernel.datamapper import DataMapper
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType, TileConfig

SPEC = DEFAULT_SYSTEM


# --- address mapping ----------------------------------------------------

def test_block_id_bijection():
    n = addrmap.num_blocks(SPEC)
    seen = set()
    for blk in range(n):
        ch, rank, bank = addrmap.block_of(blk, SPEC)
        assert addrmap.block_id_of(ch, rank, bank, SPEC) == blk
        seen.add((ch, rank, bank))
    assert len(seen) == n


def test_vertical_mapping_channel_first():
    """Consecutive h-tiles rotate channels first (paper §2.3)."""
    chans = [addrmap.block_of(i, SPEC)[0] for i in range(8)]
    assert chans[:4] == [0, 1, 2, 3]


def _check_tile_addresses_disjoint(h_tile, w_tile, n_wtiles, split):
    """Two distinct tiles never share (block, offset)."""
    if w_tile >= n_wtiles:
        w_tile = w_tile % n_wtiles
    tb = 4096
    a = addrmap.tile_address(h_tile, w_tile, n_wtiles, tb, SPEC, split)
    b = addrmap.tile_address(h_tile, (w_tile + 1) % n_wtiles, n_wtiles,
                             tb, SPEC, split)
    if n_wtiles > 1:
        assert (a.channel, a.rank, a.bank, a.byte_offset) != \
            (b.channel, b.rank, b.bank, b.byte_offset)


# --- tile config --------------------------------------------------------

def test_tile_shapes_match_paper_grouping():
    pim = SPEC.pim
    tw = {d: TileConfig.make(d, pim).t_w for d in ALL_DTYPES}
    large = [PimDType.W8A8, PimDType.W4A4, PimDType.FP_W8A8]
    small = [PimDType.W8A16, PimDType.W4A16, PimDType.FP_W8A16]
    assert min(tw[d] for d in large) > max(tw[d] for d in small)
    assert all(TileConfig.make(d, pim).t_h == pim.acc_regs
               for d in ALL_DTYPES)


# --- data mapper --------------------------------------------------------

@pytest.mark.parametrize("dtype", [PimDType.W8A8, PimDType.W4A16],
                         ids=lambda d: d.name)
@pytest.mark.parametrize("reshape", [False, True])
def test_pack_unpack_roundtrip(dtype, reshape):
    rng = np.random.default_rng(1)
    H, W = 200, 1500
    m = 2 ** (dtype.w_bits - 1) - 1
    w = rng.integers(-m - 1, m + 1, size=(H, W)).astype(np.int32)
    dm = DataMapper(SPEC)
    layout = dm.layout(H, W, dtype, reshape=reshape)
    dram = dm.pack(layout, w)
    back = dm.unpack(layout, dram)
    assert np.array_equal(back[:H, :W], w)
    assert (back[H:, :] == 0).all() and (back[:, W:] == 0).all()


def _check_layout_covers_all_tiles(h, w, di, reshape):
    """Every tile is placed exactly once; utilization in (0, 1]."""
    dm = DataMapper(SPEC)
    layout = dm.layout(h, w, ALL_DTYPES[di], reshape=reshape)
    seen = set()
    for ht in range(layout.n_htiles):
        for g in range(layout.split):
            logical = layout.logical_of(ht, g)
            rnd, loc = layout.place(logical)
            for c in range(layout.group_w):
                wt = layout.w_tile_at(g, c)
                if wt is not None:
                    key = (loc, layout.chunk_offset(rnd, c))
                    assert key not in seen
                    seen.add(key)
                    assert 0 <= wt < layout.n_wtiles
    n_assigned = len({(layout.logical_of(ht, g), c)
                      for ht in range(layout.n_htiles)
                      for g in range(layout.split)
                      for c in range(layout.group_w)
                      if layout.w_tile_at(g, c) is not None})
    assert n_assigned == layout.n_htiles * layout.n_wtiles
    assert 0 < layout.utilization <= 1.0


def test_reshape_activates_more_blocks():
    dm = DataMapper(SPEC)
    l0 = dm.layout(512, 4096, PimDType.W8A8, reshape=False)
    l1 = dm.layout(512, 4096, PimDType.W8A8, reshape=True)
    assert l1.split > 1
    assert l1.utilization > l0.utilization


# --- codegen ------------------------------------------------------------

def test_irf_program_fits_and_covers():
    for d in ALL_DTYPES:
        tc = TileConfig.make(d, SPEC.pim)
        prog = codegen.synthesize(tc, SPEC.pim)
        assert len(prog) <= SPEC.pim.irf_entries
        assert prog.acc_idx.shape[0] == tc.macs_per_tile
        assert prog.acc_idx.max() == tc.t_h - 1
        assert prog.srf_off.max() <= tc.t_w - prog.n_elems


def test_fp8_encode_decode_roundtrip():
    codes = np.arange(256, dtype=np.uint8)
    vals = codegen._fp8_decode(codes)
    finite = np.isfinite(vals)
    back = codegen._fp8_encode(vals[finite])
    np.testing.assert_array_equal(codegen._fp8_decode(back), vals[finite])


# --- end-to-end behavioral fidelity ------------------------------------

@pytest.mark.parametrize("dtype", ALL_DTYPES, ids=lambda d: d.name)
def test_hwsw_cosim_matches_numpy(dtype):
    """Command stream -> device interpreter == numpy GEMV (paper's
    'consistent behavioral accuracy')."""
    rng = np.random.default_rng(42)
    H, W = 160, 1200
    sim = PimSimulator()
    if dtype.is_fp:
        wmat = rng.integers(0, 256, size=(H, W)).astype(np.uint8)
        x = (rng.standard_normal(W)).astype(np.float32)
        y, res = sim.gemv_functional(wmat, x, dtype)
        wd = codegen._fp8_decode(wmat).astype(np.float64)
        xs = codegen.decode_srf(codegen.encode_acts(x, dtype), dtype)
        ref = wd @ xs[:W].astype(np.float64)
        np.testing.assert_allclose(y, ref, rtol=1e-9, atol=1e-6)
    else:
        wm = 2 ** (dtype.w_bits - 1) - 1
        am = 2 ** (min(dtype.a_bits, 8) - 1) - 1
        wmat = rng.integers(-wm - 1, wm + 1, size=(H, W)).astype(np.int32)
        x = rng.integers(-am - 1, am + 1, size=(W,)).astype(np.int32)
        y, res = sim.gemv_functional(wmat, x, dtype)
        assert np.array_equal(y, wmat.astype(np.int64) @ x.astype(np.int64))
    assert res.cycles > 0


@pytest.mark.parametrize("reshape", [False, True])
@pytest.mark.parametrize("fence", [False, True])
def test_cosim_reshape_fence_variants(reshape, fence):
    rng = np.random.default_rng(7)
    H, W = 96, 2048
    sim = PimSimulator()
    wmat = rng.integers(-128, 128, size=(H, W)).astype(np.int32)
    x = rng.integers(-128, 128, size=(W,)).astype(np.int32)
    y, res = sim.gemv_functional(wmat, x, PimDType.W8A8,
                                 reshape=reshape, fence=fence)
    assert np.array_equal(y, wmat.astype(np.int64) @ x.astype(np.int64))
    if reshape:
        assert res.split > 1


def _check_cosim_random_geometry(h, w, reshape):
    rng = np.random.default_rng(h * 10007 + w)
    sim = PimSimulator()
    wmat = rng.integers(-128, 128, size=(h, w)).astype(np.int32)
    x = rng.integers(-128, 128, size=(w,)).astype(np.int32)
    y, _ = sim.gemv_functional(wmat, x, PimDType.W8A8, reshape=reshape)
    assert np.array_equal(y, wmat.astype(np.int64) @ x.astype(np.int64))


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(h_tile=st.integers(0, 300), w_tile=st.integers(0, 60),
           n_wtiles=st.integers(1, 61), split=st.integers(1, 4))
    def test_tile_addresses_disjoint(h_tile, w_tile, n_wtiles, split):
        _check_tile_addresses_disjoint(h_tile, w_tile, n_wtiles, split)

    @settings(max_examples=20, deadline=None)
    @given(h=st.integers(1, 400), w=st.integers(1, 3000),
           di=st.integers(0, len(ALL_DTYPES) - 1), reshape=st.booleans())
    def test_layout_covers_all_tiles(h, w, di, reshape):
        _check_layout_covers_all_tiles(h, w, di, reshape)

    @settings(max_examples=10, deadline=None)
    @given(h=st.integers(1, 200), w=st.integers(1, 1200),
           reshape=st.booleans())
    def test_cosim_random_geometry(h, w, reshape):
        _check_cosim_random_geometry(h, w, reshape)
else:                      # deterministic fallback when hypothesis absent
    def test_tile_addresses_disjoint():
        rng = np.random.default_rng(11)
        for _ in range(40):
            _check_tile_addresses_disjoint(
                int(rng.integers(0, 301)), int(rng.integers(0, 61)),
                int(rng.integers(1, 62)), int(rng.integers(1, 5)))

    def test_layout_covers_all_tiles():
        rng = np.random.default_rng(12)
        for _ in range(12):
            _check_layout_covers_all_tiles(
                int(rng.integers(1, 401)), int(rng.integers(1, 3001)),
                int(rng.integers(0, len(ALL_DTYPES))),
                bool(rng.integers(0, 2)))

    def test_cosim_random_geometry():
        rng = np.random.default_rng(13)
        for _ in range(6):
            _check_cosim_random_geometry(
                int(rng.integers(1, 201)), int(rng.integers(1, 1201)),
                bool(rng.integers(0, 2)))
