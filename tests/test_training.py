"""Training substrate: optimizer, checkpoint (atomic/async/elastic),
fault tolerance, gradient compression, end-to-end loss descent."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.training import checkpoint as CKPT
from repro.training.fault import (HeartbeatMonitor, StragglerDetector,
                                  elastic_plan)
from repro.training.grad_compress import (CompressionConfig,
                                          apply_with_error_feedback,
                                          compress_decompress,
                                          init_error_state)
from repro.training.optimizer import (adamw_init, adamw_update,
                                      cosine_schedule, global_norm)
from repro.training.trainer import TrainConfig, Trainer


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    opt = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(params, g, opt, lr=5e-2,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), 1e-3, 10, 100))
           for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]           # warmup
    assert lrs[-1] < max(lrs)        # decay
    assert min(lrs[2:]) >= 1e-4 - 1e-9


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    CKPT.save(tmp_path, 7, tree, extra={"note": "x"})
    CKPT.save(tmp_path, 9, tree)
    assert CKPT.latest_step(tmp_path) == 9
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    restored, manifest = CKPT.restore(tmp_path, like, step=7)
    assert manifest["extra"]["note"] == "x"
    np.testing.assert_array_equal(restored["a"], tree["a"])
    # no tmp dirs left behind
    assert not list(tmp_path.glob("*.tmp"))


def test_async_checkpointer_gc(tmp_path):
    ck = CKPT.AsyncCheckpointer(tmp_path, keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_elastic_restore_across_mesh(tmp_path):
    """Checkpoint written replicated restores onto a sharded layout."""
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0)}
    CKPT.save(tmp_path, 1, tree)
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    like = {"w": jax.ShapeDtypeStruct((16,), jnp.float32)}
    restored, _ = CKPT.restore(tmp_path, like, shardings={"w": sh})
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])


def test_heartbeat_and_elastic_plan():
    t = [0.0]
    mon = HeartbeatMonitor(8, timeout_s=10.0, clock=lambda: t[0])
    for h in range(8):
        mon.beat(h)
    t[0] = 8.0
    for h in range(8):
        if h != 3:
            mon.beat(h)
    t[0] = 16.0
    dead = mon.sweep()
    assert dead == [3]
    plan = elastic_plan(mon.alive_hosts, devices_per_host=4,
                        model_parallel=4, global_batch=256,
                        latest_ckpt=120)
    assert plan.n_hosts == 7
    assert plan.data_parallel == 7
    assert (256 - plan.drop_batch) % plan.data_parallel == 0
    assert plan.restore_step == 120


def test_straggler_detector():
    det = StragglerDetector(window=16, threshold=2.0)
    flagged = []
    for step in range(40):
        dt = 1.0 if step % 13 else 5.0   # periodic slow step
        if det.observe(step, dt):
            flagged.append(step)
    assert flagged and det.advice() in ("transient", "persistent")


def test_grad_compression_error_feedback_converges():
    """int8+topk with error feedback still drives a quadratic to zero."""
    params = {"w": jnp.linspace(-2, 2, 64)}
    opt = adamw_init(params)
    err = init_error_state(params)
    cfg = CompressionConfig("int8+topk", topk_frac=0.25)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        g, err = apply_with_error_feedback(g, err, cfg)
        params, opt = adamw_update(params, g, opt, lr=3e-2,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_compression_is_lossy_but_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    out = compress_decompress(g, CompressionConfig("int8"))
    rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
    assert 0 < rel < 0.02


def test_trainer_loss_decreases(tmp_path):
    cfg = smoke_config(ARCHS["granite-8b"])
    tcfg = TrainConfig(lr=3e-3, warmup=5, total_steps=60, microbatches=2,
                       ckpt_every=25, ckpt_dir=str(tmp_path), remat=False)
    trainer = Trainer(cfg, tcfg)
    src = SyntheticLM(cfg.vocab, seed=0)

    def batches():
        step = 0
        while True:
            yield {k: jnp.asarray(v)
                   for k, v in src.batch(step, 8, 32).items()}
            step += 1

    hist = trainer.train(batches(), steps=50, log_every=1000)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)
    # checkpoint/restart: a fresh trainer restores the saved state
    trainer.ckpt.wait()
    t2 = Trainer(cfg, tcfg)
    assert t2.restore_latest()
    assert t2.step == 50
