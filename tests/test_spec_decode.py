"""Speculative decoding: the differential draft/verify battery.

The accept/advance round math is specified ONCE, model-free, in
``serving/scenarios.py`` (``SpecDecodeConfig`` / ``simulate_spec_decode``);
``serving/engine.py`` and the disagg decode cell are the independent
real-model implementations.  This suite holds all three together and
pins the speculative serve stack end to end:

1. *Round-math properties* — hypothesis-fuzzed (deterministic seeded
   corpus when hypothesis is absent, matching CI's two-job matrix):
   token conservation under accept/reject (advances sum exactly to each
   request's decode budget), no slot leak across draft truncations,
   ``acceptance=0`` degenerates to vanilla decode tick-exactly,
   ``acceptance=1`` never re-decodes a token.
2. *Engine/cells vs mirror parity* — the real monolithic engine and the
   disagg cell pair, serving speculatively with actual model decode,
   match ``simulate_spec_decode`` / ``simulate_disagg(spec_decode=)``
   tick-exactly on batches, round telemetry and completions; greedy
   speculative token streams are byte-equal to a vanilla run.
3. *Boundaries* — ``draft_len=1``, a single-slot engine, zero-request
   runs neutral everywhere, and a chaos run (seeded fault timeline)
   that completes with byte-parity on every non-chaos trace key.
4. *Golden fixture* — one speculative serve's full telemetry is pinned
   byte-exactly in ``tests/golden/spec_decode_trace.json`` and must
   replay identically across ``{scan, pallas}`` lane backends and mesh
   sizes ``{1, 2}``; regenerate deliberately with
   ``python tests/test_spec_decode.py``.
5. *Registries, draft lanes, spec families* — ``resolve_scenario`` /
   ``resolve_policy`` aliasing + error menus, the draft-lane MRU
   eviction shield (``engine.lane_cache_touch`` via
   ``OffloadPlanner.touch_draft``), the draft/verify economics model,
   and the heterogeneous ``configs/specfam.py`` populations resolved
   bit-exactly in one batched ``run_many``.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

from repro.configs import ARCHS, smoke_config
from repro.configs.specfam import SPEC_FAMILIES
from repro.core import engine
from repro.kernels import lane_scan
from repro.models import model as M
from repro.pimkernel.executor import GemvRequest, PimExecutor
from repro.pimkernel.tileconfig import PimDType
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import (OffloadPlanner, decode_gemv_sites,
                                   draft_gemv_sites)
from repro.serving.policy import make_policy, resolve_policy
from repro.serving.scenarios import (SCENARIOS, DisaggConfig, ScenarioSpec,
                                     SpecDecodeConfig, assign_slo,
                                     make_scenario, replay_batches,
                                     replay_trace, resolve_scenario,
                                     run_scenario, simulate_batches,
                                     simulate_disagg, simulate_spec_decode)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
SPEC_GOLDEN = GOLDEN_DIR / "spec_decode_trace.json"

GOLDEN_SCENARIO = dict(name="spec-decode", seed=5, slots=4, quick=True)
GOLDEN_POLICY = "hysteresis"
GOLDEN_SD = SpecDecodeConfig(draft_len=3, acceptance=0.6, seed=11)


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_state():
    # This module compiles many fresh (slots, max_seq, prompt) engine
    # variants near the END of a full tier-1 session; on a long-lived
    # single process the accumulated XLA executables can crash the CPU
    # compiler outright (segfault in backend_compile).  Dropping the
    # executable caches here costs a few recompiles and keeps the
    # compiler healthy for the battery.
    jax.clear_caches()
    yield


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def planner():
    return OffloadPlanner(ARCHS["mamba2-130m"])


# ---------------------------------------------------------------------
# 1. Round math: config validation + fuzzed schedule invariants
# ---------------------------------------------------------------------

def test_spec_decode_config_validation():
    with pytest.raises(ValueError, match="draft_len"):
        SpecDecodeConfig(draft_len=0)
    with pytest.raises(ValueError, match="acceptance"):
        SpecDecodeConfig(acceptance=-0.1)
    with pytest.raises(ValueError, match="acceptance"):
        SpecDecodeConfig(acceptance=1.5)
    with pytest.raises(ValueError, match="seed"):
        SpecDecodeConfig(seed=-1)
    rec = json.loads(json.dumps(GOLDEN_SD.to_record()))
    assert SpecDecodeConfig.from_record(rec) == GOLDEN_SD


def test_advance_bounds_and_determinism():
    sd = SpecDecodeConfig(draft_len=4, acceptance=0.5, seed=3)
    for rid in range(5):
        for rnd in range(5):
            for rem in range(1, 8):
                adv, drafted, acc = sd.advance(rid, rnd, rem)
                assert drafted == min(sd.draft_len, rem - 1)
                assert 0 <= acc <= drafted
                assert adv == acc + 1
                assert 1 <= adv <= rem          # never overshoots budget
                assert (adv, drafted, acc) == sd.advance(rid, rnd, rem)


def test_acceptance_draw_keyed_per_request_round():
    """The schedule is keyed (seed, rid, round) — independent of slot
    order and of who shares the batch, which is what lets the mirror and
    both engines agree without coordinating iteration order."""
    sd = SpecDecodeConfig(draft_len=6, acceptance=0.5, seed=7)
    a = [sd.accepted(rid, rnd) for rid in range(4) for rnd in range(4)]
    b = [sd.accepted(rid, rnd) for rnd in range(4) for rid in range(4)]
    assert sorted(a) == sorted(b)
    assert a == [sd.accepted(rid, rnd)
                 for rid in range(4) for rnd in range(4)]
    # different seeds give different schedules somewhere
    sd2 = SpecDecodeConfig(draft_len=6, acceptance=0.5, seed=8)
    assert any(sd.accepted(r, n) != sd2.accepted(r, n)
               for r in range(4) for n in range(4))


def _assert_spec_invariants(spec: ScenarioSpec, sd: SpecDecodeConfig):
    sim = simulate_spec_decode(spec, sd)
    rids = {a.rid for a in spec.arrivals}
    budget = {a.rid: a.decode_steps() for a in spec.arrivals}
    # token conservation: each round advances accepted+1, so a request's
    # total advance is rounds[r] + accepted[r] and must equal its decode
    # budget exactly — accept/reject moves ticks, never token counts
    for r in rids:
        assert sim["rounds"][r] + sim["accepted"][r] == budget[r], r
        assert sim["accepted"][r] <= sim["drafted"][r], r
        assert sim["wasted"][r] == sim["drafted"][r] - sim["accepted"][r]
        assert sim["wasted"][r] >= 0, r
    assert sum(sim["per_tick_advance"]) == sum(budget.values())
    # no slot leak: every active slot runs exactly one round per tick,
    # so occupancy integrates to the global round count; truncated
    # drafts (drafted < draft_len near the budget) cannot hold a slot
    # past completion
    assert sum(sim["per_tick_batch"]) == sum(sim["rounds"].values())
    assert set(sim["completion_ticks"]) == rids
    assert all(0 <= b <= spec.slots for b in sim["per_tick_batch"])
    assert len(sim["per_tick_batch"]) == len(sim["per_tick_advance"]) \
        == len(sim["per_tick_substeps"])
    # sub-steps bound the per-slot advance: 1 <= substep <= draft_len+1
    for b, s in zip(sim["per_tick_batch"], sim["per_tick_substeps"]):
        if b > 0:
            assert 1 <= s <= sd.draft_len + 1
        else:
            assert s == 0
    # degenerate acceptance endpoints
    if sd.acceptance == 0.0:
        assert sim["per_tick_batch"] == simulate_batches(spec)
        assert all(w == d for w, d in zip(sim["wasted"].values(),
                                          sim["drafted"].values()))
    if sd.acceptance == 1.0:
        assert all(w == 0 for w in sim["wasted"].values())


def _corpus_case(seed: int):
    rng = np.random.default_rng(2000 + seed)
    name = sorted(SCENARIOS)[seed % len(SCENARIOS)]
    spec = make_scenario(name, seed=int(rng.integers(0, 1000)),
                         slots=int(rng.integers(1, 6)), quick=True)
    sd = SpecDecodeConfig(
        draft_len=int(rng.integers(1, 7)),
        acceptance=float(rng.choice([0.0, 1.0, float(rng.random())])),
        seed=int(rng.integers(0, 1000)))
    return spec, sd


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(name=st.sampled_from(sorted(SCENARIOS)),
           seed=st.integers(0, 10_000), slots=st.integers(1, 6),
           draft_len=st.integers(1, 6),
           acceptance=st.one_of(st.just(0.0), st.just(1.0),
                                st.floats(0.0, 1.0)),
           sd_seed=st.integers(0, 10_000))
    def test_fuzzed_spec_decode_invariants(name, seed, slots, draft_len,
                                           acceptance, sd_seed):
        spec = make_scenario(name, seed=seed, slots=slots, quick=True)
        _assert_spec_invariants(spec, SpecDecodeConfig(
            draft_len=draft_len, acceptance=acceptance, seed=sd_seed))
else:                      # deterministic fallback when hypothesis absent
    @pytest.mark.parametrize("seed", range(15))
    def test_fuzzed_spec_decode_invariants(seed):
        _assert_spec_invariants(*_corpus_case(seed))


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_acceptance_zero_is_vanilla_tick_exact(name):
    """acceptance=0 accepts nothing: every round advances exactly the
    verify token, so the speculative schedule IS the vanilla schedule."""
    spec = make_scenario(name, seed=6, slots=3, quick=True)
    sim = simulate_spec_decode(spec, SpecDecodeConfig(acceptance=0.0))
    assert sim["per_tick_batch"] == simulate_batches(spec)
    assert sim["per_tick_advance"] == sim["per_tick_batch"]
    assert all(s <= 1 for s in sim["per_tick_substeps"])


def test_acceptance_one_never_redecodes():
    spec = make_scenario("spec-decode", seed=2, slots=4, quick=True)
    sd = SpecDecodeConfig(draft_len=4, acceptance=1.0)
    sim = simulate_spec_decode(spec, sd)
    assert all(w == 0 for w in sim["wasted"].values())
    # full drafts advance draft_len+1 per round except the budget tail
    assert len(sim["per_tick_batch"]) < len(simulate_batches(spec))


# ---------------------------------------------------------------------
# 2. Engines vs the mirror: tick parity + vanilla-equal token streams
# ---------------------------------------------------------------------

def _spec_trace_matches_sim(trace: dict, spec, sd):
    sim = simulate_spec_decode(spec, sd)
    assert trace["per_tick_batch"] == sim["per_tick_batch"]
    rec = trace["spec_decode"]
    assert rec["config"] == sd.to_record()
    assert rec["rounds"] == sum(sim["rounds"].values())
    assert rec["drafted"] == sum(sim["drafted"].values())
    assert rec["accepted"] == sum(sim["accepted"].values())
    assert rec["wasted"] == sum(sim["wasted"].values())
    assert rec["substeps"] == sum(sim["per_tick_substeps"])
    # the engine only appends advance telemetry on stepped ticks
    assert rec["per_tick_advance"] == [
        a for b, a in zip(sim["per_tick_batch"], sim["per_tick_advance"])
        if b > 0]


@pytest.mark.parametrize("seed,draft_len,acceptance", [
    (0, 4, 0.7), (1, 1, 0.5), (2, 3, 0.0), (3, 5, 1.0), (4, 2, 0.25),
])
def test_engine_matches_simulator(small_lm, planner, seed, draft_len,
                                  acceptance):
    """The real engine serving speculatively is tick-exact against the
    model-free mirror on every seeded acceptance schedule — batches,
    round/draft/accept/waste counters, per-tick advance, sub-steps."""
    cfg, params = small_lm
    spec = make_scenario("spec-decode", seed=seed, slots=3, quick=True)
    sd = SpecDecodeConfig(draft_len=draft_len, acceptance=acceptance,
                          seed=seed)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step",
                         spec_decode=sd)
    _spec_trace_matches_sim(trace, spec, sd)


def test_spec_token_streams_equal_vanilla(small_lm):
    """Greedy speculative decoding is output-identical to greedy vanilla
    decode: the same requests served with and without spec_decode emit
    byte-equal token streams, and the speculative engine's completions
    match the mirror's ticks."""
    cfg, params = small_lm
    spec = make_scenario("spec-decode", seed=1, slots=3, quick=True)
    sd = SpecDecodeConfig(draft_len=4, acceptance=0.7, seed=9)
    max_seq = max(64, 2 * max(a.prompt_len + a.max_new
                              for a in spec.arrivals))

    def reqs():
        rng = np.random.default_rng(spec.seed + 1)
        return {a.rid: Request(rid=a.rid,
                               prompt=rng.integers(0, cfg.vocab,
                                                   size=a.prompt_len),
                               max_new=a.max_new) for a in spec.arrivals}

    van = ServingEngine(cfg, params, slots=spec.slots, max_seq=max_seq)
    spc = ServingEngine(cfg, params, slots=spec.slots, max_seq=max_seq,
                        spec_decode=sd)
    reqs_van, reqs_spc = reqs(), reqs()
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    for eng, rs in ((van, reqs_van), (spc, reqs_spc)):
        i, t = 0, 0
        while i < len(pending) or any(eng.active) or eng.waiting:
            while i < len(pending) and pending[i].step <= t:
                eng.submit(rs[pending[i].rid])
                i += 1
            eng.step()
            t += 1
    for rid in reqs_van:
        assert reqs_van[rid].out == reqs_spc[rid].out, rid
    sim = simulate_spec_decode(spec, sd)
    assert spc.completions == sim["completion_ticks"]
    assert spc.stats["tokens"] == van.stats["tokens"]
    assert spc.stats["steps"] < van.stats["steps"]   # speculation pays


def test_acceptance_zero_engine_is_vanilla_lockstep(small_lm):
    """acceptance=0 through the REAL engine: tick-exact schedule AND
    byte-equal tokens against a vanilla engine on the same requests."""
    cfg, params = small_lm
    spec = make_scenario("bursty", seed=4, slots=3, quick=True)
    max_seq = max(64, 2 * max(a.prompt_len + a.max_new
                              for a in spec.arrivals))

    def reqs():
        rng = np.random.default_rng(spec.seed + 1)
        return {a.rid: Request(rid=a.rid,
                               prompt=rng.integers(0, cfg.vocab,
                                                   size=a.prompt_len),
                               max_new=a.max_new) for a in spec.arrivals}

    van = ServingEngine(cfg, params, slots=spec.slots, max_seq=max_seq)
    spc = ServingEngine(cfg, params, slots=spec.slots, max_seq=max_seq,
                        spec_decode=SpecDecodeConfig(acceptance=0.0))
    reqs_van, reqs_spc = reqs(), reqs()
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    for eng, rs in ((van, reqs_van), (spc, reqs_spc)):
        i, t = 0, 0
        while i < len(pending) or any(eng.active) or eng.waiting:
            while i < len(pending) and pending[i].step <= t:
                eng.submit(rs[pending[i].rid])
                i += 1
            eng.step()
            t += 1
    assert spc.step_batches == van.step_batches
    assert spc.completions == van.completions
    assert spc.admit_ticks == van.admit_ticks
    for rid in reqs_van:
        assert reqs_van[rid].out == reqs_spc[rid].out, rid


def test_disagg_cells_match_simulator_speculative(small_lm, planner):
    """The disagg cell pair serving speculatively under active
    budget/bound/SLO knobs matches simulate_disagg(spec_decode=) —
    same per-tick batches and completions, spec telemetry attached."""
    cfg, params = small_lm
    spec = make_scenario("spec-decode", seed=2, slots=3, quick=True)
    sd = SpecDecodeConfig(draft_len=3, acceptance=0.6, seed=5)
    dcfg = DisaggConfig(prefill_budget=1, handoff_bound=2,
                        starvation_age=3)
    slo = assign_slo(spec, frac_latency=0.6)
    sim = simulate_disagg(spec, dcfg, slo, spec_decode=sd)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step",
                         disagg=dcfg, slo=slo, spec_decode=sd)
    assert trace["per_tick_batch"] == sim["per_tick_batch"]
    rec = trace["disagg"]
    for key in ("prefill_ticks", "admit_ticks", "completion_ticks"):
        assert rec["requests"][key] == {str(r): t for r, t
                                        in sim[key].items()}, key
    assert trace["spec_decode"]["rounds"] == sum(sim["rounds"].values())
    assert trace["spec_decode"]["config"] == sd.to_record()


def test_mirror_disagg_speculative_equals_monolithic():
    """Under the mirror config the disagg spec-decode simulator and the
    monolithic spec-decode simulator agree tick for tick."""
    spec = make_scenario("spec-decode", seed=8, slots=4, quick=True)
    sd = SpecDecodeConfig(draft_len=4, acceptance=0.8, seed=1)
    mono = simulate_spec_decode(spec, sd)
    pair = simulate_disagg(spec, spec_decode=sd)
    assert pair["per_tick_batch"] == mono["per_tick_batch"]
    assert pair["completion_ticks"] == mono["completion_ticks"]


# ---------------------------------------------------------------------
# 3. Boundaries: draft_len=1, one slot, zero requests, chaos
# ---------------------------------------------------------------------

def test_draft_len_one_boundary(small_lm, planner):
    cfg, params = small_lm
    spec = make_scenario("spec-decode", seed=3, slots=2, quick=True)
    sd = SpecDecodeConfig(draft_len=1, acceptance=0.9, seed=0)
    _assert_spec_invariants(spec, sd)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step",
                         spec_decode=sd)
    _spec_trace_matches_sim(trace, spec, sd)
    # with draft_len=1 a tick advances at most 2 tokens
    assert all(s <= 2 for s in
               simulate_spec_decode(spec, sd)["per_tick_substeps"])


def test_single_slot_engine_speculative(small_lm, planner):
    cfg, params = small_lm
    spec = make_scenario("steady", seed=1, slots=1, quick=True)
    sd = SpecDecodeConfig(draft_len=4, acceptance=0.7, seed=2)
    _assert_spec_invariants(spec, sd)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step",
                         spec_decode=sd)
    _spec_trace_matches_sim(trace, spec, sd)
    assert max(trace["per_tick_batch"]) == 1


def test_max_new_floor_never_drafts():
    """A request at its last budgeted token (remaining=1) drafts zero
    tokens — speculation never overshoots max_new."""
    sd = SpecDecodeConfig(draft_len=8, acceptance=1.0)
    adv, drafted, acc = sd.advance(0, 0, 1)
    assert (adv, drafted, acc) == (1, 0, 0)


def test_zero_request_spec_summary_neutral(small_lm, planner):
    cfg, params = small_lm
    eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                        spec_decode=SpecDecodeConfig())
    assert eng.step() is False
    out = eng.run(max_steps=3)
    assert out["steps"] == 0 and out["tokens"] == 0
    assert eng.spec_report() == dict(rounds=0, drafted=0, accepted=0,
                                     wasted=0, substeps=0,
                                     per_tick_advance=[])
    spec = ScenarioSpec(name="spec-decode", seed=0, slots=2, arrivals=())
    trace = run_scenario(spec, cfg, params, planner, policy="hysteresis",
                         spec_decode=SpecDecodeConfig())
    assert trace["steps"] == 0 and trace["per_tick_batch"] == []
    assert trace["spec_decode"]["rounds"] == 0
    assert trace["controller"]["efficiency"] == 1.0
    sim = simulate_spec_decode(spec)
    assert sim["per_tick_batch"] == [] and sim["completion_ticks"] == {}


def test_vanilla_trace_has_no_spec_key(small_lm, planner):
    """No spec_decode → no "spec_decode" trace key: the pinned vanilla
    goldens (serve/disagg/chaos) stay byte-identical by construction."""
    cfg, params = small_lm
    spec = make_scenario("steady", seed=0, slots=2, quick=True)
    trace = run_scenario(spec, cfg, params, planner, policy="per-step")
    assert "spec_decode" not in trace
    assert replay_batches(trace) == trace["per_tick_batch"]


def _strip_chaos(t: dict) -> str:
    return json.dumps({k: v for k, v in t.items() if k != "chaos"},
                      sort_keys=True)


def test_spec_decode_under_chaos_byte_parity(small_lm):
    """A speculative serve under a seeded fault timeline completes with
    zero unhandled exceptions and — for a scheduling-neutral schedule —
    every non-chaos trace key byte-identical to a healthy run driven by
    the fault-free shadow timeline."""
    from repro.core import faults
    from repro.serving.chaos import (baseline_timeline,
                                     make_chaos_timeline,
                                     run_chaos_scenario)
    cfg, params = small_lm
    spec = make_scenario(**GOLDEN_SCENARIO)
    sd = GOLDEN_SD
    horizon = max(a.step for a in spec.arrivals) + 1
    tl = make_chaos_timeline(3, horizon=max(horizon, 8),
                             scheduling=False)

    engine.lane_cache_reset()
    faulted = run_chaos_scenario(
        cfg, params, OffloadPlanner(ARCHS["mamba2-130m"]), scenario=spec,
        timeline=tl, spec_decode=sd)
    assert faulted["chaos"]["injected"] > 0
    assert faulted["spec_decode"]["config"] == sd.to_record()
    _spec_trace_matches_sim(faulted, spec, sd)

    faults.reset()
    engine.lane_cache_reset()
    baseline = run_chaos_scenario(
        cfg, params, OffloadPlanner(ARCHS["mamba2-130m"]), scenario=spec,
        timeline=baseline_timeline(tl), spec_decode=sd)
    assert not baseline["chaos"]["injected"]
    assert _strip_chaos(faulted) == _strip_chaos(baseline)
    faults.reset()


# ---------------------------------------------------------------------
# 4. Golden fixture: byte-exact across backends and mesh sizes
# ---------------------------------------------------------------------

def _golden_spec_trace(small_lm) -> dict:
    cfg, params = small_lm
    spec = make_scenario(**GOLDEN_SCENARIO)
    fresh_planner = OffloadPlanner(ARCHS["granite-8b"])
    return run_scenario(spec, cfg, params, fresh_planner,
                        policy=GOLDEN_POLICY, spec_decode=GOLDEN_SD)


def test_golden_spec_decode_trace_exact(small_lm):
    """The speculative serve's full telemetry — per-tick batches,
    draft/verify counters, controller report, per-step speedups — is
    diffed EXACTLY against the committed fixture.  Regenerate
    deliberately with `python tests/test_spec_decode.py`."""
    fixture = json.loads(SPEC_GOLDEN.read_text())
    current = json.loads(json.dumps(_golden_spec_trace(small_lm)))
    assert set(current) == set(fixture)
    for key in fixture:
        assert current[key] == fixture[key], f"golden drift at {key}"


@pytest.mark.parametrize("mesh_size", [1, 2])
@pytest.mark.parametrize("backend", ["scan", "pallas"])
def test_golden_replays_across_backends_and_meshes(small_lm, backend,
                                                   mesh_size):
    """replay_trace reconstructs the speculative run from the record
    alone (schedule + policy + SpecDecodeConfig) and must re-emit it
    byte-identically under every lane backend x mesh combination — lane
    resolution is bit-identical across all of them by contract."""
    if backend == "pallas" and not lane_scan.pallas_lane_supported():
        pytest.skip("pallas lane kernel unsupported here")
    if mesh_size > len(engine.lane_devices()):
        pytest.skip(f"mesh size {mesh_size} needs more host devices")
    cfg, params = small_lm
    fixture = json.loads(SPEC_GOLDEN.read_text())
    engine.lane_cache_clear()      # force THIS combo to resolve lanes
    fresh_planner = OffloadPlanner(ARCHS["granite-8b"])
    with engine.lane_backend_scope(backend):
        trace = replay_trace(fixture, cfg, params, fresh_planner,
                             mesh=mesh_size)
    trace = json.loads(json.dumps(trace))
    assert set(trace) == set(fixture)
    for key in fixture:
        assert trace[key] == fixture[key], \
            f"{backend}/mesh{mesh_size} drift at {key}"


def test_golden_spec_trace_replays_without_model():
    """The committed trace is self-describing: its embedded schedule and
    SpecDecodeConfig re-derive the occupancy through the model-free
    mirror, and the speculative accounting is internally consistent."""
    fixture = json.loads(SPEC_GOLDEN.read_text())
    assert replay_batches(fixture) == fixture["per_tick_batch"]
    rec = fixture["spec_decode"]
    assert SpecDecodeConfig.from_record(rec["config"]) == GOLDEN_SD
    spec = ScenarioSpec.from_record(fixture["scenario"])
    _spec_trace_matches_sim(fixture, spec, GOLDEN_SD)
    assert rec["wasted"] == rec["drafted"] - rec["accepted"]
    assert fixture["controller"]["efficiency"] >= 0.95


# ---------------------------------------------------------------------
# 5. Registries, draft lanes, economics, spec families
# ---------------------------------------------------------------------

def test_scenario_registry_resolution():
    assert resolve_scenario("spec_decode") == "spec-decode"
    assert resolve_scenario("spec-decode") == "spec-decode"
    assert make_scenario("spec_decode", seed=1, quick=True) == \
        make_scenario("spec-decode", seed=1, quick=True)
    with pytest.raises(ValueError, match="unknown scenario 'warp'"):
        resolve_scenario("warp")
    with pytest.raises(ValueError, match="choose from"):
        make_scenario("warp-speed")


def test_policy_registry_resolution():
    assert resolve_policy("per_step") == "per-step"
    assert make_policy("per_step").name == "per-step"
    with pytest.raises(ValueError, match="unknown offload policy"):
        resolve_policy("greedy")
    with pytest.raises(ValueError, match="choose from"):
        make_policy("greedy")


def test_draft_gemv_sites_shrink():
    cfg = ARCHS["mamba2-130m"]
    full = decode_gemv_sites(cfg)
    draft = draft_gemv_sites(cfg, shrink=4)
    assert len(draft) == len(full)
    for f, d in zip(full, draft):
        assert d.name == "draft." + f.name
        assert d.h == max(16, f.h // 4) and d.w == max(16, f.w // 4)
        assert d.count == f.count
    with pytest.raises(ValueError, match="shrink"):
        draft_gemv_sites(cfg, shrink=0)


def test_touch_draft_pins_lanes_mru():
    """The eviction shield: touch_draft finds every resolved draft lane
    and moves it MRU — silently (no hit/miss counter movement, so
    sticky-policy epochs are not skewed) — and a touched lane survives
    eviction pressure that evicts an untouched peer."""
    engine.lane_cache_reset()
    p = OffloadPlanner(ARCHS["mamba2-130m"])
    p.plan_draft()
    misses0 = engine.lane_cache_info()["misses"]
    hits0 = engine.lane_cache_info()["hits"]
    n = p.touch_draft()
    assert n > 0                       # every draft lane present
    info = engine.lane_cache_info()
    assert info["misses"] == misses0 and info["hits"] == hits0
    assert p.touch_draft() == n        # idempotent

    # raw MRU semantics: fill a tiny cache, touch the oldest entry,
    # insert one more — the touched entry survives, the untouched
    # next-oldest is evicted
    engine.lane_cache_reset()
    prev_max = engine.lane_cache_info()["maxsize"]
    engine.configure_lane_cache(2)
    try:
        cyc = "cycA"                   # keys are opaque to the LRU
        engine.lane_cache_import([((cyc, 0, "old"), 1, None),
                                  ((cyc, 0, "new"), 2, None)])
        assert engine.lane_cache_touch([(cyc, "old")]) == 1
        engine.lane_cache_import([((cyc, 0, "hot"), 3, None)])
        assert engine.lane_cache_touch([(cyc, "old")]) == 1   # survived
        assert engine.lane_cache_touch([(cyc, "new")]) == 0   # evicted
        assert engine.lane_cache_touch([(cyc, "gone")]) == 0  # absent ok
    finally:
        engine.configure_lane_cache(prev_max)
        engine.lane_cache_reset()


def test_spec_decode_speedup_model(planner):
    """The draft/verify economics: expected tokens/round grows with
    acceptance, so per-token speedup is monotone in acceptance; with
    acceptance=0 speculation only adds draft cost and cannot win."""
    lo = planner.spec_decode_speedup(draft_len=4, acceptance=0.1)
    hi = planner.spec_decode_speedup(draft_len=4, acceptance=0.9)
    assert lo["tokens_per_round"] < hi["tokens_per_round"]
    assert lo["speedup"] < hi["speedup"]
    zero = planner.spec_decode_speedup(draft_len=4, acceptance=0.0)
    assert zero["tokens_per_round"] == 1.0
    assert zero["speedup"] < 1.0
    one = planner.spec_decode_speedup(draft_len=4, acceptance=1.0)
    assert one["tokens_per_round"] == 5.0
    assert one["draft_step_ns"] < one["verify_step_ns"]


def test_spec_families_share_bank_geometry():
    banks = {sp.timings.num_banks for sp in SPEC_FAMILIES.values()}
    assert banks == {16}               # one compiled program per fleet
    assert len(SPEC_FAMILIES) >= 4
    assert "phone-lp5x" in SPEC_FAMILIES and "cxl-expander" in SPEC_FAMILIES
    # the populations are genuinely heterogeneous
    assert len({(sp.num_channels, sp.fence_ns, sp.timings.data_rate_mtps,
                 sp.pim.mac_interval_ck)
                for sp in SPEC_FAMILIES.values()}) == len(SPEC_FAMILIES)


def test_specfam_grid_bit_exact_in_one_run_many():
    """The heterogeneous family population resolves in ONE batched
    run_many with cycle counts bit-identical to looping per-family
    executors — the fleet/specfam_* benchmark contract."""
    dims = (256, 512)
    grid = [r for sp in SPEC_FAMILIES.values() for d in dims
            for r in (GemvRequest.pim(1024, d, PimDType.W8A8, spec=sp),
                      GemvRequest.baseline(1024, d, PimDType.W8A8,
                                           spec=sp))]
    batched = PimExecutor().run_many(grid)
    looped = []
    for sp in SPEC_FAMILIES.values():
        ex = PimExecutor(sp)
        looped += [ex.run_gemv(r.H, r.W, r.dtype)
                   if r.kind == "pim" else
                   ex.run_baseline(r.H, r.W, r.dtype)
                   for r in grid if r.spec == sp]
    assert len(batched) == len(looped) == len(grid)
    for a, b in zip(looped, batched):
        assert a.cycles == b.cycles, (a.meta, a.cycles, b.cycles)


def test_specfam_frontiers_per_population(planner):
    """One plan_grid dispatch covers the population; each family then
    reports a full offload frontier and spec-decode economics, and the
    families disagree somewhere (heterogeneity is observable)."""
    planner.plan_grid(list(SPEC_FAMILIES.values()))
    site_names = {s.name for s in decode_gemv_sites(ARCHS["mamba2-130m"])}
    frontiers = {}
    for name, sp in SPEC_FAMILIES.items():
        fr = planner.frontier(spec=sp)
        assert set(fr) == site_names
        assert all(isinstance(b, int) and b >= 1 for b in fr.values())
        frontiers[name] = fr
        sdrec = planner.spec_decode_speedup(spec=sp)
        assert sdrec["speedup"] > 0
        assert 1.0 <= sdrec["tokens_per_round"] <= 1.0 + sdrec["draft_len"]
    assert len({json.dumps(f, sort_keys=True)
                for f in frontiers.values()}) > 1


if __name__ == "__main__":          # regenerate the committed fixture
    cfg = smoke_config(ARCHS["granite-8b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    SPEC_GOLDEN.write_text(json.dumps(
        _golden_spec_trace((cfg, params)), indent=1, sort_keys=True))
    print(f"wrote {SPEC_GOLDEN}")
