"""Shared fixtures: module-state hygiene for the lane resolver.

``engine.configure_lane_devices`` / ``engine.configure_lane_mesh`` set
the process-default :class:`~repro.core.engine.BackendScope`.  A test
that forces a device cap or a mesh and fails (or simply forgets to
restore) would silently change the execution backend of every later
test in the session — the parity suites would then compare a path
against itself.  The autouse fixture below makes that impossible:
every test starts and ends on the default backend (env-controlled
device list, no mesh, no active per-cell scope).
"""
import pytest

from repro.core import engine, faults


@pytest.fixture(autouse=True)
def _reset_lane_backend_state():
    engine.reset_backend_scopes()
    engine.configure_scan_unroll(None)
    faults.reset()
    yield
    engine.reset_backend_scopes()
    engine.configure_scan_unroll(None)
    faults.reset()
