"""Timing-engine correctness: JAX scan engine == Python oracle, plus
timing-constraint invariants, on both structured and random streams."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # collection must never hard-fail
    HAVE_HYPOTHESIS = False

from repro.core import commands as C
from repro.core.engine import run_streams
from repro.core.engine_ref import RefEngine
from repro.core.timing import DEFAULT_SYSTEM, SystemSpec, PimSpec
from repro.pimkernel.executor import PimExecutor
from repro.pimkernel.tileconfig import PimDType

CYC = DEFAULT_SYSTEM.derive_cycles()


def _assert_engines_agree(stream):
    iss_ref, tot_ref = RefEngine(CYC, validate=False).run(stream)
    iss_jax, tot_jax = run_streams(CYC, [stream])
    np.testing.assert_array_equal(iss_ref, iss_jax[0].astype(np.int64))
    assert tot_ref == int(tot_jax[0])


def test_simple_sb_stream():
    b = C.StreamBuilder()
    b.emit(C.ACT, 0, 3)
    b.emit_repeat(C.RD, 16, a=0, b=3)
    b.emit(C.ACT, 5, 9)
    b.emit_repeat(C.WR, 4, a=5, b=9)
    b.emit(C.PRE, 0)
    b.emit(C.PREA)
    b.emit(C.REFAB)
    _assert_engines_agree(b.build())


def test_pim_stream_agrees():
    ex = PimExecutor(DEFAULT_SYSTEM)
    layout, program = ex.plan(256, 2048, PimDType.W8A16)
    gs = ex.build_streams(layout, program, fence=True)
    for s in gs.streams:
        _assert_engines_agree(s)


# --- random-stream equivalence ----------------------------------------

def build_valid_stream(ops):
    """Build a structurally-valid command stream from op tuples.

    SB phase: per-bank ACT -> RD/WR -> PRE sequences; MB phase: ACT_MB /
    MAC / WR_SRF / RD_ACC / FENCE mixes.  Validity (row open before CAS,
    mode correctness) is maintained by construction.  Shared by the
    hypothesis strategy below and the deterministic fleet tests.
    """
    b = C.StreamBuilder()
    open_banks: set[int] = set()
    mode = 0
    mb_open = False
    for kind, bank, row, n in ops:
        if mode == 0:
            if kind == 0:  # activate + CAS burst + precharge
                if bank in open_banks:
                    b.emit(C.PRE, bank)
                    open_banks.discard(bank)
                b.emit(C.ACT, bank, row)
                b.emit_repeat(C.RD if n % 2 else C.WR, 1 + n % 7,
                              a=bank, b=row)
                b.emit(C.PRE, bank)
            elif kind == 1:
                b.emit(C.PREA)
                open_banks.clear()
                b.emit(C.REFAB)
            elif kind == 2:
                for x in sorted(open_banks):
                    b.emit(C.PRE, x)
                open_banks.clear()
                b.emit(C.MODE_MB)
                mode = 1
        else:
            if kind == 0:
                if mb_open:
                    b.emit(C.PRE_MB)
                for q in range(4):
                    b.emit(C.ACT_MB, q, row)
                mb_open = True
                b.emit_repeat(C.MAC, 1 + n % 9, c_start=0)
            elif kind == 1:
                b.emit_repeat(C.WR_SRF, 1 + n % 5, a=0, b=0)
                if n % 3 == 0:
                    b.emit(C.FENCE)
            elif kind == 2:
                b.emit_repeat(C.RD_ACC, 1 + n % 4, a=bank)
                if mb_open:
                    b.emit(C.PRE_MB)
                    mb_open = False
                b.emit(C.MODE_SB)
                mode = 0
    if mode == 1:
        if mb_open:
            b.emit(C.PRE_MB)
        b.emit(C.MODE_SB)
    return b.build()


def random_op_tuples(rng, max_ops: int = 40):
    """Deterministic (seeded-numpy) op tuples for ``build_valid_stream``."""
    return [(int(rng.integers(0, 3)), int(rng.integers(0, 16)),
             int(rng.integers(0, 128)), int(rng.integers(0, 31)))
            for _ in range(int(rng.integers(1, max_ops + 1)))]


if HAVE_HYPOTHESIS:
    def _random_stream_strategy():
        op = st.tuples(st.integers(0, 2), st.integers(0, 15),
                       st.integers(0, 127), st.integers(0, 30))
        return st.lists(op, min_size=1,
                        max_size=40).map(build_valid_stream)

    @settings(max_examples=40, deadline=None)
    @given(_random_stream_strategy())
    def test_engines_agree_random(stream):
        _assert_engines_agree(stream)

    @settings(max_examples=25, deadline=None)
    @given(_random_stream_strategy())
    def test_timing_invariants(stream):
        _check_timing_invariants(stream)
else:                      # deterministic fallback when hypothesis absent
    def test_engines_agree_random():
        rng = np.random.default_rng(0)
        for _ in range(25):
            _assert_engines_agree(build_valid_stream(random_op_tuples(rng)))

    def test_timing_invariants():
        rng = np.random.default_rng(1)
        for _ in range(15):
            _check_timing_invariants(
                build_valid_stream(random_op_tuples(rng)))


def _check_timing_invariants(stream):
    """Issue times are feasible: per-bank tRC, global tCCD/tFAW, monotone
    non-negative issue cycles."""
    iss, tot = RefEngine(CYC, validate=False).run(stream)
    assert (iss >= 0).all()
    assert tot >= (iss.max() if iss.size else 0)
    # tCCD between any two CAS commands (RD/WR; SRF/IRF use cSRFI >= cCCD)
    cas = iss[np.isin(stream[:, 0], [C.RD, C.WR])]
    if cas.size > 1:
        assert np.diff(np.sort(cas)).min() >= CYC.cCCD
    # per-bank ACT-to-ACT >= tRC
    for bank in range(16):
        sel = (stream[:, 0] == C.ACT) & (stream[:, 1] == bank)
        t = np.sort(iss[sel])
        if t.size > 1:
            assert np.diff(t).min() >= CYC.cRC
    # tFAW: any 5 consecutive ACTs (incl. ACT_MB) span >= tFAW
    acts = np.sort(iss[np.isin(stream[:, 0], [C.ACT, C.ACT_MB])])
    if acts.size > 4:
        assert (acts[4:] - acts[:-4]).min() >= CYC.cFAW


def test_fence_latency_is_paid():
    spec = DEFAULT_SYSTEM
    b = C.StreamBuilder()
    b.emit(C.MODE_MB)
    for q in range(4):
        b.emit(C.ACT_MB, q, 0)
    b.emit_repeat(C.MAC, 8)
    n_before = len(b)
    b.emit(C.FENCE)
    b.emit(C.FENCE)  # consecutive fences each pay cFENCE
    b.emit_repeat(C.MAC, 1)
    s = b.build()
    iss, tot = RefEngine(spec.derive_cycles(), validate=False).run(s)
    f1, f2 = iss[n_before], iss[n_before + 1]
    assert f2 - f1 == spec.derive_cycles().cFENCE


def test_mac_rate_honors_interval():
    ex = PimExecutor(DEFAULT_SYSTEM)
    layout, program = ex.plan(1024, 4096, PimDType.W8A8)
    gs = ex.build_streams(layout, program)
    iss, tot = run_streams(DEFAULT_SYSTEM.derive_cycles(), gs.streams)
    s = gs.streams[0]
    mac_t = np.sort(iss[0][s[:, 0] == C.MAC])
    assert np.diff(mac_t).min() >= DEFAULT_SYSTEM.pim.mac_interval_ck


def test_engine_vmap_channels_independent():
    """Batched resolution equals per-stream resolution."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    layout, program = ex.plan(512, 1024, PimDType.W4A8)
    gs = ex.build_streams(layout, program)
    iss_b, tot_b = run_streams(DEFAULT_SYSTEM.derive_cycles(), gs.streams)
    for i, s in enumerate(gs.streams):
        iss_1, tot_1 = run_streams(DEFAULT_SYSTEM.derive_cycles(), [s])
        np.testing.assert_array_equal(iss_1[0], iss_b[i, : s.shape[0]])


def test_flush_modes_equivalent_macs():
    """ACC->DRAM flush (MOV_ACC) vs bus read-out: same MAC schedule,
    different flush commands; both resolve without violations."""
    ex = PimExecutor(DEFAULT_SYSTEM)
    layout, program = ex.plan(1024, 2048, PimDType.W8A8)
    for flush in ("bus", "dram"):
        gs = ex.build_streams(layout, program, flush=flush)
        res = ex.time_streams(gs)
        assert res.cycles > 0
        macs = int(res.counts[C.MAC])
        if flush == "bus":
            assert res.counts[C.RD_ACC] > 0 and res.counts[C.MOV_ACC] == 0
            bus_macs = macs
        else:
            assert res.counts[C.MOV_ACC] > 0 and res.counts[C.RD_ACC] == 0
            assert macs == bus_macs


def test_fleet_matches_individual_runs():
    """Vmapped fleet resolution == per-point resolution."""
    from repro.core.engine import resolve_fleet
    ex = PimExecutor(DEFAULT_SYSTEM)
    sets = []
    for (h, w) in [(256, 1024), (512, 512), (1024, 2048)]:
        layout, program = ex.plan(h, w, PimDType.W8A8)
        sets.append(ex.build_streams(layout, program).streams)
    fleet = resolve_fleet([(CYC, ss) for ss in sets])
    for ss, fr in zip(sets, fleet):
        iss_solo, solo = run_streams(CYC, ss)
        np.testing.assert_array_equal(solo, fr.totals)
        for i, s in enumerate(ss):
            np.testing.assert_array_equal(fr.issue[i],
                                          iss_solo[i, : s.shape[0]])
