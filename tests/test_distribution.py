"""Distribution layer: sharding rules, HLO collective parser, roofline
math.  (The full 512-device lower/compile proof lives in launch/dryrun.py;
these tests cover the logic units on the host mesh.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.distribution import sharding as SH
from repro.distribution.hlo_analysis import (_shape_bytes,
                                             collective_bytes,
                                             parse_collectives)
from repro.distribution.roofline import RooflineTerms, model_flops
from repro.models import model as M


class FakeMesh:
    """Duck-typed mesh for rule unit tests (no devices needed)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _leaf_specs(cfg, mesh):
    rules = SH.tp_rules(cfg, mesh)
    logical = M.param_logical(cfg)
    specs = M.param_specs(cfg)
    flat_l = jax.tree.leaves(
        logical, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(s, (str, type(None))) for s in x))
    flat_s = jax.tree.leaves(specs)
    return [(l, s, SH._leaf_pspec(tuple(l), s.shape, rules, mesh))
            for l, s in zip(flat_l, flat_s)]


@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_pspecs_divisible(arch):
    """Every sharded dim divides its mesh axis; axes unique per leaf."""
    cfg = ARCHS[arch]
    for mesh in (MESH1, MESH2):
        for logical, spec, pspec in _leaf_specs(cfg, mesh):
            assert len(pspec) <= len(spec.shape)
            used = [a for a in pspec if a is not None]
            assert len(used) == len(set(used)), (logical, pspec)
            for dim, axis in zip(spec.shape, tuple(pspec)):
                if axis is not None:
                    assert dim % mesh.shape[axis] == 0, \
                        (arch, logical, spec.shape, pspec)


def test_fsdp_only_for_big_archs():
    rules_small = SH.tp_rules(ARCHS["gemma3-4b"], MESH1)
    rules_big = SH.tp_rules(ARCHS["qwen2-72b"], MESH1)
    assert rules_small["embed"] is None
    assert rules_big["embed"] == "data"


def test_moe_expert_sharding_rule():
    """dbrx (16 experts) shards experts; granite-moe (40) falls back."""
    r_dbrx = SH.tp_rules(ARCHS["dbrx-132b"], MESH1)
    assert r_dbrx["experts"] == "model" and r_dbrx["mlp"] is None
    r_gm = SH.tp_rules(ARCHS["granite-moe-3b-a800m"], MESH1)
    assert r_gm["experts"] is None and r_gm["mlp"] == "model"


def test_input_shardings_match_specs():
    """Sharding tree structure matches input_specs for every cell kind."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen2-72b", "mamba2-130m", "internvl2-26b",
                 "musicgen-large"):
        cfg = ARCHS[arch]
        from repro.configs import shapes_for
        for sname in shapes_for(cfg):
            shape = SHAPES[sname]
            specs = M.input_specs(cfg, shape)
            shard = SH.input_shardings(cfg, mesh, shape)
            jax.tree.util = jax.tree_util
            s1 = jax.tree.structure(specs)
            s2 = jax.tree.structure(shard)
            assert s1 == s2, (arch, sname, s1, s2)


# --- HLO parser ---------------------------------------------------------

HLO_SAMPLE = """
ENTRY %main.42 (p0: bf16[16,128]) -> bf16[16,2048] {
  %ag = bf16[16,2048]{1,0} all-gather(bf16[16,128]{1,0} %p0), dims={1}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%add
  ROOT %t = bf16[16,2048]{1,0} add(%ag, %ag)
}
%body.7 (p: s32[]) -> s32[] {
  %rs = bf16[8,64]{1,0} reduce-scatter(bf16[8,1024]{1,0} %q), dims={1}
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,2048]") == 16 * 2048 * 2
    assert _shape_bytes("f32[256]") == 1024
    assert _shape_bytes("(f32[2,2], s8[4])") == 20


def test_parse_collectives_and_trip_scaling():
    per = parse_collectives(HLO_SAMPLE)
    assert per["main"]["all-gather"] == 16 * 2048 * 2
    assert per["main"]["all-reduce"] == 2 * 1024             # 2x conv.
    assert per["body.7"]["reduce-scatter"] == 8 * 1024 * 2   # operand
    tot1 = collective_bytes(HLO_SAMPLE, scan_trip_count=1)["total"]
    tot10 = collective_bytes(HLO_SAMPLE, scan_trip_count=10)["total"]
    assert tot10 - tot1 == 9 * 8 * 1024 * 2


# --- roofline math ------------------------------------------------------

def test_roofline_terms_and_bottleneck():
    t = RooflineTerms(arch="x", shape="train_4k", mesh="pod1", chips=256,
                      hlo_flops=1e18, hlo_bytes=1e15, coll_bytes=1e13,
                      model_flops=6e17)
    assert t.bottleneck == "compute"
    assert 0.5 < t.useful_ratio <= 0.61
    assert 0 < t.roofline_fraction <= 1.0


def test_model_flops_train_matches_6nd():
    cfg = ARCHS["granite-8b"]
    shape = SHAPES["train_4k"]
    f = model_flops(cfg, shape)
    base = 6 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert f > base                      # attention term on top
    assert f < base * 1.5


def test_model_flops_decode_scales_with_batch():
    cfg = ARCHS["qwen2-72b"]
    d32 = model_flops(cfg, SHAPES["decode_32k"])
    assert d32 / SHAPES["decode_32k"].global_batch == pytest.approx(
        2 * cfg.param_count() + 4 * 32768 * cfg.n_layers * cfg.n_heads
        * cfg.d_head, rel=0.05)
