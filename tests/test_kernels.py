"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle.

Sweeps shapes (aligned + ragged) and all PIM dtypes; int paths must be
bit-exact (integer MACs), fp paths allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.pim_gemm import pim_gemm_fp, pim_gemm_int
from repro.kernels.pim_gemv import pim_gemv_fp, pim_gemv_int
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType

BLOCK = (128, 256)
SHAPES = [(128, 256), (256, 512), (384, 640), (130, 258), (64, 1024)]


def _rand_int(rng, shape, bits):
    m = 2 ** (bits - 1) - 1
    return rng.integers(-m - 1, m + 1, size=shape)


@pytest.mark.parametrize("h,w", SHAPES)
@pytest.mark.parametrize("w_bits", [8, 4])
@pytest.mark.parametrize("a_bits", [8, 16])
def test_gemv_int_matches_ref(h, w, w_bits, a_bits):
    rng = np.random.default_rng(h * 1000 + w + w_bits + a_bits)
    wq = _rand_int(rng, (h, w), w_bits).astype(np.int8)
    xq = _rand_int(rng, (w,), a_bits)
    xq = xq.astype(np.int8 if a_bits == 8 else np.int16)
    ws = rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32)
    xs = np.float32(0.03)
    wk = ref.pack_w4(wq) if w_bits == 4 else jnp.asarray(wq)
    got = pim_gemv_int(wk, jnp.asarray(xq), jnp.asarray(ws), xs,
                       w_bits=w_bits, block=BLOCK, interpret=True)
    want = ref.ref_gemv_int(wk, xq, ws, xs, w_bits=w_bits)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("b", [1, 4, 9])
@pytest.mark.parametrize("w_bits", [8, 4])
def test_gemm_int_matches_ref(b, w_bits):
    h, w = 192, 384
    rng = np.random.default_rng(b * 7 + w_bits)
    wq = _rand_int(rng, (h, w), w_bits).astype(np.int8)
    xq = _rand_int(rng, (b, w), 8).astype(np.int8)
    ws = rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32)
    xs = np.float32(0.02)
    wk = ref.pack_w4(wq) if w_bits == 4 else jnp.asarray(wq)
    got = pim_gemm_int(wk, jnp.asarray(xq), jnp.asarray(ws), xs,
                       w_bits=w_bits, block=(8, 128, 256), interpret=True)
    want = ref.ref_gemm_int(wk, xq, ws, xs, w_bits=w_bits)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("h,w", [(128, 256), (130, 300)])
def test_gemv_fp_matches_ref(h, w):
    rng = np.random.default_rng(h + w)
    wf = (rng.standard_normal((h, w)) * 0.5).astype(np.float32)
    x = (rng.standard_normal((w,)) * 0.5).astype(np.float32)
    w8 = jnp.asarray(wf).astype(jnp.float8_e4m3fn)
    xb = jnp.asarray(x).astype(jnp.bfloat16)
    got = pim_gemv_fp(w8, xb, block=BLOCK, interpret=True)
    want = ref.ref_gemv_fp(w8, xb)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_gemm_fp_matches_ref():
    rng = np.random.default_rng(3)
    wf = (rng.standard_normal((192, 384)) * 0.5).astype(np.float32)
    xb = (rng.standard_normal((5, 384)) * 0.5).astype(np.float32)
    w8 = jnp.asarray(wf).astype(jnp.float8_e4m3fn)
    xk = jnp.asarray(xb).astype(jnp.bfloat16)
    got = pim_gemm_fp(w8, xk, block=(8, 128, 256), interpret=True)
    want = ref.ref_gemm_fp(w8, xk)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, size=(16, 64)).astype(np.int8)
    assert np.array_equal(np.asarray(ref.unpack_w4(ref.pack_w4(q))), q)


@pytest.mark.parametrize("dtype", ALL_DTYPES, ids=lambda d: d.name)
def test_pim_linear_all_dtypes(dtype):
    """End-to-end layer API: kernel path == oracle path, all 7 dtypes."""
    rng = np.random.default_rng(hash(dtype.name) % 2**31)
    wf = (rng.standard_normal((96, 192)) * 0.3).astype(np.float32)
    x = (rng.standard_normal((3, 192)) * 0.8).astype(np.float32)
    qw = ops.prepare_weights(wf, dtype)
    got = ops.pim_linear(x, qw, block=(128, 128), interpret=True)
    want = ops.pim_linear_ref(x, qw)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_quantization_fidelity():
    """Dequantized GEMV approximates the float GEMV (sanity)."""
    rng = np.random.default_rng(5)
    wf = rng.standard_normal((256, 512)).astype(np.float32) * 0.1
    x = rng.standard_normal((512,)).astype(np.float32)
    qw = ops.prepare_weights(wf, PimDType.W8A8)
    got = ops.pim_linear(x, qw, block=BLOCK, interpret=True)
    want = wf @ x
    err = np.linalg.norm(np.asarray(got) - want) / np.linalg.norm(want)
    assert err < 0.02, err
