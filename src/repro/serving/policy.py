"""Adaptive offload control: closed-loop policies over the planner.

The ``OffloadPlanner`` answers "which GEMV sites does PIM win at batch
B?"; this module decides *when that question is asked*.  In a live
decode loop the batch size shifts every step (requests finish, bursts
arrive), and per-step recomputation — today's ``step_telemetry``
behavior — issues one planner query per decode step.  The
``OffloadController`` wraps the planner behind pluggable policies:

* ``per-step`` — recompute the oracle offload set every step (the
  baseline and, by construction, the realized-speedup oracle).
* ``hysteresis`` — a site's host/PIM assignment flips only after the
  batch has sat on the other side of its crossover for K consecutive
  steps, so occupancy jitter around a crossover cannot thrash the
  decision.  Planner queries drop from one-per-step to one at startup.
* ``sticky`` — keep one epoch's offload set until the occupancy drifts
  away from the epoch's reference batch or the engine's resolved-lane
  cache reports a miss (``engine.lane_cache_info`` — the world went
  cold, e.g. the cache was cleared or reconfigured); only then re-plan,
  optionally re-deriving decisions through the simulator
  (``OffloadPlanner.invalidate``), which a warm lane cache turns into
  dict lookups instead of fleet work.

Every policy reports decision-switch counts, planner queries/replans
and realized-vs-oracle occupancy-weighted speedup, so "cheaper control"
is always measured against "how much speedup it gave up".
"""
from __future__ import annotations

import dataclasses

from repro.core import engine, faults
from .offload import offload_set, step_cost


class OffloadPolicy:
    """Decides the offload site-set shown one live batch size per step."""

    name = "base"

    def offload_for(self, controller: "OffloadController", step: int,
                    batch: int) -> frozenset:
        raise NotImplementedError


class PerStepPolicy(OffloadPolicy):
    """Recompute the oracle offload set every decode step."""

    name = "per-step"

    def offload_for(self, controller, step, batch):
        return controller.query(batch)


class HysteresisPolicy(OffloadPolicy):
    """Damp decision flips inside a band around each site's crossover.

    Per-site state machines over the exact crossover batch
    ``b* = host_ns / pim_ns``:

    * **outside the band** (``batch < b*/band`` or ``batch > b*·band``)
      the decision is wrong by a margin worth paying for — the site
      flips to the per-step oracle immediately, so out-of-band steps
      decide *identically* to per-step recompute;
    * **inside the band** the penalty for a stale assignment is small
      (cost ratio bounded by ``band``), so the site keeps its current
      assignment until the batch has disagreed with it for ``k``
      consecutive steps (any agreeing step resets the streak) —
      occupancy jitter around a crossover cannot thrash the decision.

    The fuzzed properties: per-site flips never exceed the trace's
    crossings of that site's threshold, in-band-committed flips are
    further bounded by ``steps // k``, and every out-of-band step
    matches per-step recompute exactly.
    """

    name = "hysteresis"

    def __init__(self, k: int = 3, band: float = 1.25):
        if k < 1:
            raise ValueError("hysteresis window k must be >= 1")
        if band < 1.0:
            raise ValueError("hysteresis band must be >= 1.0")
        self.k = int(k)
        self.band = float(band)
        self._state: dict | None = None
        self._streak: dict = {}

    def in_band(self, decision, batch: int) -> bool:
        crossover = decision.host_ns / max(decision.pim_ns, 1e-9)
        return crossover / self.band < batch < crossover * self.band

    def offload_for(self, controller, step, batch):
        decisions = controller.decisions
        if self._state is None:
            first = controller.query(batch)
            self._state = {d.site.name: d.site.name in first
                           for d in decisions}
            self._streak = {d.site.name: 0 for d in decisions}
            return first
        for d in decisions:
            name = d.site.name
            desired = d.offload_at(batch)
            if desired == self._state[name]:
                self._streak[name] = 0
            elif not self.in_band(d, batch):
                self._state[name] = desired
                self._streak[name] = 0
            else:
                self._streak[name] += 1
                if self._streak[name] >= self.k:
                    self._state[name] = desired
                    self._streak[name] = 0
        return frozenset(n for n, on in self._state.items() if on)


class StickyPolicy(OffloadPolicy):
    """One offload set per epoch; re-plan on drift or lane-cache miss.

    The epoch's set is the oracle at its reference batch.  A new epoch
    starts on occupancy drift — the running mean since the epoch began
    moves more than ``drift`` slots from the reference after
    ``min_epoch`` steps (slow ramps), or a single step jumps
    ``jump`` or more slots away (bursts, drain/refill cliffs) — or when
    the engine's resolved-lane cache records a miss since the epoch
    began: the signal that the memoized timing world went cold.  Drift
    replans re-derive the set from the already-cached decisions; cold
    replans ``refresh`` through ``OffloadPlanner.invalidate`` so the
    decisions themselves are re-resolved (cheaply, when the lane cache
    is warm).
    """

    name = "sticky"

    def __init__(self, drift: float = 0.75, min_epoch: int = 3,
                 jump: float = 2.0, watch_lane_cache: bool = True):
        self.drift = float(drift)
        self.min_epoch = int(min_epoch)
        self.jump = float(jump)
        self.watch_lane_cache = watch_lane_cache
        self._set: frozenset | None = None
        self._ref = 0.0
        self._sum = 0
        self._n = 0
        self._miss0 = 0

    def _epoch(self, batch: int, offload: frozenset) -> frozenset:
        self._set = offload
        self._ref = float(batch)
        self._sum = 0
        self._n = 0
        self._miss0 = engine.lane_cache_info()["misses"]
        return offload

    def _cold(self) -> bool:
        return (self.watch_lane_cache
                and engine.lane_cache_info()["misses"] > self._miss0)

    def offload_for(self, controller, step, batch):
        if self._set is None:
            return self._epoch(batch, controller.query(batch))
        if self._cold():
            return self._epoch(batch,
                               controller.replan(batch, refresh=True))
        if abs(batch - self._ref) >= self.jump:
            return self._epoch(batch, controller.replan(batch))
        self._sum += batch
        self._n += 1
        mean = self._sum / self._n
        if self._n >= self.min_epoch and abs(mean - self._ref) > self.drift:
            return self._epoch(batch, controller.replan(batch))
        return self._set


POLICIES = {
    PerStepPolicy.name: PerStepPolicy,
    HysteresisPolicy.name: HysteresisPolicy,
    StickyPolicy.name: StickyPolicy,
}


def resolve_policy(name: str) -> str:
    """Canonicalize a policy name or raise listing every valid one.

    The :func:`~repro.serving.scenarios.resolve_scenario` analogue:
    CLI-friendly underscore aliases map to the registry's dashed names
    (``per_step`` → ``per-step``) and unknown names fail with the full
    menu at validation time — the launchers route ``--policy`` through
    this instead of a frozen argparse ``choices`` list.
    """
    cand = str(name).replace("_", "-")
    if cand in POLICIES:
        return cand
    raise ValueError(f"unknown offload policy {name!r}; "
                     f"choose from {sorted(POLICIES)}")


def make_policy(name: str, **kw) -> OffloadPolicy:
    return POLICIES[resolve_policy(name)](**kw)


@dataclasses.dataclass
class StepRecord:
    """What the controller decided (and what it cost) for one step."""

    step: int
    batch: int
    offloaded: int          # |offload set|
    speedup: float          # host_ns / realized mixed_ns for this step

    def to_record(self) -> dict:
        return dict(step=self.step, batch=self.batch,
                    offloaded=self.offloaded, speedup=self.speedup)


class OffloadController:
    """Closed-loop decision maker between a serving loop and the planner.

    ``observe(batch)`` is called once per decode step with the live
    batch size and returns the step's :class:`StepRecord`; the chosen
    offload set is whatever the policy says.  The controller accounts
    every step twice — once at the policy's set (realized) and once at
    the per-step oracle set — so ``report()`` can state exactly how much
    speedup the cheaper control loop gave up, alongside the planner
    query/replan counts it saved.

    ``planner`` must provide ``plan(fence=, spec=)`` returning
    ``OffloadDecision``s and ``invalidate()``; the property tests drive
    the controller with a stub, the serving stack with the real
    :class:`~repro.serving.offload.OffloadPlanner`.
    """

    def __init__(self, planner, policy: str | OffloadPolicy = "per-step",
                 fence: bool = True, spec=None, **policy_kw):
        self.planner = planner
        self.fence = fence
        self.spec = spec
        self.policy = (policy if isinstance(policy, OffloadPolicy)
                       else make_policy(policy, **policy_kw))
        self.planner_queries = 0
        self.replans = 0
        self.switches = 0
        self.switch_log: list[dict] = []
        self.trace: list[StepRecord] = []
        self.set_log: list[frozenset] = []
        self._decisions = None
        self._current: frozenset | None = None
        self._step = 0
        self._host_ns = 0.0
        self._mixed_ns = 0.0
        self._oracle_ns = 0.0
        self.planner_degraded = False

    # -- planner access (the accounting boundary) ----------------------
    @property
    def decisions(self):
        if self._decisions is None:
            try:
                self._decisions = faults.retry_call(
                    lambda: self.planner.plan(fence=self.fence,
                                              spec=self.spec),
                    site="planner")
            except Exception as e:  # noqa: BLE001 - planner timeout path
                # Degrade to host-only serving: an empty decision set
                # offloads nothing, so the serve loop keeps running
                # (correct tokens, no PIM speedup) instead of crashing.
                self.planner_degraded = True
                self._decisions = []
                faults.record_event(
                    "planner", "degrade",
                    f"host-only offload set after planner failure: "
                    f"{type(e).__name__}: {e}")
        return self._decisions

    def query(self, batch: int) -> frozenset:
        """Derive the oracle offload set at ``batch`` — counted; the
        whole point of a policy is issuing fewer of these."""
        self.planner_queries += 1
        return offload_set(self.decisions, batch)

    def replan(self, batch: int, refresh: bool = False) -> frozenset:
        """A counted re-plan; ``refresh`` also re-derives the decisions
        through the planner (simulator query, lane-cache-cheap when
        warm) instead of reusing the cached ones."""
        if refresh:
            self.planner.invalidate()
            self._decisions = None
        self.replans += 1
        return self.query(batch)

    # -- the per-step control loop -------------------------------------
    def observe(self, batch: int) -> StepRecord:
        offload = self.policy.offload_for(self, self._step, batch)
        if self._current is not None and offload != self._current:
            self.switches += 1
            self.switch_log.append(dict(
                step=self._step, batch=batch,
                on=sorted(offload - self._current),
                off=sorted(self._current - offload)))
        self._current = offload
        host, mixed = step_cost(self.decisions, batch, offload)
        _, oracle = step_cost(self.decisions, batch,
                              offload_set(self.decisions, batch))
        self._host_ns += host
        self._mixed_ns += mixed
        self._oracle_ns += oracle
        rec = StepRecord(step=self._step, batch=batch,
                         offloaded=len(offload),
                         speedup=host / max(mixed, 1e-9))
        self.trace.append(rec)
        self.set_log.append(offload)
        self._step += 1
        return rec

    def report(self) -> dict:
        steps = self._step
        if steps == 0 or self._host_ns == 0:
            # No steps, or a planner-degraded run whose empty decision
            # set accrued zero cost — every ratio is neutral.
            realized = oracle = efficiency = 1.0
        else:
            realized = self._host_ns / max(self._mixed_ns, 1e-9)
            oracle = self._host_ns / max(self._oracle_ns, 1e-9)
            efficiency = self._oracle_ns / max(self._mixed_ns, 1e-9)
        out = dict(policy=self.policy.name, steps=steps,
                   switches=self.switches,
                   planner_queries=self.planner_queries,
                   replans=self.replans,
                   host_ns=self._host_ns, mixed_ns=self._mixed_ns,
                   oracle_ns=self._oracle_ns,
                   realized_speedup=realized, oracle_speedup=oracle,
                   efficiency=efficiency,
                   switch_log=list(self.switch_log))
        if self.planner_degraded:
            # Conditional so healthy reports (and pinned golden traces)
            # keep their exact key set.
            out["planner_degraded"] = True
        return out
