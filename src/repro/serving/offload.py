"""PIM offload planner: which decode-phase GEMVs go to LP5X-PIM.

This is the HW/SW co-design point where the paper's simulator becomes a
*framework feature*: for every weight matrix touched by ``decode_step``
the planner queries the cycle-accurate simulator (PIM time, with mode
transitions / fences / flush-outs) against the host baseline (sequential
weight read at memory-system bandwidth) and emits an offload plan +
predicted speedup per decode batch size.

Batched decode on LP5X-PIM executes the batch as B back-to-back GEMVs
(weights are re-streamed from the banks each pass — in-bank data reuse
across a batch is not part of the LP5X-PIM execution model), while the
host baseline amortizes one weight read over the whole batch.  The
planner therefore finds the crossover batch size, which is the behavior
the PIM literature reports (PIM wins the small-batch regime).
"""
from __future__ import annotations

import dataclasses

from typing import Sequence

from repro.configs.base import ArchConfig
from repro.core.pimsim import PimSimulator
from repro.core.timing import SystemSpec
from repro.pimkernel.executor import GemvRequest
from repro.pimkernel.tileconfig import PimDType


@dataclasses.dataclass
class GemvSite:
    name: str            # e.g. "attn.wq"
    h: int               # output dim
    w: int               # input dim
    count: int           # instances per decode step (layers folded in)


def decode_gemv_sites(cfg: ArchConfig) -> list[GemvSite]:
    """Weight matrices a single-token decode multiplies against."""
    sites = []
    L = cfg.n_layers
    d = cfg.d_model
    if not cfg.attention_free:
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        sites += [GemvSite("attn.wq", hq * hd, d, L),
                  GemvSite("attn.wk", hkv * hd, d, L),
                  GemvSite("attn.wv", hkv * hd, d, L),
                  GemvSite("attn.wo", d, hq * hd, L)]
    if cfg.family == "moe":
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        n = 3 if cfg.mlp == "swiglu" else 2
        # per token only top-k experts run; router is a small GEMV too
        sites.append(GemvSite("moe.router", e, d, L))
        sites += [GemvSite(f"moe.w{i}", cfg.d_ff, d, L * k)
                  for i in range(n - 1)]
        sites.append(GemvSite("moe.wo", d, cfg.d_ff, L * k))
    elif cfg.d_ff > 0:
        n = 3 if cfg.mlp == "swiglu" else 2
        sites += [GemvSite(f"mlp.w{i}", cfg.d_ff, d, L)
                  for i in range(n - 1)]
        sites.append(GemvSite("mlp.wo", d, cfg.d_ff, L))
    if cfg.ssm is not None:
        di = cfg.d_inner
        proj = 2 * di + 2 * cfg.ssm.state_dim + cfg.n_ssm_heads
        sites += [GemvSite("ssm.in_proj", proj, d, L),
                  GemvSite("ssm.out_proj", d, di, L)]
    sites.append(GemvSite("lm_head", cfg.vocab_padded, d, 1))
    return sites


@dataclasses.dataclass
class OffloadDecision:
    site: GemvSite
    pim_ns: float          # one GEMV on LP5X-PIM
    host_ns: float         # one weight pass on the host memory system
    reshape: bool
    offload_below_batch: int   # offload when batch < this

    def speedup_at(self, batch: int) -> float:
        pim = self.pim_ns * batch
        host = max(self.host_ns, 1e-9)   # host amortizes weight reads
        return host / pim

    def offload_at(self, batch: int) -> bool:
        """Exact per-step predicate: PIM wins this site at this batch.

        The float comparison, not the truncated ``offload_below_batch``
        integer, so every consumer (planner telemetry, controller
        policies, property tests) agrees at the boundary.
        """
        return self.pim_ns * batch < self.host_ns


def offload_set(decisions: Sequence[OffloadDecision],
                batch: int) -> frozenset:
    """Site names PIM wins at this batch — the per-step oracle set."""
    return frozenset(d.site.name for d in decisions if d.offload_at(batch))


def step_cost(decisions: Sequence[OffloadDecision], batch: int,
              offload: frozenset) -> tuple[float, float]:
    """(host_ns, mixed_ns) of one decode step at ``batch`` with the
    sites in ``offload`` on PIM and everything else on the host.  This
    is the decision API the adaptive controller shares with
    ``decode_speedup`` — any offload set can be costed, not just the
    oracle one, which is how realized-vs-oracle telemetry is computed.
    """
    host_total = mixed_total = 0.0
    for d in decisions:
        host = d.host_ns * d.site.count
        host_total += host
        if d.site.name in offload:
            mixed_total += d.pim_ns * batch * d.site.count
        else:
            mixed_total += host
    return host_total, mixed_total


class OffloadPlanner:
    def __init__(self, cfg: ArchConfig, sim: PimSimulator | None = None,
                 dtype: PimDType = PimDType.W8A8):
        self.cfg = cfg
        self.sim = sim or PimSimulator()
        self.dtype = dtype
        self._plans: dict[tuple, list[OffloadDecision]] = {}

    def plan_grid(self, specs: Sequence[SystemSpec],
                  fence: bool = True) -> list[list[OffloadDecision]]:
        """Offload decisions for the whole (spec x site) grid at once.

        Every hardware variant's per-site PIM and host-baseline telemetry
        queries are batched into one fleet request — a single engine
        dispatch covers the entire design-space grid for this model —
        and each variant's plan is cached under its (spec, fence) key.
        Returns one decision list per spec, in input order.
        """
        specs = [sp or self.sim.spec for sp in specs]
        sites = decode_gemv_sites(self.cfg)
        reshapes = [site.h < 2048 for site in sites]   # §3.3 regime
        todo = [sp for sp in dict.fromkeys(specs)
                if (sp, fence) not in self._plans]
        reqs = []
        for sp in todo:
            for site, reshape in zip(sites, reshapes):
                reqs.append(GemvRequest.pim(site.h, site.w, self.dtype,
                                            fence=fence, reshape=reshape,
                                            spec=sp))
                reqs.append(GemvRequest.baseline(site.h, site.w,
                                                 self.dtype, spec=sp))
        res = iter(self.sim.run_many(reqs))
        for sp in todo:
            out = []
            for site, reshape in zip(sites, reshapes):
                pim, base = next(res), next(res)
                crossover = max(1, int(base.ns / pim.ns))
                out.append(OffloadDecision(site=site, pim_ns=pim.ns,
                                           host_ns=base.ns, reshape=reshape,
                                           offload_below_batch=crossover))
            self._plans[(sp, fence)] = out
        return [self._plans[(sp, fence)] for sp in specs]

    def plan(self, fence: bool = True,
             spec: SystemSpec | None = None) -> list[OffloadDecision]:
        """Offload decision per GEMV site (one spec of the grid path)."""
        return self.plan_grid([spec or self.sim.spec], fence=fence)[0]

    def invalidate(self) -> None:
        """Forget cached plans and batched simulator results so the next
        ``plan`` re-derives every offload decision through the engine.
        With a warm resolved-lane LRU that replan costs dict lookups,
        not fleet work — the property sticky-policy refreshes rely on.
        """
        self._plans.clear()
        self.sim.clear_cache()

    def decode_speedup(self, batch: int = 1, fence: bool = True,
                       spec: SystemSpec | None = None) -> dict:
        """End-to-end decode-step speedup from offloading (Amdahl over
        all GEMV sites; cached weights on host amortize over batch)."""
        decisions = self.plan(fence=fence, spec=spec)
        off = offload_set(decisions, batch)
        host_total, mixed_total = step_cost(decisions, batch, off)
        return dict(batch=batch,
                    host_ns=host_total,
                    mixed_ns=mixed_total,
                    speedup=host_total / max(mixed_total, 1e-9),
                    offloaded=[d.site.name for d in decisions
                               if d.site.name in off],
                    n_sites=len(decisions))

    def occupancy_weighted_speedup(self, occupancy: dict[int, int],
                                   fence: bool = True,
                                   spec: SystemSpec | None = None) -> dict:
        """Decode-phase speedup under a batch-occupancy histogram.

        ``occupancy`` maps decode batch size -> number of steps observed
        at that size (``ServingEngine.batch_occupancy``).  Each step's
        offload decision is taken at its *own* batch size — crossover per
        step, not per run — and the host/mixed step times are weighted by
        the histogram.  After the first ``plan`` (one batched, lane-
        cache-accelerated fleet query) this is pure arithmetic over the
        cached decisions, so it is cheap enough to recompute every run.

        An empty histogram means "no decode steps observed": the neutral
        answer is speedup 1.0 over zero steps, not the 0/eps collapse a
        missing-trace caller would otherwise read as "PIM is infinitely
        bad".
        """
        if not occupancy:
            return dict(steps=0, host_ns=0.0, mixed_ns=0.0, speedup=1.0,
                        per_batch_speedup={})
        host_total = mixed_total = 0.0
        per_batch = {}
        steps = 0
        for b, count in sorted(occupancy.items()):
            tel = self.decode_speedup(batch=b, fence=fence, spec=spec)
            per_batch[b] = tel["speedup"]
            host_total += tel["host_ns"] * count
            mixed_total += tel["mixed_ns"] * count
            steps += count
        return dict(steps=steps, host_ns=host_total, mixed_ns=mixed_total,
                    speedup=host_total / max(mixed_total, 1e-9),
                    per_batch_speedup=per_batch)
