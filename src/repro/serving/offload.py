"""PIM offload planner: which decode-phase GEMVs go to LP5X-PIM.

This is the HW/SW co-design point where the paper's simulator becomes a
*framework feature*: for every weight matrix touched by ``decode_step``
the planner queries the cycle-accurate simulator (PIM time, with mode
transitions / fences / flush-outs) against the host baseline (sequential
weight read at memory-system bandwidth) and emits an offload plan +
predicted speedup per decode batch size.

Batched decode on LP5X-PIM executes the batch as B back-to-back GEMVs
(weights are re-streamed from the banks each pass — in-bank data reuse
across a batch is not part of the LP5X-PIM execution model), while the
host baseline amortizes one weight read over the whole batch.  The
planner therefore finds the crossover batch size, which is the behavior
the PIM literature reports (PIM wins the small-batch regime).
"""
from __future__ import annotations

import dataclasses

from typing import Sequence

from repro.configs.base import ArchConfig
from repro.core.pimsim import PimSimulator
from repro.core.timing import SystemSpec
from repro.pimkernel.executor import GemvRequest
from repro.pimkernel.tileconfig import PimDType


@dataclasses.dataclass
class GemvSite:
    name: str            # e.g. "attn.wq"
    h: int               # output dim
    w: int               # input dim
    count: int           # instances per decode step (layers folded in)


def decode_gemv_sites(cfg: ArchConfig) -> list[GemvSite]:
    """Weight matrices a single-token decode multiplies against."""
    sites = []
    L = cfg.n_layers
    d = cfg.d_model
    if not cfg.attention_free:
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        sites += [GemvSite("attn.wq", hq * hd, d, L),
                  GemvSite("attn.wk", hkv * hd, d, L),
                  GemvSite("attn.wv", hkv * hd, d, L),
                  GemvSite("attn.wo", d, hq * hd, L)]
    if cfg.family == "moe":
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        n = 3 if cfg.mlp == "swiglu" else 2
        # per token only top-k experts run; router is a small GEMV too
        sites.append(GemvSite("moe.router", e, d, L))
        sites += [GemvSite(f"moe.w{i}", cfg.d_ff, d, L * k)
                  for i in range(n - 1)]
        sites.append(GemvSite("moe.wo", d, cfg.d_ff, L * k))
    elif cfg.d_ff > 0:
        n = 3 if cfg.mlp == "swiglu" else 2
        sites += [GemvSite(f"mlp.w{i}", cfg.d_ff, d, L)
                  for i in range(n - 1)]
        sites.append(GemvSite("mlp.wo", d, cfg.d_ff, L))
    if cfg.ssm is not None:
        di = cfg.d_inner
        proj = 2 * di + 2 * cfg.ssm.state_dim + cfg.n_ssm_heads
        sites += [GemvSite("ssm.in_proj", proj, d, L),
                  GemvSite("ssm.out_proj", d, di, L)]
    sites.append(GemvSite("lm_head", cfg.vocab_padded, d, 1))
    return sites


def draft_gemv_sites(cfg: ArchConfig, shrink: int = 4) -> list[GemvSite]:
    """GEMV sites of the speculative *draft* model: the target's sites
    with both dimensions shrunk by ``shrink`` (floored at 16).

    Speculative decoding drafts with a model roughly ``shrink²`` times
    smaller; those small GEMVs are exactly the regime LPDDR-PIM wins
    hardest (LP-Spec's observation), so the draft pass routes through
    the PIM-friendly small-shape path — and its resolved lanes are the
    hot entries :meth:`OffloadPlanner.touch_draft` pins in the lane LRU.
    Deriving from the target's own sites gives every architecture
    family a consistent draft proxy without a second model config.
    """
    if shrink < 1:
        raise ValueError("shrink must be >= 1")
    return [GemvSite("draft." + s.name, max(16, s.h // shrink),
                     max(16, s.w // shrink), s.count)
            for s in decode_gemv_sites(cfg)]


@dataclasses.dataclass
class OffloadDecision:
    site: GemvSite
    pim_ns: float          # one GEMV on LP5X-PIM
    host_ns: float         # one weight pass on the host memory system
    reshape: bool
    offload_below_batch: int   # offload when batch < this

    def speedup_at(self, batch: int) -> float:
        pim = self.pim_ns * batch
        host = max(self.host_ns, 1e-9)   # host amortizes weight reads
        return host / pim

    def offload_at(self, batch: int) -> bool:
        """Exact per-step predicate: PIM wins this site at this batch.

        The float comparison, not the truncated ``offload_below_batch``
        integer, so every consumer (planner telemetry, controller
        policies, property tests) agrees at the boundary.
        """
        return self.pim_ns * batch < self.host_ns


def offload_set(decisions: Sequence[OffloadDecision],
                batch: int) -> frozenset:
    """Site names PIM wins at this batch — the per-step oracle set."""
    return frozenset(d.site.name for d in decisions if d.offload_at(batch))


def step_cost(decisions: Sequence[OffloadDecision], batch: int,
              offload: frozenset) -> tuple[float, float]:
    """(host_ns, mixed_ns) of one decode step at ``batch`` with the
    sites in ``offload`` on PIM and everything else on the host.  This
    is the decision API the adaptive controller shares with
    ``decode_speedup`` — any offload set can be costed, not just the
    oracle one, which is how realized-vs-oracle telemetry is computed.
    """
    host_total = mixed_total = 0.0
    for d in decisions:
        host = d.host_ns * d.site.count
        host_total += host
        if d.site.name in offload:
            mixed_total += d.pim_ns * batch * d.site.count
        else:
            mixed_total += host
    return host_total, mixed_total


class OffloadPlanner:
    def __init__(self, cfg: ArchConfig, sim: PimSimulator | None = None,
                 dtype: PimDType = PimDType.W8A8):
        self.cfg = cfg
        self.sim = sim or PimSimulator()
        self.dtype = dtype
        self._plans: dict[tuple, list[OffloadDecision]] = {}
        self._draft_plans: dict[tuple, list[OffloadDecision]] = {}
        self._draft_reqs: dict[tuple, list[GemvRequest]] = {}

    def plan_grid(self, specs: Sequence[SystemSpec],
                  fence: bool = True) -> list[list[OffloadDecision]]:
        """Offload decisions for the whole (spec x site) grid at once.

        Every hardware variant's per-site PIM and host-baseline telemetry
        queries are batched into one fleet request — a single engine
        dispatch covers the entire design-space grid for this model —
        and each variant's plan is cached under its (spec, fence) key.
        Returns one decision list per spec, in input order.
        """
        specs = [sp or self.sim.spec for sp in specs]
        sites = decode_gemv_sites(self.cfg)
        reshapes = [site.h < 2048 for site in sites]   # §3.3 regime
        todo = [sp for sp in dict.fromkeys(specs)
                if (sp, fence) not in self._plans]
        reqs = []
        for sp in todo:
            for site, reshape in zip(sites, reshapes):
                reqs.append(GemvRequest.pim(site.h, site.w, self.dtype,
                                            fence=fence, reshape=reshape,
                                            spec=sp))
                reqs.append(GemvRequest.baseline(site.h, site.w,
                                                 self.dtype, spec=sp))
        res = iter(self.sim.run_many(reqs))
        for sp in todo:
            out = []
            for site, reshape in zip(sites, reshapes):
                pim, base = next(res), next(res)
                crossover = max(1, int(base.ns / pim.ns))
                out.append(OffloadDecision(site=site, pim_ns=pim.ns,
                                           host_ns=base.ns, reshape=reshape,
                                           offload_below_batch=crossover))
            self._plans[(sp, fence)] = out
        return [self._plans[(sp, fence)] for sp in specs]

    def plan(self, fence: bool = True,
             spec: SystemSpec | None = None) -> list[OffloadDecision]:
        """Offload decision per GEMV site (one spec of the grid path)."""
        return self.plan_grid([spec or self.sim.spec], fence=fence)[0]

    def plan_draft(self, fence: bool = True,
                   spec: SystemSpec | None = None,
                   shrink: int = 4) -> list[OffloadDecision]:
        """Offload decisions for the speculative draft model's sites.

        Same batched grid path as :meth:`plan` but over
        :func:`draft_gemv_sites` — one fleet dispatch warms every draft
        lane through the engine's resolved-lane LRU, and the planned
        requests are kept so :meth:`touch_draft` can re-pin those lanes
        without re-resolving anything.
        """
        sp = spec or self.sim.spec
        key = (sp, fence, shrink)
        if key not in self._draft_plans:
            sites = draft_gemv_sites(self.cfg, shrink=shrink)
            reshapes = [site.h < 2048 for site in sites]
            reqs = []
            for site, reshape in zip(sites, reshapes):
                reqs.append(GemvRequest.pim(site.h, site.w, self.dtype,
                                            fence=fence, reshape=reshape,
                                            spec=sp))
                reqs.append(GemvRequest.baseline(site.h, site.w,
                                                 self.dtype, spec=sp))
            res = iter(self.sim.run_many(reqs))
            out = []
            for site, reshape in zip(sites, reshapes):
                pim, base = next(res), next(res)
                crossover = max(1, int(base.ns / pim.ns))
                out.append(OffloadDecision(site=site, pim_ns=pim.ns,
                                           host_ns=base.ns,
                                           reshape=reshape,
                                           offload_below_batch=crossover))
            self._draft_plans[key] = out
            self._draft_reqs[key] = reqs
        return self._draft_plans[key]

    def touch_draft(self, fence: bool = True,
                    spec: SystemSpec | None = None,
                    shrink: int = 4) -> int:
        """Pin the draft model's resolved lanes at the MRU end of the
        lane LRU (``engine.lane_cache_touch`` via the executor) so
        eviction pressure from big heterogeneous grids or replan storms
        cannot push the hot small-shape draft lanes out mid-serve.
        Plans the draft first if needed; returns lanes touched (0 when
        the cache ran cold — the next resolve re-warms them)."""
        sp = spec or self.sim.spec
        self.plan_draft(fence=fence, spec=sp, shrink=shrink)
        return self.sim.executor.touch_many(
            self._draft_reqs[(sp, fence, shrink)])

    def spec_decode_speedup(self, batch: int = 1, draft_len: int = 4,
                            acceptance: float = 0.7, fence: bool = True,
                            spec: SystemSpec | None = None,
                            shrink: int = 4) -> dict:
        """Expected per-generated-token economics of the draft/verify
        loop vs vanilla decode, pure arithmetic over the cached plans.

        One round drafts ``draft_len`` tokens on the draft model and
        verifies with one batched target pass; with leading-prefix
        acceptance it yields ``1 + Σ_{j≤L} p^j`` tokens in expectation.
        Both phases run under their own oracle offload sets at this
        batch, so the verdict is "speculation on the best hybrid vs
        vanilla on the best hybrid" — the honest comparison.
        """
        target = self.plan(fence=fence, spec=spec)
        draft = self.plan_draft(fence=fence, spec=spec, shrink=shrink)
        _, vanilla_ns = step_cost(target, batch,
                                  offload_set(target, batch))
        _, draft_ns = step_cost(draft, batch, offload_set(draft, batch))
        tokens = 1.0 + sum(acceptance ** j
                           for j in range(1, draft_len + 1))
        round_ns = draft_len * draft_ns + vanilla_ns
        per_token = round_ns / tokens
        return dict(batch=batch, draft_len=draft_len,
                    acceptance=acceptance,
                    tokens_per_round=tokens,
                    draft_step_ns=draft_ns, verify_step_ns=vanilla_ns,
                    ns_per_token=per_token,
                    vanilla_ns_per_token=vanilla_ns,
                    speedup=vanilla_ns / max(per_token, 1e-9))

    def frontier(self, fence: bool = True,
                 spec: SystemSpec | None = None) -> dict:
        """Per-site offload frontier of one spec: site name → the batch
        below which PIM wins it.  After :meth:`plan_grid` over a
        population this is a cache lookup — the per-population report
        the ``fleet/specfam_*`` rows print."""
        return {d.site.name: d.offload_below_batch
                for d in self.plan(fence=fence, spec=spec)}

    def invalidate(self) -> None:
        """Forget cached plans and batched simulator results so the next
        ``plan`` re-derives every offload decision through the engine.
        With a warm resolved-lane LRU that replan costs dict lookups,
        not fleet work — the property sticky-policy refreshes rely on.
        """
        self._plans.clear()
        self._draft_plans.clear()
        self._draft_reqs.clear()
        self.sim.clear_cache()

    def decode_speedup(self, batch: int = 1, fence: bool = True,
                       spec: SystemSpec | None = None) -> dict:
        """End-to-end decode-step speedup from offloading (Amdahl over
        all GEMV sites; cached weights on host amortize over batch)."""
        decisions = self.plan(fence=fence, spec=spec)
        off = offload_set(decisions, batch)
        host_total, mixed_total = step_cost(decisions, batch, off)
        return dict(batch=batch,
                    host_ns=host_total,
                    mixed_ns=mixed_total,
                    speedup=host_total / max(mixed_total, 1e-9),
                    offloaded=[d.site.name for d in decisions
                               if d.site.name in off],
                    n_sites=len(decisions))

    def occupancy_weighted_speedup(self, occupancy: dict[int, int],
                                   fence: bool = True,
                                   spec: SystemSpec | None = None) -> dict:
        """Decode-phase speedup under a batch-occupancy histogram.

        ``occupancy`` maps decode batch size -> number of steps observed
        at that size (``ServingEngine.batch_occupancy``).  Each step's
        offload decision is taken at its *own* batch size — crossover per
        step, not per run — and the host/mixed step times are weighted by
        the histogram.  After the first ``plan`` (one batched, lane-
        cache-accelerated fleet query) this is pure arithmetic over the
        cached decisions, so it is cheap enough to recompute every run.

        An empty histogram means "no decode steps observed": the neutral
        answer is speedup 1.0 over zero steps, not the 0/eps collapse a
        missing-trace caller would otherwise read as "PIM is infinitely
        bad".
        """
        if not occupancy:
            return dict(steps=0, host_ns=0.0, mixed_ns=0.0, speedup=1.0,
                        per_batch_speedup={})
        host_total = mixed_total = 0.0
        per_batch = {}
        steps = 0
        for b, count in sorted(occupancy.items()):
            tel = self.decode_speedup(batch=b, fence=fence, spec=spec)
            per_batch[b] = tel["speedup"]
            host_total += tel["host_ns"] * count
            mixed_total += tel["mixed_ns"] * count
            steps += count
        return dict(steps=steps, host_ns=host_total, mixed_ns=mixed_total,
                    speedup=host_total / max(mixed_total, 1e-9),
                    per_batch_speedup=per_batch)
