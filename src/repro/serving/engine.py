"""Batched serving engine: continuous batching over KV-cache slots.

Requests enter a waiting queue, get prefilled into a free slot, and the
decode loop steps every active slot in one batched ``decode_step`` call
(one batch of GEMVs per projection — the PIM offload unit).  Finished
slots (EOS or max tokens) free immediately and the next waiting request
takes over — continuous batching, the production serving pattern.

The engine also carries the PIM telemetry: per decode step it asks the
OffloadPlanner what the step would cost on a host-only vs PIM-offloaded
LPDDR5X system (the paper's motivating use case: on-device LLM decode).

Speculative decoding (``spec_decode=``, a
``scenarios.SpecDecodeConfig``): each serve tick runs one draft/verify
*round* per active slot instead of a single decode step.  The seeded
config decides how many draft tokens each request accepts this round
(keyed per ``(rid, round)``, so the schedule is independent of slot
order and identical to the model-free ``simulate_spec_decode`` mirror);
the engine realizes an advance of ``k + 1`` tokens as that many batched
decode sub-steps on the real target model — greedy speculative decoding
is output-identical to greedy vanilla decode, so the token streams stay
byte-equal to a vanilla run and the differential battery asserts it.
Slots whose round is shorter than the tick's longest ride along masked:
they feed their last token at an un-advanced position and their logits
are discarded; the garbage cache write at that position is overwritten
by their next genuine sub-step before anything reads it (the same
precedent as inactive slots decoding token 0).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from .offload import OffloadPlanner
from .policy import OffloadController


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    eos: int = -1
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, slots: int = 4,
                 max_seq: int = 256, planner: Optional[OffloadPlanner]
                 = None, step_telemetry: bool = False,
                 controller: Optional[OffloadController] = None,
                 spec_decode=None):
        assert cfg.input_mode == "tokens", "engine serves token models"
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.max_seq = max_seq
        self.cache = M.init_cache(cfg, slots, max_seq, jnp.float32)
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, dtype=np.int32)
        self.waiting: list[Request] = []
        # Adaptive offload control: the controller sees every decode
        # step's live batch size and runs its policy (per-step
        # recompute, hysteresis, sticky — serving/policy.py); its
        # planner doubles as the telemetry planner unless one was
        # passed explicitly.
        self.controller = controller
        if planner is None and controller is not None:
            planner = controller.planner
        self.planner = planner
        self.stats = dict(steps=0, tokens=0, prefills=0)
        self.batch_occupancy: dict[int, int] = {}
        self.step_batches: list[int] = []      # trace: batch per step
        # Per-request scheduling record: driver tick of admission
        # (= prefill, in the monolithic engine) and of completion.  The
        # disaggregated cell pair (serving/cells.py) records the same
        # ticks, which is what the differential parity battery diffs.
        self.ticks = 0                         # step() calls, idle included
        self.admit_ticks: dict[int, int] = {}
        self.completions: dict[int, int] = {}
        # Per-step PIM telemetry: one planner query per decode step at
        # the step's true occupancy.  The first query per batch size does
        # the (lane-cache-accelerated) fleet resolve; repeats are pure
        # arithmetic over the cached offload decisions.
        self.step_telemetry = step_telemetry
        self.step_speedups: list[dict] = []
        # Speculative decoding: the seeded accept/advance schedule
        # (duck-typed — scenarios.SpecDecodeConfig; None = vanilla) plus
        # per-request round counters and the per-tick advance telemetry
        # the mirror parity battery diffs.
        self.spec_decode = spec_decode
        self.spec_rounds: dict[int, int] = {}
        self.spec_drafted: dict[int, int] = {}
        self.spec_accepted: dict[int, int] = {}
        self.spec_advance: list[int] = []
        self.spec_substeps: list[int] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
        # Jitted like decode: one compile per prompt length, then ~ms
        # per prefill — eager prefill is the serving stack's tick-time
        # ceiling (a daemon admitting tens of requests per tick spends
        # its whole tick in op-by-op dispatch otherwise).
        self._prefill_fn = jax.jit(
            lambda p, b, c: M.prefill(cfg, p, b, c))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self, tick: int):
        for slot in range(self.slots):
            if self.active[slot] is None and self.waiting:
                req = self.waiting.pop(0)
                self._prefill(slot, req)
                self.active[slot] = req
                self.admit_ticks[req.rid] = tick

    def _prefill(self, slot: int, req: Request):
        """Single-slot prefill into the batched cache (slot-masked)."""
        s = len(req.prompt)
        assert s < self.max_seq
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        tmp_cache = M.init_cache(self.cfg, 1, self.max_seq, jnp.float32)
        logits, tmp_cache = self._prefill_fn(self.params,
                                             {"tokens": prompt}, tmp_cache)
        # merge the single-row cache into the batched cache at `slot`
        def merge(full, one):
            return full.at[:, slot:slot + 1].set(one)
        self.cache = jax.tree.map(merge, self.cache, tmp_cache)
        self.pos[slot] = s
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.stats["prefills"] += 1

    # ------------------------------------------------------------------
    def step(self):
        """One batched decode step over all active slots."""
        tick = self.ticks
        self.ticks += 1          # idle ticks advance too (driver-aligned)
        self._admit(tick)
        act = [i for i, r in enumerate(self.active) if r is not None]
        if not act:
            return False
        self.batch_occupancy[len(act)] = \
            self.batch_occupancy.get(len(act), 0) + 1
        if self.spec_decode is not None:
            self._spec_round(tick, act)
        else:
            tokens = np.zeros((self.slots, 1), dtype=np.int32)
            for i in act:
                tokens[i, 0] = self.active[i].out[-1]
            # one position per slot (ragged decode positions)
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens), pos)
            # one argmax over the whole batch on device, one host
            # transfer — not a device->host sync per active slot
            next_tok = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
            for i in act:
                req = self.active[i]
                tok = int(next_tok[i])
                req.out.append(tok)
                self.pos[i] += 1
                self.stats["tokens"] += 1
                if (tok == req.eos or len(req.out) >= req.max_new
                        or self.pos[i] >= self.max_seq - 1):
                    req.done = True
                    self.active[i] = None
                    self.completions[req.rid] = tick
        self.step_batches.append(len(act))
        if self.controller is not None:
            self.controller.observe(len(act))
        if self.planner is not None and self.step_telemetry:
            tel = self.planner.decode_speedup(batch=len(act))
            self.step_speedups.append(dict(step=self.stats["steps"],
                                           batch=len(act),
                                           speedup=tel["speedup"]))
        self.stats["steps"] += 1
        return True

    def _spec_round(self, tick: int, act: list[int]) -> None:
        """One speculative round per active slot, as batched sub-steps.

        The seeded schedule fixes each slot's advance up front; the
        tick then runs ``max(advance)`` batched decode sub-steps, each
        slot participating genuinely for its own first ``advance`` of
        them and riding along masked afterwards.  Each genuine sub-step
        is bit-identical to a vanilla decode step for that slot (the
        model is per-slot independent), so token streams match vanilla.
        """
        sd = self.spec_decode
        adv: dict[int, int] = {}
        for i in act:
            req = self.active[i]
            rem = max(1, req.max_new - len(req.out))
            a, drf, acc = sd.advance(req.rid,
                                     self.spec_rounds.get(req.rid, 0),
                                     rem)
            self.spec_rounds[req.rid] = \
                self.spec_rounds.get(req.rid, 0) + 1
            self.spec_drafted[req.rid] = \
                self.spec_drafted.get(req.rid, 0) + drf
            self.spec_accepted[req.rid] = \
                self.spec_accepted.get(req.rid, 0) + acc
            adv[i] = a
        nsub = max(adv.values())
        advanced = 0
        for s in range(nsub):
            live = [i for i in act
                    if s < adv[i] and self.active[i] is not None]
            if not live:
                break
            tokens = np.zeros((self.slots, 1), dtype=np.int32)
            for i in act:
                if self.active[i] is not None:
                    tokens[i, 0] = self.active[i].out[-1]
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens), pos)
            next_tok = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
            for i in live:
                req = self.active[i]
                tok = int(next_tok[i])
                req.out.append(tok)
                self.pos[i] += 1
                self.stats["tokens"] += 1
                advanced += 1
                if (tok == req.eos or len(req.out) >= req.max_new
                        or self.pos[i] >= self.max_seq - 1):
                    req.done = True
                    self.active[i] = None
                    self.completions[req.rid] = tick
        self.spec_advance.append(advanced)
        self.spec_substeps.append(nsub)

    def spec_report(self) -> dict:
        """Aggregate speculative telemetry (all zeros when vanilla or
        nothing ran — the neutral-summary contract)."""
        drafted = sum(self.spec_drafted.values())
        accepted = sum(self.spec_accepted.values())
        return dict(rounds=sum(self.spec_rounds.values()),
                    drafted=drafted, accepted=accepted,
                    wasted=drafted - accepted,
                    substeps=sum(self.spec_substeps),
                    per_tick_advance=list(self.spec_advance))

    def run(self, max_steps: int = 1000) -> dict:
        while (any(self.active) or self.waiting) and max_steps > 0:
            self.step()
            max_steps -= 1
        return self.summary()

    def summary(self) -> dict:
        """Run stats + PIM telemetry (+ policy report when controlled).

        Split out of :meth:`run` so trace-driven drivers — scenario
        loops that interleave arrivals with steps — get the identical
        record without going through ``run``'s step loop.
        """
        out = dict(self.stats)
        out["batch_occupancy"] = dict(self.batch_occupancy)
        # Derived metrics stay neutral on zero-request runs (a --quick
        # drain-refill with a tiny step budget completes nothing): no
        # raises, no 0/0 — completed 0, in-flight counts, rate 0.0.
        out["completed"] = len(self.completions)
        out["in_flight"] = (sum(r is not None for r in self.active)
                            + len(self.waiting))
        out["tokens_per_step"] = (self.stats["tokens"] / self.stats["steps"]
                                  if self.stats["steps"] else 0.0)
        if self.planner is not None:
            # One batched fleet query builds the site plan; per-batch-size
            # speedups are then pure arithmetic over the cached decisions.
            tel = self.planner.decode_speedup(batch=max(1, self.slots))
            batches = sorted(self.batch_occupancy) or [max(1, self.slots)]
            tel["per_batch_speedup"] = {
                b: self.planner.decode_speedup(batch=b)["speedup"]
                for b in batches}
            if self.batch_occupancy:
                # occupancy-weighted offload: crossover per step, not per
                # run — the batch-occupancy histogram weights each decode
                # step's offload decision by its true batch size.
                tel["occupancy_weighted"] = \
                    self.planner.occupancy_weighted_speedup(
                        self.batch_occupancy)
            if self.step_speedups:
                tel["per_step"] = list(self.step_speedups)
            out["pim_telemetry"] = tel
        if self.controller is not None:
            out["policy"] = self.controller.report()
        return out
