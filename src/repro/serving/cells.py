"""Disaggregated serving: a prefill cell and a decode cell joined by a
KV-handoff queue.

Production LLM serving splits prefill (compute-bound, long-prompt) and
decode (memory-bound, the LP5X-PIM sweet spot) into cells with
different batching and offload economics.  This module is that split
for :class:`~repro.serving.engine.ServingEngine`:

* :class:`PrefillCell` owns the admission queue (per-tenant SLO
  classes, FIFO within a class, aging so throughput tenants cannot
  starve under latency bursts) and performs prompt prefills — each
  produces a single-row KV cache plus the first token — up to a
  per-tick budget, pushing results onto the handoff queue.
* :class:`KVHandoffQueue` is the bounded FIFO between the cells; the
  prefill cell stalls rather than overrun it, and its peak depth is
  part of every report (the fuzzed bound property).
* :class:`DecodeCell` owns the batched KV cache and slots: handed-off
  requests merge into free slots the moment slots free (continuous
  batching — slot reclamation on completion, never batch-synchronous
  refill), and every tick runs ONE batched ``decode_step`` over all
  active slots, exactly the monolithic engine's decode loop.

Each cell can carry its own :class:`OffloadController` policy AND its
own :class:`~repro.core.engine.BackendScope` (lane backend, mesh,
device cap, circuit breaker): a cell activates its scope around its
tick work, so a prefill-side backend fault or breaker trip never
changes the decode cell's ladder — the cells' execution resources are
provisioned independently, like real disaggregated deployments.
Without scopes both cells run under the process-default scope (the
classic ``configure_lane_backend`` / ``configure_lane_mesh`` state).
Both cells still share the process-global resolved-lane LRU and
warm-start caches (``core/engine.py`` / ``core/warmstart.py``), so a
prefill→decode handoff never re-resolves lanes (asserted in
``tests/test_disagg.py``).

Under ``DisaggConfig.mirror()`` (unbounded prefill/handoff, one SLO
class) the pair replays the monolithic engine tick-exactly: identical
per-request completion ticks, batch occupancy, tokens and controller
telemetry — the differential contract ``tests/test_disagg.py`` pins
against the golden bursty trace.  The scheduling semantics themselves
are specified once in ``serving/scenarios.py`` (``simulate_disagg`` /
``_admission_pick``); this module is the independent real-model
implementation the parity battery diffs against it.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import faults
from repro.core import engine as lane_engine
from repro.models import model as M
from .engine import Request
from .offload import OffloadPlanner
from .policy import OffloadController
from .scenarios import (DisaggConfig, SLO_CLASSES, SLO_LATENCY,
                        SLO_THROUGHPUT)


def _scope_ctx(scope):
    """A cell's scope activation: ``backend_scope`` when the cell
    carries one, a no-op otherwise (so unscoped cells keep inheriting
    whatever scope — default or enclosing — is already active)."""
    return (lane_engine.backend_scope(scope) if scope is not None
            else contextlib.nullcontext())


class AdmissionQueue:
    """Per-SLO-class FIFO admission with aging (the anti-starvation rule).

    The pick order — starved throughput requests (waited >=
    ``starvation_age`` ticks) oldest-first, then latency FIFO, then
    throughput FIFO — implements the same spec as
    ``scenarios._admission_pick``; the property suite fuzzes both and
    the cell-vs-simulator parity test holds them together.  With a
    single class every rule degenerates to plain FIFO.
    """

    def __init__(self, starvation_age: int = 8):
        self.starvation_age = int(starvation_age)
        self._entries: list[tuple] = []    # (enq_tick, seq, Request, slo)
        self._seq = 0

    def __len__(self) -> int:
        return len(self._entries)

    def push(self, req: Request, slo: str, tick: int) -> None:
        if slo not in SLO_CLASSES:
            raise ValueError(f"unknown SLO class {slo!r}; "
                             f"choose from {SLO_CLASSES}")
        self._entries.append((tick, self._seq, req, slo))
        self._seq += 1

    def pop(self, tick: int) -> tuple[Request, str, int]:
        """(request, slo, enqueue tick) of the next admission."""
        starved = [i for i, (enq, _, _, slo) in enumerate(self._entries)
                   if slo == SLO_THROUGHPUT
                   and tick - enq >= self.starvation_age]
        if starved:
            pick = min(starved, key=lambda i: self._entries[i][:2])
        else:
            latency = [i for i, e in enumerate(self._entries)
                       if e[3] == SLO_LATENCY]
            pool = latency or range(len(self._entries))
            pick = min(pool, key=lambda i: self._entries[i][:2])
        enq, _, req, slo = self._entries.pop(pick)
        return req, slo, enq

    def shed(self, tick: int) -> tuple[Request, str, int]:
        """(request, slo, enqueue tick) of the entry to drop under
        admission pressure — the exact inverse of :meth:`pop`, same
        spec as ``scenarios._shed_pick``: youngest non-starved
        throughput request first, then youngest latency, starved
        throughput only when nothing else waits (aging preserved)."""
        fresh = [i for i, (enq, _, _, slo) in enumerate(self._entries)
                 if slo == SLO_THROUGHPUT
                 and tick - enq < self.starvation_age]
        if fresh:
            pick = max(fresh, key=lambda i: self._entries[i][:2])
        else:
            latency = [i for i, e in enumerate(self._entries)
                       if e[3] == SLO_LATENCY]
            pool = latency or range(len(self._entries))
            pick = max(pool, key=lambda i: self._entries[i][:2])
        enq, _, req, slo = self._entries.pop(pick)
        return req, slo, enq

    def wait_entries(self) -> list[tuple[int, str]]:
        """(enqueue tick, slo) of every waiting request — the per-class
        wait-age telemetry the autoscaler's grow signal reads."""
        return [(enq, slo) for enq, _, _, slo in self._entries]


@dataclasses.dataclass
class KVHandoff:
    """One prefilled request in flight between the cells: the request,
    its single-row KV cache, its sequence position after prefill."""

    req: Request
    cache: object            # 1-row cache pytree from M.prefill
    pos: int
    slo: str
    prefill_tick: int


class KVHandoffQueue:
    """Bounded FIFO of prefilled requests awaiting a decode slot."""

    def __init__(self, bound: int | None = None):
        self.bound = bound
        self._q: list[KVHandoff] = []
        self.handoffs = 0
        self.max_depth = 0
        self.waits: list[int] = []   # per-pop ticks spent in the queue

    def __len__(self) -> int:
        return len(self._q)

    def room(self) -> bool:
        inj = faults.injector()
        if inj is not None and inj.should_fail("handoff") is not None:
            # Simulated handoff pressure: report the queue full so the
            # prefill cell stalls this tick — the graceful path the
            # bound already exercises, never the overrun crash below.
            faults.record_event("handoff", "inject",
                                "simulated handoff pressure")
            faults.record_event("handoff", "stall",
                                "prefill cell stalls (queue reported full)")
            return False
        return self.bound is None or len(self._q) < self.bound

    def push(self, item: KVHandoff) -> None:
        if not self.room():
            raise RuntimeError(f"KV-handoff queue overrun (bound "
                               f"{self.bound}) — prefill cell must stall")
        self._q.append(item)
        self.handoffs += 1
        self.max_depth = max(self.max_depth, len(self._q))

    def pop(self, tick: int | None = None) -> KVHandoff:
        """FIFO pop; with ``tick`` the item's queue wait (ticks between
        prefill and decode admission) is recorded for telemetry."""
        item = self._q.pop(0)
        if tick is not None:
            self.waits.append(int(tick) - item.prefill_tick)
        return item

    def report(self) -> dict:
        return dict(bound=self.bound, depth=len(self._q),
                    handoffs=self.handoffs, max_depth=self.max_depth)

    def wait_report(self) -> dict:
        """Queue-wait telemetry, guarded for empty populations: a
        zero-request (or all-shed) run reports neutral ``0.0`` means —
        the PR 7 zero-request convention — never a divide by zero.
        Kept out of :meth:`report` so the golden disagg traces stay
        byte-identical."""
        n = len(self.waits)
        return dict(pops=n,
                    mean_wait=(sum(self.waits) / n if n else 0.0),
                    max_wait=(max(self.waits) if n else 0))


class PrefillCell:
    """Admission + prompt prefill; produces KV handoffs.

    The prefill computation is byte-identical to the monolithic
    engine's ``_prefill`` (same 1-row cache init, same ``M.prefill``
    call, same greedy first token); only the merge into the batched
    cache is deferred to the decode cell — which is what lets this cell
    run ahead of slot availability.
    """

    def __init__(self, cfg: ArchConfig, params, max_seq: int,
                 budget: int | None = None, starvation_age: int = 8,
                 admission_capacity: int | None = None,
                 controller: Optional[OffloadController] = None,
                 scope: "lane_engine.BackendScope | None" = None):
        self.cfg, self.params = cfg, params
        self.max_seq = max_seq
        self.budget = budget
        self.admission_capacity = admission_capacity
        self.queue = AdmissionQueue(starvation_age)
        self.controller = controller
        self.scope = scope
        self.stats = dict(prefills=0, ticks=0)
        self.prefill_ticks: dict[int, int] = {}
        self.enq_ticks: dict[int, int] = {}
        self.slo_of: dict[int, str] = {}
        self.shed: dict[int, int] = {}    # rid -> shed tick
        # Jitted like the decode cell's step: one compile per prompt
        # length keeps a budget-6 prefill tick in the milliseconds.
        self._prefill_fn = jax.jit(
            lambda p, b, c: M.prefill(cfg, p, b, c))

    def submit(self, req: Request, slo: str, tick: int) -> None:
        self.queue.push(req, slo, tick)
        self.enq_ticks[req.rid] = tick
        self.slo_of[req.rid] = slo
        while (self.admission_capacity is not None
               and len(self.queue) > self.admission_capacity):
            # SLO-aware load shedding: drop the lowest-priority waiter
            # (AdmissionQueue.shed = inverse admission order) instead of
            # letting pressure reach the handoff-overrun invariant.
            victim, vslo, _ = self.queue.shed(tick)
            self.shed[victim.rid] = tick
            faults.record_event(
                "admission", "shed",
                f"rid={victim.rid} slo={vslo} "
                f"(capacity {self.admission_capacity})", tick=tick)

    def _prefill(self, req: Request) -> KVHandoff:
        s = len(req.prompt)
        assert s < self.max_seq
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        cache = M.init_cache(self.cfg, 1, self.max_seq, jnp.float32)
        logits, cache = self._prefill_fn(self.params,
                                         {"tokens": prompt}, cache)
        req.out.append(int(jnp.argmax(logits[0])))
        self.stats["prefills"] += 1
        return KVHandoff(req=req, cache=cache, pos=s, slo="", prefill_tick=0)

    def tick(self, t: int, handoff: KVHandoffQueue) -> int:
        """Prefill up to ``budget`` admitted requests while the handoff
        queue has room; returns the number prefilled this tick.  All
        lane work (controller replans, planner touches) runs under this
        cell's backend scope when one is set."""
        with _scope_ctx(self.scope):
            return self._tick(t, handoff)

    def _tick(self, t: int, handoff: KVHandoffQueue) -> int:
        self.stats["ticks"] += 1
        n = 0
        while ((self.budget is None or n < self.budget)
               and handoff.room() and len(self.queue)):
            req, slo, _ = self.queue.pop(t)
            item = self._prefill(req)
            item.slo, item.prefill_tick = slo, t
            self.prefill_ticks[req.rid] = t
            handoff.push(item)
            n += 1
        if self.controller is not None and n > 0:
            self.controller.observe(n)
        return n

    def report(self) -> dict:
        out = dict(self.stats)
        out["waiting"] = len(self.queue)
        if self.admission_capacity is not None:
            out["shed"] = len(self.shed)
        if self.controller is not None:
            out["policy"] = self.controller.report()
        return out


class DecodeCell:
    """Batched continuous-batching decode over KV-cache slots.

    The decode loop is the monolithic engine's, verbatim in semantics:
    one batched ``decode_step`` per tick over every active slot, one
    device argmax, slots freed the instant their request completes.
    Admission happens from the handoff queue instead of a waiting list
    — handed-off single-row caches merge into the batched cache at the
    lowest free slot, FIFO.
    """

    def __init__(self, cfg: ArchConfig, params, slots: int, max_seq: int,
                 planner: Optional[OffloadPlanner] = None,
                 controller: Optional[OffloadController] = None,
                 step_telemetry: bool = False, spec_decode=None,
                 scope: "lane_engine.BackendScope | None" = None):
        assert cfg.input_mode == "tokens", "cells serve token models"
        self.cfg, self.params = cfg, params
        self.slots = slots
        # Admission limit for autoscaling: the cache stays allocated at
        # ``slots`` (so growing is free) and only slots below ``limit``
        # accept new work; after a shrink, busy slots above the limit
        # finish their requests but are never refilled (lame-duck).
        self.limit = slots
        self.scope = scope
        self.max_seq = max_seq
        self.cache = M.init_cache(cfg, slots, max_seq, jnp.float32)
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, dtype=np.int32)
        self.controller = controller
        if planner is None and controller is not None:
            planner = controller.planner
        self.planner = planner
        self.stats = dict(steps=0, tokens=0)
        self.batch_occupancy: dict[int, int] = {}
        self.step_batches: list[int] = []
        self.step_telemetry = step_telemetry
        self.step_speedups: list[dict] = []
        self.admit_ticks: dict[int, int] = {}
        self.completions: dict[int, int] = {}
        # Speculative decoding: same seeded accept/advance schedule as
        # the monolithic engine (scenarios.SpecDecodeConfig or None).
        self.spec_decode = spec_decode
        self.spec_rounds: dict[int, int] = {}
        self.spec_drafted: dict[int, int] = {}
        self.spec_accepted: dict[int, int] = {}
        self.spec_advance: list[int] = []
        self.spec_substeps: list[int] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

    def free_slots(self) -> int:
        return sum(1 for r in self.active if r is None)

    def admit(self, handoff: KVHandoffQueue, tick: int) -> int:
        """Merge handed-off requests into free slots below the admission
        limit, FIFO, lowest slot first — zero lane work: the merge is a
        pure cache write."""
        n = 0
        for slot in range(min(self.slots, self.limit)):
            if self.active[slot] is None and len(handoff):
                item = handoff.pop(tick)

                def merge(full, one):
                    return full.at[:, slot:slot + 1].set(one)
                self.cache = jax.tree.map(merge, self.cache, item.cache)
                self.pos[slot] = item.pos
                self.active[slot] = item.req
                self.admit_ticks[item.req.rid] = tick
                n += 1
        return n

    def step(self, tick: int) -> int:
        """One batched decode step; returns the batch size (0 = idle).
        Runs under this cell's backend scope when one is set."""
        with _scope_ctx(self.scope):
            return self._step(tick)

    def _step(self, tick: int) -> int:
        act = [i for i, r in enumerate(self.active) if r is not None]
        if not act:
            return 0
        self.batch_occupancy[len(act)] = \
            self.batch_occupancy.get(len(act), 0) + 1
        if self.spec_decode is not None:
            self._spec_round(tick, act)
        else:
            tokens = np.zeros((self.slots, 1), dtype=np.int32)
            for i in act:
                tokens[i, 0] = self.active[i].out[-1]
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens), pos)
            next_tok = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
            for i in act:
                req = self.active[i]
                tok = int(next_tok[i])
                req.out.append(tok)
                self.pos[i] += 1
                self.stats["tokens"] += 1
                if (tok == req.eos or len(req.out) >= req.max_new
                        or self.pos[i] >= self.max_seq - 1):
                    req.done = True
                    self.active[i] = None
                    self.completions[req.rid] = tick
        self.step_batches.append(len(act))
        if self.controller is not None:
            self.controller.observe(len(act))
        if self.planner is not None and self.step_telemetry:
            tel = self.planner.decode_speedup(batch=len(act))
            self.step_speedups.append(dict(step=self.stats["steps"],
                                           batch=len(act),
                                           speedup=tel["speedup"]))
        self.stats["steps"] += 1
        return len(act)

    def _spec_round(self, tick: int, act: list[int]) -> None:
        """One speculative round per active slot — semantics identical
        to ``ServingEngine._spec_round`` (the differential battery
        holds the two implementations and the model-free mirror
        together)."""
        sd = self.spec_decode
        adv: dict[int, int] = {}
        for i in act:
            req = self.active[i]
            rem = max(1, req.max_new - len(req.out))
            a, drf, acc = sd.advance(req.rid,
                                     self.spec_rounds.get(req.rid, 0),
                                     rem)
            self.spec_rounds[req.rid] = \
                self.spec_rounds.get(req.rid, 0) + 1
            self.spec_drafted[req.rid] = \
                self.spec_drafted.get(req.rid, 0) + drf
            self.spec_accepted[req.rid] = \
                self.spec_accepted.get(req.rid, 0) + acc
            adv[i] = a
        nsub = max(adv.values())
        advanced = 0
        for s in range(nsub):
            live = [i for i in act
                    if s < adv[i] and self.active[i] is not None]
            if not live:
                break
            tokens = np.zeros((self.slots, 1), dtype=np.int32)
            for i in act:
                if self.active[i] is not None:
                    tokens[i, 0] = self.active[i].out[-1]
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens), pos)
            next_tok = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
            for i in live:
                req = self.active[i]
                tok = int(next_tok[i])
                req.out.append(tok)
                self.pos[i] += 1
                self.stats["tokens"] += 1
                advanced += 1
                if (tok == req.eos or len(req.out) >= req.max_new
                        or self.pos[i] >= self.max_seq - 1):
                    req.done = True
                    self.active[i] = None
                    self.completions[req.rid] = tick
        self.spec_advance.append(advanced)
        self.spec_substeps.append(nsub)


class DisaggServingEngine:
    """The composed cell pair: one ``step()`` call is one driver tick.

    Drop-in for :class:`ServingEngine` in the scenario driver — same
    ``submit`` / ``step`` / ``run`` / ``summary`` surface plus
    ``waiting`` / ``active`` / ``step_batches`` views — with the
    disaggregated internals: per-tick the prefill cell admits and
    prefills (SLO-aware, budgeted, handoff-bounded), the decode cell
    reclaims freed slots from the handoff queue and runs one batched
    decode step.
    """

    def __init__(self, cfg: ArchConfig, params, slots: int = 4,
                 max_seq: int = 256,
                 disagg: DisaggConfig | None = None,
                 planner: Optional[OffloadPlanner] = None,
                 controller: Optional[OffloadController] = None,
                 prefill_controller: Optional[OffloadController] = None,
                 step_telemetry: bool = False, spec_decode=None,
                 prefill_scope: "lane_engine.BackendScope | None" = None,
                 decode_scope: "lane_engine.BackendScope | None" = None):
        self.disagg = disagg or DisaggConfig.mirror()
        self.handoff = KVHandoffQueue(self.disagg.handoff_bound)
        self.prefill_cell = PrefillCell(
            cfg, params, max_seq, budget=self.disagg.prefill_budget,
            starvation_age=self.disagg.starvation_age,
            admission_capacity=self.disagg.admission_capacity,
            controller=prefill_controller, scope=prefill_scope)
        self.decode_cell = DecodeCell(cfg, params, slots, max_seq,
                                      planner=planner,
                                      controller=controller,
                                      step_telemetry=step_telemetry,
                                      spec_decode=spec_decode,
                                      scope=decode_scope)
        self.ticks = 0

    # -- ServingEngine-compatible views --------------------------------
    @property
    def active(self) -> list:
        return self.decode_cell.active

    @property
    def waiting(self) -> int:
        """Truthy while any request sits before its decode slot."""
        return len(self.prefill_cell.queue) + len(self.handoff)

    @property
    def step_batches(self) -> list[int]:
        return self.decode_cell.step_batches

    @property
    def completions(self) -> dict[int, int]:
        return self.decode_cell.completions

    @property
    def shed(self) -> dict[int, int]:
        """rid -> tick of every request dropped by admission shedding."""
        return self.prefill_cell.shed

    @property
    def planner(self):
        return self.decode_cell.planner

    @property
    def controller(self):
        return self.decode_cell.controller

    def submit(self, req: Request, slo: str = SLO_LATENCY) -> None:
        self.prefill_cell.submit(req, slo, self.ticks)

    def spec_report(self) -> dict:
        """Aggregate speculative telemetry — the decode cell's, in the
        monolithic engine's ``spec_report`` shape."""
        dec = self.decode_cell
        drafted = sum(dec.spec_drafted.values())
        accepted = sum(dec.spec_accepted.values())
        return dict(rounds=sum(dec.spec_rounds.values()),
                    drafted=drafted, accepted=accepted,
                    wasted=drafted - accepted,
                    substeps=sum(dec.spec_substeps),
                    per_tick_advance=list(dec.spec_advance))

    def step(self) -> bool:
        """One tick: prefill → handoff admission → batched decode.
        Returns True when the decode cell actually stepped."""
        t = self.ticks
        self.ticks += 1
        self.prefill_cell.tick(t, self.handoff)
        self.decode_cell.admit(self.handoff, t)
        return self.decode_cell.step(t) > 0

    def run(self, max_steps: int = 1000) -> dict:
        while (any(self.active) or self.waiting) and max_steps > 0:
            self.step()
            max_steps -= 1
        return self.summary()

    # -- reporting -----------------------------------------------------
    def request_ticks(self) -> dict:
        """Per-request scheduling record, keyed like the model-free
        simulator's output so the parity suite can diff them raw."""
        return dict(prefill_ticks=dict(self.prefill_cell.prefill_ticks),
                    admit_ticks=dict(self.decode_cell.admit_ticks),
                    completion_ticks=dict(self.decode_cell.completions))

    def wait_telemetry(self, tick: int | None = None) -> dict:
        """Per-class admission-wait ages of the requests still waiting
        — the live SLO pressure signal the autoscaler's grow rule reads
        each tick.  Neutral over empty queues (``max_wait=0``,
        ``mean_wait=0.0``), matching the zero-request convention."""
        t = self.ticks if tick is None else int(tick)
        ages: dict[str, list[int]] = {cls: [] for cls in SLO_CLASSES}
        for enq, slo in self.prefill_cell.queue.wait_entries():
            ages[slo].append(t - enq)
        out = {}
        for cls in SLO_CLASSES:
            a = ages[cls]
            out[cls] = dict(waiting=len(a),
                            max_wait=(max(a) if a else 0),
                            mean_wait=(sum(a) / len(a) if a else 0.0))
        return out

    def scopes_report(self) -> dict | None:
        """Per-cell backend-scope record (None when neither cell is
        scoped, so unscoped summaries/traces stay byte-identical)."""
        pre, dec = self.prefill_cell.scope, self.decode_cell.scope
        if pre is None and dec is None:
            return None
        return dict(
            prefill=(pre.describe() if pre is not None else None),
            decode=(dec.describe() if dec is not None else None))

    def _slo_summary(self) -> dict:
        """Per-class wait/latency means — neutral (0.0) over zero
        completions, never a divide by zero."""
        out = {}
        cell = self.prefill_cell
        for cls in SLO_CLASSES:
            rids = [r for r, s in cell.slo_of.items() if s == cls]
            done = [r for r in rids if r in self.completions]
            waits = [self.decode_cell.admit_ticks[r] - cell.enq_ticks[r]
                     for r in done]
            lats = [self.completions[r] - cell.enq_ticks[r] for r in done]
            out[cls] = dict(
                submitted=len(rids), completed=len(done),
                mean_admit_wait=(sum(waits) / len(done) if done else 0.0),
                mean_completion_ticks=(sum(lats) / len(done)
                                       if done else 0.0))
        return out

    def summary(self) -> dict:
        """The monolithic engine's summary shape (steps, tokens,
        prefills, occupancy, PIM telemetry, policy report) plus the
        disaggregation record under ``"disagg"``.  Every derived metric
        is neutral on zero-request runs."""
        dec = self.decode_cell
        steps = dec.stats["steps"]
        out = dict(steps=steps, tokens=dec.stats["tokens"],
                   prefills=self.prefill_cell.stats["prefills"])
        out["batch_occupancy"] = dict(dec.batch_occupancy)
        out["completed"] = len(self.completions)
        out["in_flight"] = (sum(r is not None for r in dec.active)
                            + self.waiting)
        out["tokens_per_step"] = (dec.stats["tokens"] / steps
                                  if steps else 0.0)
        if dec.planner is not None:
            tel = dec.planner.decode_speedup(batch=max(1, dec.slots))
            batches = sorted(dec.batch_occupancy) or [max(1, dec.slots)]
            tel["per_batch_speedup"] = {
                b: dec.planner.decode_speedup(batch=b)["speedup"]
                for b in batches}
            if dec.batch_occupancy:
                tel["occupancy_weighted"] = \
                    dec.planner.occupancy_weighted_speedup(
                        dec.batch_occupancy)
            if dec.step_speedups:
                tel["per_step"] = list(dec.step_speedups)
            out["pim_telemetry"] = tel
        if dec.controller is not None:
            out["policy"] = dec.controller.report()
        out["disagg"] = dict(
            config=self.disagg.to_record(),
            handoff=self.handoff.report(),
            prefill=self.prefill_cell.report(),
            slo={str(r): s for r, s in
                 sorted(self.prefill_cell.slo_of.items())},
            per_class=self._slo_summary(),
            requests={k: {str(r): t for r, t in sorted(v.items())}
                      for k, v in self.request_ticks().items()})
        if self.disagg.admission_capacity is not None:
            # Key present only under bounded admission so pre-shedding
            # golden traces stay byte-identical.
            out["disagg"]["shed"] = {
                str(r): t for r, t in sorted(self.shed.items())}
        scopes = self.scopes_report()
        if scopes is not None:
            # Same convention: only scoped cell pairs grow the key.
            out["disagg"]["scopes"] = scopes
        return out
