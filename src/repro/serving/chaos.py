"""Chaos harness: seeded fault timelines composed over serve scenarios.

``core/faults.py`` owns the primitives (injection seams, breaker,
retry, event log); this module owns the *choreography*: a
:class:`ChaosAction` timeline says which fault fires at which serve
tick, :func:`make_chaos_timeline` derives a deterministic timeline from
a seed and the process's active degradation ladder, and
:func:`run_chaos_scenario` drives a real scenario run
(``scenarios.run_scenario``) with the timeline firing from the driver's
``on_tick`` hook — retries backing off against a
:class:`~repro.core.faults.VirtualClock` so a chaos run never
real-sleeps.

The contract the chaos suite pins: because every ladder rung is
bit-identical and cache poison/eviction only changes *where* a lane
total comes from, a degraded run completes the same request set with
byte-identical per-request outputs as the healthy single-device scan
baseline — and for fault schedules that never touch scheduling (backend
faults, cache faults, planner faults) the whole exported trace is
byte-identical.  Scheduling faults (handoff pressure, admission
shedding) shift *when* work happens, never *what* it computes.

Every injected fault and every degradation step lands in the trace's
``"chaos"`` record (timeline + structured event log + breaker state),
so an incident is replayable from the trace alone:
``run_chaos_scenario`` with the same seed and config reproduces the
same faults at the same ticks, byte for byte.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import engine, faults
from .scenarios import ScenarioSpec, make_scenario, run_scenario

CHAOS_SITES = (
    "backend", "lane_cache.poison", "lane_cache.scrub",
    "lane_cache.storm", "handoff", "planner", "replan",
)

# Actions that neither arm faults nor corrupt state — the subset a
# fault-free baseline run replays so its control flow (replans, cache
# temperature) matches the chaos run's exactly, making the two traces
# byte-comparable.
NEUTRAL_ACTIONS = ("lane_cache.scrub", "lane_cache.storm", "replan")


@dataclasses.dataclass(frozen=True)
class ChaosAction:
    """One scheduled fault: at serve tick ``tick``, do ``action``.

    ``action`` vocabulary — ``backend.<rung>`` arms ``count`` injected
    failures at that ladder rung (``count < 0`` = persistent, the
    breaker-trip path); ``lane_cache.poison`` corrupts ``count`` cached
    lane entries in place; ``lane_cache.scrub`` runs the integrity sweep
    (deterministic detection of whatever poison is still unread);
    ``lane_cache.storm`` drops the whole lane LRU cold (the eviction
    storm's observable effect: every lane misses and re-resolves);
    ``handoff`` arms ``count`` ticks of simulated handoff-queue pressure
    (the prefill cell stalls); ``planner`` arms ``count`` planner
    failures (absorbed by retry, or degraded to host-only offload);
    ``replan`` forces the serve controller through a refresh re-plan —
    the chaos drill that makes the engine re-resolve lanes mid-run, so
    armed backend faults and cold caches are actually hit between the
    initial plan and drain.
    """

    tick: int
    action: str
    count: int = 1
    note: str = ""

    def to_record(self) -> dict:
        return dict(tick=self.tick, action=self.action,
                    count=self.count, note=self.note)

    @staticmethod
    def from_record(rec: dict) -> "ChaosAction":
        return ChaosAction(**rec)


def baseline_timeline(timeline: list[ChaosAction]) -> list[ChaosAction]:
    """The fault-free shadow of a timeline: only the neutral actions
    (scrubs, storms, forced replans) survive.  A healthy run driven by
    this shadow performs the identical planner queries and cache
    misses, so the parity suite can diff its trace byte-for-byte
    against the faulted run's."""
    return [a for a in timeline if a.action in NEUTRAL_ACTIONS]


def apply_action(act: ChaosAction, inj: faults.FaultInjector,
                 eng=None) -> None:
    """Fire one timeline action (called at its tick by the driver)."""
    if act.action.startswith("backend."):
        inj.arm(act.action, count=act.count,
                message=act.note or f"chaos: {act.action}")
    elif act.action == "lane_cache.poison":
        n = engine.lane_cache_poison(act.count, seed=act.tick)
        faults.record_event("lane_cache", "inject",
                            f"poisoned {n} cached lane entries")
    elif act.action == "lane_cache.scrub":
        engine.lane_cache_verify()
    elif act.action == "lane_cache.storm":
        info = engine.lane_cache_info()
        engine.lane_cache_clear()
        faults.record_event(
            "lane_cache", "inject",
            f"eviction storm: {info['size']} entries dropped cold")
    elif act.action in ("handoff", "planner"):
        inj.arm(act.action, count=act.count,
                message=act.note or f"chaos: {act.action} pressure")
    elif act.action == "replan":
        ctrl = getattr(eng, "controller", None)
        if ctrl is not None:
            batch = ctrl.trace[-1].batch if ctrl.trace else 1
            ctrl.replan(batch, refresh=True)
    else:
        raise ValueError(f"unknown chaos action {act.action!r}")


def make_chaos_timeline(seed: int = 0, horizon: int = 30,
                        rungs: list[str] | None = None,
                        scheduling: bool = True,
                        scope=None) -> list[ChaosAction]:
    """A deterministic fault timeline covering every seam.

    Same ``(seed, horizon, rungs, scheduling)`` always yields the same
    actions at the same ticks.  ``scope`` — an optional
    :class:`~repro.core.engine.BackendScope`: the default rung list is
    then that scope's ladder (``engine.ladder_rungs(scope)``), so a
    timeline aimed at one serve cell arms faults on the rungs that cell
    will actually resolve through, not the process default's.  The composition: one transient fault on
    the top ladder rung early (absorbed by retry), one persistent burst
    on the top rung mid-run when a lower rung exists (trips the breaker,
    steps the ladder down), a lane-cache poison paired with a scrub one
    tick later (deterministic detection), an eviction storm, a planner
    fault armed before the first plan, and — when ``scheduling`` —
    handoff pressure.  ``scheduling=False`` yields a timeline whose
    faults provably cannot move work between ticks, the schedules the
    byte-identical-trace parity tests run.
    """
    rungs = (list(rungs) if rungs is not None
             else engine.ladder_rungs(scope))
    rng = np.random.default_rng(seed)
    top = "backend." + rungs[0]
    acts = [
        ChaosAction(0, "planner", 1, "planner timeout before first plan"),
        ChaosAction(0, top, 1, "transient fault on the initial plan"),
    ]
    # Poison a couple of cached lanes and catch them with a scrub.
    t0 = 2 + int(rng.integers(0, max(horizon // 4, 1)))
    acts.append(ChaosAction(t0, "lane_cache.poison",
                            1 + int(rng.integers(0, 2))))
    acts.append(ChaosAction(t0 + 1, "lane_cache.scrub", 0))
    if len(rungs) > 1:
        acts.append(ChaosAction(
            t0 + 1, top, -1,
            "persistent: trip the breaker, step the ladder down"))
    # Eviction-storm + forced-replan pairs (the storm sorts first at
    # equal ticks): each drops the cache fully cold and immediately
    # re-plans, so every pair re-resolves the identical lane set — the
    # faulted and baseline runs' miss counters stay in lockstep — and
    # each cold resolve hits whatever is armed.  Four pairs trip a
    # persistent top-rung fault through the default K=3 breaker and
    # leave the last resolve on the skip path.
    gap = max(2, horizon // 8)
    for k in range(4):
        acts.append(ChaosAction(t0 + 2 + k * gap, "lane_cache.storm", 0))
        acts.append(ChaosAction(t0 + 2 + k * gap, "replan", 0,
                                f"forced refresh replan {k + 1}/4"))
    if scheduling:
        acts.append(ChaosAction(int(rng.integers(2, max(horizon - 2, 3))),
                                "handoff", int(rng.integers(1, 4))))
    return sorted(acts, key=lambda a: (a.tick, a.action))


def run_chaos_scenario(cfg, params, planner,
                       scenario: "ScenarioSpec | None" = None,
                       seed: int = 0, quick: bool = False,
                       slots: int = 8, policy: str = "sticky",
                       fence: bool = True,
                       timeline: "list[ChaosAction] | None" = None,
                       breaker_threshold: int = 3, retries: int = 1,
                       mesh=None, disagg=False, slo=None,
                       spec_decode=None,
                       policy_kw: dict | None = None,
                       prefill_scope=None, decode_scope=None) -> dict:
    """Serve a scenario under a seeded fault timeline; return the trace.

    Resets the fault state (events, breaker with ``breaker_threshold``,
    a fresh injector), runs ``scenarios.run_scenario`` with the timeline
    firing via ``on_tick``, retry backoffs on a
    :class:`~repro.core.faults.VirtualClock` (no real sleeps), and
    attaches the incident record under ``trace["chaos"]``: the timeline,
    every structured fault/degradation event (tick-tagged), the breaker
    state and the simulated backoff sleeps.  Deterministic end to end —
    the golden chaos trace pins the whole record byte-exactly.

    ``prefill_scope`` / ``decode_scope`` (require ``disagg``) give each
    cell its own :class:`~repro.core.engine.BackendScope` — its own
    backend, ladder and circuit breaker.  Faults then trip the breaker
    of whichever cell resolved through them, never the process-global
    one, and the incident record gains a ``scope_breakers`` key (each
    scope's breaker state, keyed by scope name; present only when
    scoped, so the pinned golden chaos trace stays byte-identical).
    """
    with engine.lane_mesh_scope(mesh):
        spec = scenario if scenario is not None else \
            make_scenario("chaos", seed=seed, slots=slots, quick=quick)
        if timeline is None:
            horizon = (max(a.step for a in spec.arrivals) + 1
                       if spec.arrivals else 1)
            timeline = make_chaos_timeline(seed, horizon=max(horizon, 8))
        by_tick: dict[int, list[ChaosAction]] = {}
        for act in timeline:
            by_tick.setdefault(act.tick, []).append(act)
        clock = faults.VirtualClock()
        inj = faults.FaultInjector()
        faults.reset_events()
        faults.configure_breaker(breaker_threshold)

        def on_tick(t: int, eng) -> None:
            faults.set_tick(t)
            for act in by_tick.get(t, ()):
                apply_action(act, inj, eng)

        try:
            with faults.fault_scope(inj), \
                    faults.retry_scope(retries=retries, clock=clock):
                trace = run_scenario(
                    spec, cfg, params, planner, policy=policy,
                    fence=fence, policy_kw=policy_kw,
                    mesh=engine.lane_mesh(), disagg=disagg, slo=slo,
                    spec_decode=spec_decode,
                    prefill_scope=prefill_scope,
                    decode_scope=decode_scope, on_tick=on_tick)
        finally:
            faults.set_tick(None)
    trace["chaos"] = dict(
        seed=seed,
        breaker_threshold=breaker_threshold,
        retries=retries,
        timeline=[a.to_record() for a in timeline],
        injected=inj.injected,
        events=faults.events(),
        breaker=faults.backend_breaker().info(),
        backoff_sleeps=list(clock.sleeps),
    )
    scoped = [s for s in (prefill_scope, decode_scope)
              if s is not None and s.breaker is not None]
    if scoped:
        trace["chaos"]["scope_breakers"] = {
            s.name or f"scope{i}": s.breaker.info()
            for i, s in enumerate(scoped)}
    return trace
