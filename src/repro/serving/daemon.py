"""Long-running serve daemon over the disaggregated cell pair.

The scenario driver (``scenarios.run_scenario``) serves a finite,
pre-scripted arrival schedule and exits — fine for parity batteries,
not for the ROADMAP's "heavy traffic from millions of users".  This
module daemonizes the cell pair:

* :class:`ServeDaemon` drives a :class:`~.cells.DisaggServingEngine`
  tick by tick from *asynchronous* arrival sources — a seeded scenario
  arrival process (the same generators every battery uses) merged with
  an injectable thread-safe arrival queue (:meth:`ServeDaemon.inject`)
  — and exposes drain (stop ingest, serve out every queued request)
  and hard shutdown (stop now, account for every request) with the
  drain diagnostics PR 8 added (:class:`~.scenarios.ScenarioDrainError`
  on a stuck drain).  Idle ticks wait on the shared clock protocol
  (``faults.VirtualClock`` / ``faults.SystemClock``), so daemon tests
  never real-sleep.
* :class:`TraceWriter` streams the run's trace as tick-ordered JSONL
  chunks with a bounded in-memory buffer, so million-request runs never
  hold their trace in RAM; :meth:`TraceWriter.load` reassembles a trace
  byte-identical to the in-memory path, replayable through the existing
  ``scenarios.replay_trace``.
* :class:`AutoscaleController` grows/shrinks the decode cell's
  admission limit against the per-class SLO wait telemetry the cells
  report — the real-cell implementation of the
  :class:`~.scenarios.AutoscaleConfig` rule, which
  ``scenarios.simulate_disagg`` specifies model-free; the differential
  parity suite holds the two together tick-exactly.

Per-cell :class:`~repro.core.engine.BackendScope` objects ride through
unchanged: a daemon whose prefill cell degrades to a lower rung keeps
its decode cell's ladder — and its bytes — untouched.
"""
from __future__ import annotations

import json
import threading

import numpy as np

from repro.core import faults
from repro.core import engine as lane_engine
from .engine import Request
from .scenarios import (AutoscaleConfig, DisaggConfig, ScenarioDrainError,
                        ScenarioSpec, SLO_LATENCY)


class AutoscaleController:
    """Cross-cell decode-slot autoscaling over the live cell pair.

    The independent real-cell implementation of THE grow/shrink rule
    :class:`~.scenarios.AutoscaleConfig` documents (and
    ``simulate_disagg(..., autoscale=...)`` implements model-free):
    grow the decode admission limit on per-class SLO wait pressure,
    shrink it on sustained idleness, one slot per action, with a
    cooldown between actions.  ``observe(t)`` must run once at the end
    of every engine tick — the recorded ``limits`` trace is the limit
    that was in force *during* that tick, which is what the parity
    battery diffs against the simulator's.
    """

    def __init__(self, cfg: AutoscaleConfig, engine):
        self.cfg = cfg
        self.eng = engine
        cap = engine.decode_cell.slots
        self.max_slots = min(cfg.max_slots or cap, cap)
        self.limit = min(cfg.start_slots or cfg.min_slots, self.max_slots)
        engine.decode_cell.limit = self.limit
        self.limits: list[int] = []
        self.grows = 0
        self.shrinks = 0
        self._cool = 0
        self._idle = 0

    def observe(self, t: int) -> int:
        """Apply the end-of-tick rule; returns the limit for the next
        tick.  Mirrors ``simulate_disagg``'s autoscale block exactly —
        same telemetry, same branch order, same counters."""
        eng = self.eng
        self.limits.append(self.limit)
        busy = sum(1 for r in eng.decode_cell.active if r is not None)
        pressure = sum(
            1 for enq, slo in eng.prefill_cell.queue.wait_entries()
            if t - enq >= self.cfg.class_wait(slo))
        if self._cool > 0:
            self._cool -= 1
        elif pressure > 0 and self.limit < self.max_slots:
            self.limit += 1
            self.grows += 1
            self._cool = self.cfg.cooldown
            self._idle = 0
        elif (len(eng.prefill_cell.queue) == 0 and len(eng.handoff) == 0
              and busy < self.limit):
            self._idle += 1
            if (self._idle >= self.cfg.idle_ticks
                    and self.limit > self.cfg.min_slots):
                self.limit -= 1
                self.shrinks += 1
                self._cool = self.cfg.cooldown
                self._idle = 0
        else:
            self._idle = 0
        eng.decode_cell.limit = self.limit
        return self.limit

    def report(self) -> dict:
        """Trace record: embedded config (for replay) + the per-tick
        limit trace + action counts + slot-ticks actually provisioned
        (the fixed-slot oracle would provision ``slots * ticks``)."""
        return dict(config=self.cfg.to_record(),
                    limits=list(self.limits),
                    grows=self.grows, shrinks=self.shrinks,
                    slot_ticks=sum(self.limits))


class TraceWriter:
    """Streaming trace export: tick-ordered JSONL, bounded memory.

    Records are written as canonical JSON lines (sorted keys) in three
    kinds — one ``meta`` record first (the trace's scalar header:
    scenario, policy, fence), one ``tick`` record per driver tick, one
    ``summary`` record last (everything else).  Lines accumulate in a
    buffer of at most ``chunk_records`` and are flushed chunk-wise, so
    the writer's memory never grows with the run; :meth:`load`
    reassembles the trace dict from the chunks byte-identically to the
    in-memory path (the daemon battery asserts the canonical dumps are
    equal), and the result replays through ``scenarios.replay_trace``
    like any recorded trace.
    """

    def __init__(self, path, chunk_records: int = 256):
        if chunk_records < 1:
            raise ValueError("chunk_records must be >= 1")
        self.path = str(path)
        self.chunk_records = int(chunk_records)
        self._f = open(self.path, "w", encoding="utf-8")
        self._buf: list[str] = []
        self._ticks = 0
        self.records = 0
        self.flushes = 0
        self._closed = False

    def _write(self, record: dict) -> None:
        self._buf.append(json.dumps(record, sort_keys=True))
        self.records += 1
        if len(self._buf) >= self.chunk_records:
            self.flush()

    def write_meta(self, **fields) -> None:
        self._write(dict(kind="meta", **fields))

    def write_tick(self, tick: int, batch: int) -> None:
        if tick != self._ticks:
            raise ValueError(f"tick records must be tick-ordered: "
                             f"expected {self._ticks}, got {tick}")
        self._ticks += 1
        self._write(dict(kind="tick", tick=int(tick), batch=int(batch)))

    def write_summary(self, fields: dict) -> None:
        self._write(dict(kind="summary", summary=fields))

    def flush(self) -> None:
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._f.flush()
            self._buf.clear()
            self.flushes += 1

    def close(self) -> None:
        if not self._closed:
            self.flush()
            self._f.close()
            self._closed = True

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    @staticmethod
    def load(path) -> dict:
        """Reassemble a streamed trace into the in-memory trace dict.

        Concatenated chunks parse line-wise; ``tick`` records (asserted
        contiguous and in order) become ``per_tick_batch``, and the
        ``meta`` / ``summary`` records merge into the scalar keys —
        byte-identical, under canonical JSON dumps, to the trace the
        daemon would have built in RAM.
        """
        meta: dict = {}
        summary: dict = {}
        per_tick: list[int] = []
        with open(str(path), encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                kind = rec.pop("kind")
                if kind == "meta":
                    meta.update(rec)
                elif kind == "tick":
                    if rec["tick"] != len(per_tick):
                        raise ValueError(
                            f"trace stream out of order: tick "
                            f"{rec['tick']} at position {len(per_tick)}")
                    per_tick.append(rec["batch"])
                elif kind == "summary":
                    summary.update(rec["summary"])
                else:
                    raise ValueError(f"unknown trace record kind {kind!r}")
        return dict(**meta, per_tick_batch=per_tick, **summary)


class ServeDaemon:
    """Continuous driver for the disaggregated cell pair.

    One instance owns one :class:`~.cells.DisaggServingEngine` (built
    with the same controller/planner wiring as ``run_scenario``) and
    serves two arrival sources merged tick by tick:

    * a seeded **scenario arrival process** (``scenario=``, any
      :class:`~.scenarios.ScenarioSpec` from the generators) whose
      arrivals are submitted when their tick comes up, and
    * an **injectable queue** (:meth:`inject`, thread-safe) drained at
      the top of every tick — the asynchronous path a live frontend
      would use.

    Lifecycle: :meth:`run` ticks until the daemon is *draining* (see
    :meth:`drain`) and empty, until ``max_requests`` have completed
    (auto-drain), or until :meth:`shutdown` (hard stop).  Every request
    is accounted — :meth:`accounting` proves
    ``ingested == completed + shed + in_flight`` and reports arrivals
    never submitted (``dropped``) after a hard stop.  Idle ticks (no
    submission, no prefill, no decode) wait ``idle_wait`` seconds on
    the configured clock — a ``faults.VirtualClock`` in tests, the
    shared ``SystemClock`` live — never a bare ``time.sleep``.

    In scenario mode with no injected arrivals the daemon's tick loop
    is tick-for-tick the ``run_scenario`` driver, so :meth:`trace`
    (or the streamed :class:`TraceWriter` equivalent) is a standard
    replayable trace record.
    """

    def __init__(self, cfg, params, planner,
                 scenario: ScenarioSpec | None = None,
                 policy: str = "per-step", fence: bool = True,
                 max_seq: int | None = None,
                 policy_kw: dict | None = None,
                 disagg: "DisaggConfig | None" = None,
                 slo: dict[int, str] | None = None,
                 spec_decode=None,
                 autoscale: AutoscaleConfig | None = None,
                 prefill_scope: "lane_engine.BackendScope | None" = None,
                 decode_scope: "lane_engine.BackendScope | None" = None,
                 max_requests: int | None = None,
                 writer: TraceWriter | None = None,
                 clock=None, idle_wait: float = 0.0,
                 on_tick=None):
        from .cells import DisaggServingEngine
        from .policy import OffloadController

        self.cfg, self.params, self.planner = cfg, params, planner
        self.scenario = scenario
        self.fence = fence
        self.controller = OffloadController(planner, policy=policy,
                                            fence=fence,
                                            **(policy_kw or {}))
        self.disagg = disagg or DisaggConfig.mirror()
        self.slo = dict(slo or {})
        self.spec_decode = spec_decode
        self.max_requests = max_requests
        self.writer = writer
        self.clock = clock if clock is not None else faults.SYSTEM_CLOCK
        self.idle_wait = float(idle_wait)
        self.on_tick = on_tick

        arrivals = list(scenario.arrivals) if scenario is not None else []
        if max_seq is None:
            max_seq = max((a.prompt_len + a.max_new for a in arrivals),
                          default=16)
            max_seq = max(64, 2 * max_seq)
        self.max_seq = max_seq
        slots = scenario.slots if scenario is not None else 4
        self.eng = DisaggServingEngine(
            cfg, params, slots=slots, max_seq=max_seq,
            disagg=self.disagg, controller=self.controller,
            spec_decode=spec_decode,
            prefill_scope=prefill_scope, decode_scope=decode_scope)
        self.scaler = (AutoscaleController(autoscale, self.eng)
                       if autoscale is not None else None)
        if spec_decode is not None:
            planner.plan_draft(fence=fence)

        # Seeded scenario arrivals: same request materialization as the
        # scenario driver (token values from seed+1), so a pure-scenario
        # daemon run emits the driver's exact trace.
        self._pending = sorted(arrivals, key=lambda a: (a.step, a.rid))
        self._rng = np.random.default_rng(
            (scenario.seed if scenario is not None else 0) + 1)
        self._reqs = {a.rid: Request(
            rid=a.rid,
            prompt=self._rng.integers(0, cfg.vocab, size=a.prompt_len),
            max_new=a.max_new) for a in self._pending}
        self._next_arrival = 0
        self._next_rid = max((a.rid for a in arrivals), default=-1) + 1

        # The injectable asynchronous arrival queue.
        self._inbox: list[tuple[Request, str]] = []
        self._inbox_lock = threading.Lock()

        self._draining = False
        self._stopped = False
        self.idle_ticks = 0
        self.dropped: dict[int, int] = {}       # rid -> drop tick
        self.ingested = 0
        self._per_tick: list[int] | None = ([] if writer is None else None)
        if writer is not None and scenario is not None:
            writer.write_meta(scenario=scenario.to_record(),
                              policy=self.controller.policy.name,
                              fence=fence)

    # -- arrival sources -----------------------------------------------
    def inject(self, prompt_len: int, max_new: int,
               slo: str = SLO_LATENCY, rid: int | None = None) -> int:
        """Queue one asynchronous arrival (thread-safe); returns its
        rid.  Rejected (ValueError) once the daemon is draining — a
        draining daemon serves out, it does not ingest."""
        if self._draining or self._stopped:
            raise ValueError("daemon is draining/stopped; "
                             "not accepting arrivals")
        with self._inbox_lock:
            if rid is None:
                rid = self._next_rid
                self._next_rid += 1
            req = Request(rid=rid,
                          prompt=self._rng.integers(0, self.cfg.vocab,
                                                    size=prompt_len),
                          max_new=max_new)
            self._inbox.append((req, slo))
        return rid

    # -- lifecycle ------------------------------------------------------
    def drain(self) -> None:
        """Stop ingesting (scenario arrivals not yet due are dropped,
        injections rejected) and serve out everything queued."""
        self._draining = True

    def shutdown(self) -> None:
        """Hard stop: no more ticks.  Whatever was queued stays queued
        — :meth:`accounting` itemizes it, nothing goes missing."""
        self._draining = True
        self._stopped = True

    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def stopped(self) -> bool:
        return self._stopped

    def _drained(self) -> bool:
        return (not any(self.eng.active) and not self.eng.waiting
                and not self._inbox
                and self._next_arrival >= len(self._pending))

    def step(self) -> int:
        """One daemon tick: fire hooks, ingest due arrivals (scenario +
        injected), tick the cell pair, autoscale, record the trace
        tick.  Returns the decode batch size."""
        t = self.eng.ticks
        if self.on_tick is not None:
            self.on_tick(t, self.eng)
        if self.spec_decode is not None:
            self.planner.touch_draft(fence=self.fence)
        if self._draining:
            # Drop (account, don't serve) scenario arrivals not yet due.
            while self._next_arrival < len(self._pending):
                a = self._pending[self._next_arrival]
                self.dropped[a.rid] = t
                self._next_arrival += 1
        submitted = 0
        while (self._next_arrival < len(self._pending)
               and self._pending[self._next_arrival].step <= t):
            a = self._pending[self._next_arrival]
            self.eng.submit(self._reqs[a.rid],
                            slo=self.slo.get(a.rid, SLO_LATENCY))
            self.ingested += 1
            self._next_arrival += 1
            submitted += 1
        with self._inbox_lock:
            inbox, self._inbox = self._inbox, []
        for req, slo in inbox:
            self.slo[req.rid] = slo
            self.eng.submit(req, slo=slo)
            self.ingested += 1
            submitted += 1
        prefilled = len(self.eng.prefill_cell.prefill_ticks)
        stepped = self.eng.step()
        prefilled = (len(self.eng.prefill_cell.prefill_ticks)
                     - prefilled)
        batch = self.eng.step_batches[-1] if stepped else 0
        if self.scaler is not None:
            self.scaler.observe(t)
        if self.writer is not None:
            self.writer.write_tick(t, batch)
        elif self._per_tick is not None:
            self._per_tick.append(batch)
        if submitted == 0 and prefilled == 0 and batch == 0:
            self.idle_ticks += 1
            if self.idle_wait > 0:
                self.clock.sleep(self.idle_wait)
        return batch

    def run(self, max_ticks: int = 1_000_000) -> dict:
        """Tick until drained (after :meth:`drain` or request/scenario
        exhaustion), ``max_requests`` completions (auto-drain), or
        :meth:`shutdown`.  A drain that fails to empty the cells within
        ``max_ticks`` raises :class:`ScenarioDrainError` with the PR 8
        queue diagnostics.  Returns :meth:`report`."""
        ticks = 0
        while not self._stopped:
            if self._drained():
                if self._draining or self.scenario is not None:
                    # A pure-scenario daemon completes like the driver;
                    # an injectable daemon only exits via drain().
                    break
            self.step()
            if (self.max_requests is not None
                    and len(self.eng.completions) >= self.max_requests):
                self.drain()
            ticks += 1
            if ticks > max_ticks:
                eng = self.eng
                queued = ([e[2].rid for e in
                           eng.prefill_cell.queue._entries]
                          + [h.req.rid for h in eng.handoff._q])
                raise ScenarioDrainError(
                    self.scenario.name if self.scenario else "daemon",
                    max_ticks,
                    queues=dict(waiting=len(eng.prefill_cell.queue),
                                handoff=len(eng.handoff),
                                pending=(len(self._pending)
                                         - self._next_arrival)),
                    oldest_age=(eng.ticks - min(
                        enq for enq, _ in
                        eng.prefill_cell.queue.wait_entries())
                        if len(eng.prefill_cell.queue) else None),
                    last_batch=[r.rid for r in eng.active
                                if r is not None])
        if self.writer is not None:
            self.writer.write_summary(self._summary_fields())
            self.writer.close()
        return self.report()

    # -- reporting ------------------------------------------------------
    def accounting(self) -> dict:
        """Request conservation: every arrival the daemon ever saw is
        exactly one of completed / shed / in flight / dropped.  The
        hard-shutdown battery asserts the invariant."""
        eng = self.eng
        in_flight = (len(eng.prefill_cell.queue) + len(eng.handoff)
                     + sum(r is not None for r in eng.active))
        out = dict(ingested=self.ingested,
                   completed=len(eng.completions),
                   shed=len(eng.shed),
                   in_flight=in_flight,
                   dropped=len(self.dropped),
                   queued_inbox=len(self._inbox))
        assert (out["ingested"]
                == out["completed"] + out["shed"] + out["in_flight"]), \
            f"request conservation violated: {out}"
        return out

    def _summary_fields(self) -> dict:
        stats = self.eng.summary()
        fields = dict(
            occupancy={str(k): v for k, v in
                       sorted(stats["batch_occupancy"].items())},
            steps=stats["steps"], tokens=stats["tokens"],
            prefills=stats["prefills"],
            controller=self.controller.report(),
            per_step=[r.to_record() for r in self.controller.trace],
            disagg=stats["disagg"],
        )
        if self.scaler is not None:
            fields["autoscale"] = self.scaler.report()
        if self.spec_decode is not None:
            fields["spec_decode"] = dict(
                config=self.spec_decode.to_record(),
                **self.eng.spec_report())
        return fields

    def trace(self) -> dict:
        """The in-memory trace record (scenario mode, no writer) — the
        same shape ``run_scenario`` emits, so it pins, diffs and
        replays like any recorded trace."""
        if self.scenario is None:
            raise ValueError("trace() needs a scenario-mode daemon")
        if self._per_tick is None:
            raise ValueError("trace() unavailable when streaming to a "
                             "TraceWriter — use TraceWriter.load()")
        return dict(scenario=self.scenario.to_record(),
                    policy=self.controller.policy.name,
                    fence=self.fence,
                    per_tick_batch=list(self._per_tick),
                    **self._summary_fields())

    def report(self) -> dict:
        """Operational snapshot: lifecycle state, accounting, queue and
        autoscale telemetry, per-cell scope records when scoped."""
        eng = self.eng
        out = dict(draining=self._draining, stopped=self._stopped,
                   ticks=eng.ticks, idle_ticks=self.idle_ticks,
                   accounting=self.accounting(),
                   handoff_wait=eng.handoff.wait_report(),
                   slo_wait=eng.wait_telemetry())
        if self.scaler is not None:
            out["autoscale"] = self.scaler.report()
        scopes = eng.scopes_report()
        if scopes is not None:
            out["scopes"] = scopes
        return out
