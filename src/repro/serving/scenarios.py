"""Trace-driven serving scenarios: seeded workloads + replayable traces.

A scenario is a deterministic arrival schedule — request id, arrival
tick, prompt length, decode budget — produced by a seeded generator.
Five load shapes cover the serving regimes the offload policies must
survive:

* ``steady``        — one request every few ticks, stable occupancy.
* ``bursty``        — Poisson arrivals whose rate spikes in short burst
                      windows (the queue oscillates across the offload
                      crossover batch).
* ``diurnal``       — sinusoidal arrival rate, a slow ramp up and down.
* ``prefill-heavy`` — few requests, long prompts, short decode budgets.
* ``drain-refill``  — waves separated by idle gaps (occupancy collapses
                      to zero and refills from empty).
* ``chaos``         — heavy pressure spikes over a low background rate,
                      sized so bounded admission/handoff configs shed:
                      the arrival schedule the fault-injection harness
                      (``serving/chaos.py``) composes fault timelines
                      over.
* ``spec-decode``   — small prompts with long decode budgets, the
                      draft/verify speculative regime: served with a
                      :class:`SpecDecodeConfig`, acceptance-dependent
                      multi-token advances swing completion times and
                      occupancy in ways no fixed-budget schedule does.

``simulate_batches`` mirrors :class:`ServingEngine`'s admission and
completion semantics exactly (requests finish on their decode budget,
never on EOS), so a scenario's per-tick occupancy trace is available
*without* running a model — that is what the policy benchmarks, the
dry-run closed loop and the property tests drive.  ``simulate_disagg``
is the same model-free mirror for the disaggregated prefill/decode
cell pair (``serving/cells.py``): SLO-classed admission
(``_admission_pick`` is THE order spec), budgeted prefill, a bounded
KV-handoff queue and continuous-batching decode.
``simulate_spec_decode`` is the mirror for speculative serving: the
seeded accept/advance round math in :class:`SpecDecodeConfig` is THE
spec both it and the real engines realize, keyed per (request, round)
so it is independent of slot processing order.  ``run_scenario``
drives the real engine end to end (model decode included, monolithic
or ``disagg=``, vanilla or ``spec_decode=``) and emits a replayable
trace record; one bursty trace per engine shape is pinned byte-exactly
in ``tests/golden/serve_trace.json`` / ``tests/golden/disagg_trace.json``
/ ``tests/golden/spec_decode_trace.json``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


class ScenarioDrainError(RuntimeError):
    """A scenario failed to drain within its tick bound.

    Carries the queue state at the moment of failure so a wedged run is
    diagnosable from the exception alone: per-queue depths, the age of
    the oldest still-queued request, and the last tick's batch
    composition.
    """

    def __init__(self, name: str, tick: int, queues: dict[str, int],
                 oldest_age: int | None, last_batch):
        self.name = name
        self.tick = tick
        self.queues = dict(queues)
        self.oldest_age = oldest_age
        self.last_batch = list(last_batch)
        depths = ", ".join(f"{q}={d}" for q, d in self.queues.items())
        age = "n/a" if oldest_age is None else f"{oldest_age} ticks"
        super().__init__(
            f"scenario {name!r} did not drain within {tick} ticks: "
            f"queue depths [{depths}], oldest queued request age {age}, "
            f"last-tick batch {self.last_batch}")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of a scenario schedule (all scheduling, no tokens)."""

    rid: int
    step: int          # driver tick at which the request is submitted
    prompt_len: int
    max_new: int

    def decode_steps(self) -> int:
        # Prefill emits the first token; the engine marks a request done
        # after the decode step that reaches max_new, so a request holds
        # its slot for max(1, max_new - 1) decode steps.
        return max(1, self.max_new - 1)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    seed: int
    slots: int
    arrivals: tuple

    def to_record(self) -> dict:
        return dict(name=self.name, seed=self.seed, slots=self.slots,
                    arrivals=[dataclasses.asdict(a) for a in self.arrivals])

    @staticmethod
    def from_record(rec: dict) -> "ScenarioSpec":
        return ScenarioSpec(
            name=rec["name"], seed=rec["seed"], slots=rec["slots"],
            arrivals=tuple(Arrival(**a) for a in rec["arrivals"]))


def _pack(name: str, seed: int, slots: int, raw) -> ScenarioSpec:
    """Sort (step, order) and assign dense rids — determinism lives here."""
    arrivals = tuple(Arrival(rid=i, step=int(s), prompt_len=int(p),
                             max_new=int(m))
                     for i, (s, p, m) in enumerate(raw))
    return ScenarioSpec(name=name, seed=seed, slots=slots,
                        arrivals=arrivals)


def _steady(rng, slots: int, quick: bool):
    n = 8 if quick else 24
    gap = 2
    return [(i * gap, rng.integers(4, 12), rng.integers(4, 8))
            for i in range(n)]


def _bursty(rng, slots: int, quick: bool):
    horizon = 40 if quick else 120
    n_bursts = 2 if quick else 5
    burst_at = sorted(rng.choice(horizon - 6, size=n_bursts,
                                 replace=False))
    raw = []
    for t in range(horizon):
        lam = 0.12
        for b in burst_at:
            if b <= t < b + 3:
                lam = 1.6
        for _ in range(rng.poisson(lam)):
            raw.append((t, rng.integers(4, 12), rng.integers(3, 9)))
    return raw


def _diurnal(rng, slots: int, quick: bool):
    horizon = 48 if quick else 144
    period = horizon / 2
    raw = []
    for t in range(horizon):
        lam = 0.55 * (1.0 + math.sin(2.0 * math.pi * t / period))
        for _ in range(rng.poisson(lam)):
            raw.append((t, rng.integers(4, 12), rng.integers(3, 8)))
    return raw


def _prefill_heavy(rng, slots: int, quick: bool):
    n = 6 if quick else 16
    gap = 3
    return [(i * gap, rng.integers(24, 48), rng.integers(2, 5))
            for i in range(n)]


def _drain_refill(rng, slots: int, quick: bool):
    waves = 2 if quick else 4
    wave_size = slots + 2
    max_new_hi = 7
    # A wave of wave_size requests over `slots` drains in at most
    # ceil(wave_size / slots) * (max_new_hi - 1) decode ticks; the gap
    # guarantees an idle stretch between waves.
    wave_gap = -(-wave_size // slots) * (max_new_hi - 1) + 6
    raw = []
    for w in range(waves):
        for _ in range(wave_size):
            raw.append((w * wave_gap, rng.integers(4, 12),
                        rng.integers(3, max_new_hi)))
    return raw


def _chaos(rng, slots: int, quick: bool):
    # Short, hard pressure spikes over a trickle background: queues
    # deepen fast enough that bounded admission capacities actually
    # shed, and the idle stretches between spikes let the degradation
    # ladder's retries/replans land on a drained system.
    horizon = 30 if quick else 90
    raw = []
    for t in range(horizon):
        lam = 2.4 if t % 12 < 3 else 0.25
        for _ in range(rng.poisson(lam)):
            raw.append((t, rng.integers(4, 14), rng.integers(3, 8)))
    return raw


def _spec_decode(rng, slots: int, quick: bool):
    # The draft/verify regime: small prompts, long decode budgets (the
    # shapes speculative decoding pays for), paced so acceptance-
    # dependent completion swings push the occupancy back and forth
    # across the offload crossover batch.
    horizon = 12 if quick else 36
    raw = []
    for t in range(0, horizon, 2):
        for _ in range(int(rng.integers(1, 3))):
            raw.append((t, rng.integers(4, 10), rng.integers(8, 25)))
    return raw


SCENARIOS = {
    "steady": _steady,
    "bursty": _bursty,
    "diurnal": _diurnal,
    "prefill-heavy": _prefill_heavy,
    "drain-refill": _drain_refill,
    "chaos": _chaos,
    "spec-decode": _spec_decode,
}


def resolve_scenario(name: str) -> str:
    """Canonicalize a scenario name or raise listing every valid one.

    CLI-friendly underscore aliases map to the registry's dashed names
    (``spec_decode`` → ``spec-decode``), and unknown names fail with
    the full menu at validation time instead of surfacing later as a
    bare ``KeyError``.  The launchers validate ``--scenario`` through
    this instead of a frozen argparse ``choices`` list.
    """
    cand = str(name).replace("_", "-")
    if cand in SCENARIOS:
        return cand
    raise ValueError(f"unknown scenario {name!r}; "
                     f"choose from {sorted(SCENARIOS)}")


def make_scenario(name: str, seed: int = 0, slots: int = 8,
                  quick: bool = False) -> ScenarioSpec:
    """Build a deterministic scenario: same (name, seed, slots, quick)
    always yields the identical arrival schedule."""
    name = resolve_scenario(name)
    rng = np.random.default_rng(seed)
    return _pack(name, seed, slots, SCENARIOS[name](rng, slots, quick))


# ---------------------------------------------------------------------
# Pure occupancy simulation (ServingEngine's scheduling semantics)
# ---------------------------------------------------------------------

def simulate_batches(spec: ScenarioSpec, max_ticks: int = 100_000
                     ) -> list[int]:
    """Per-tick decode batch sizes of an engine driving this scenario.

    0 entries are idle ticks (all slots free, later arrivals pending) —
    the drain/refill gaps.  This mirrors ``ServingEngine`` exactly:
    admission at the start of a tick in arrival order, one decode step
    per tick per active slot, completion after ``decode_steps`` ticks
    (EOS never fires in scenario runs); the conformance test drives the
    real engine and asserts tick-for-tick equality.
    """
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    i = 0
    waiting: list[Arrival] = []
    active = [0] * spec.slots
    batches: list[int] = []
    t = 0
    while i < len(pending) or waiting or any(active):
        while i < len(pending) and pending[i].step <= t:
            waiting.append(pending[i])
            i += 1
        for s in range(spec.slots):
            if active[s] == 0 and waiting:
                active[s] = waiting.pop(0).decode_steps()
        batches.append(sum(1 for rem in active if rem > 0))
        for s in range(spec.slots):
            if active[s] > 0:
                active[s] -= 1
        t += 1
        if t > max_ticks:
            raise ScenarioDrainError(
                spec.name, max_ticks,
                queues=dict(waiting=len(waiting),
                            pending=len(pending) - i),
                oldest_age=(t - min(a.step for a in waiting)
                            if waiting else None),
                last_batch=[rem for rem in active if rem > 0])
    return batches


def occupancy_trace(spec: ScenarioSpec) -> list[int]:
    """The non-idle batch sequence — what an offload policy observes."""
    return [b for b in simulate_batches(spec) if b > 0]


# ---------------------------------------------------------------------
# Speculative decoding: the seeded accept/advance round math (THE spec)
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpecDecodeConfig:
    """Scheduling spec of the draft/verify speculative-decode loop.

    Per serve tick, every active request runs one *round*: it drafts
    ``drafted = min(draft_len, remaining - 1)`` tokens (never drafting
    past its decode budget), a seeded leading-prefix acceptance draw
    accepts ``k <= drafted`` of them, and the verify step contributes
    one token unconditionally — so the request advances ``k + 1``
    tokens and wastes ``drafted - k`` draft positions.  Consequences
    that hold *by construction* (the property suite pins them):

    * token conservation — a request's advances sum exactly to its
      ``decode_steps()`` budget, accepted or not;
    * ``acceptance=0`` advances 1 token per tick: the schedule
      degenerates to vanilla decode, tick-exactly equal to
      :func:`simulate_batches`;
    * ``acceptance=1`` accepts every drafted token: nothing is ever
      re-decoded (``wasted == 0``).

    The acceptance draw is keyed by ``(seed, rid, round)`` — not by any
    global counter — so the model-free mirror and the real engines
    compute identical schedules regardless of slot processing order,
    and a request's fate is independent of who shares its batch.
    """

    draft_len: int = 4
    acceptance: float = 0.7
    seed: int = 0

    def __post_init__(self):
        if self.draft_len < 1:
            raise ValueError("draft_len must be >= 1")
        if not 0.0 <= self.acceptance <= 1.0:
            raise ValueError("acceptance must be in [0, 1]")
        if self.seed < 0:
            raise ValueError("seed must be >= 0")

    def to_record(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_record(rec: dict) -> "SpecDecodeConfig":
        return SpecDecodeConfig(**rec)

    def accepted(self, rid: int, round_: int) -> int:
        """Accepted draft-token count for a request's n-th round:
        leading accepts of ``draft_len`` Bernoulli(acceptance) draws
        (speculative decoding accepts a prefix — the first rejection
        discards the rest of the draft)."""
        draws = np.random.default_rng(
            (self.seed, rid, round_)).random(self.draft_len)
        k = 0
        for d in draws:
            if d >= self.acceptance:
                break
            k += 1
        return k

    def advance(self, rid: int, round_: int, remaining: int
                ) -> tuple[int, int, int]:
        """One round for a request with ``remaining`` budget: returns
        ``(advance, drafted, accepted)``.  ``advance = accepted + 1``
        (the verify token) and never exceeds ``remaining``."""
        drafted = min(self.draft_len, remaining - 1)
        k = min(self.accepted(rid, round_), drafted)
        return k + 1, drafted, k


def simulate_spec_decode(spec: ScenarioSpec,
                         spec_decode: SpecDecodeConfig | None = None,
                         max_ticks: int = 100_000) -> dict:
    """Tick-exact model-free mirror of speculative-decode serving.

    The ``simulate_batches`` analogue for a :class:`ServingEngine`
    running ``spec_decode=``: admission and slot fill are identical
    (arrival-order FIFO into free slots), but each active slot performs
    one :meth:`SpecDecodeConfig.advance` round per tick instead of a
    single-token decrement.  Returns per-tick batches, per-tick total
    advance, per-tick verify sub-steps (``max`` advance — the number of
    batched decode calls the real engine issues that tick), per-request
    round/draft/accept/waste counters and completion ticks — everything
    the differential battery diffs against the engine-driven run.
    """
    sd = spec_decode or SpecDecodeConfig()
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    i = 0
    waiting: list[Arrival] = []
    active = [0] * spec.slots
    slot_rid = [-1] * spec.slots
    batches: list[int] = []
    advance: list[int] = []
    substeps: list[int] = []
    rounds: dict[int, int] = {a.rid: 0 for a in spec.arrivals}
    drafted: dict[int, int] = {a.rid: 0 for a in spec.arrivals}
    accepted: dict[int, int] = {a.rid: 0 for a in spec.arrivals}
    completion_ticks: dict[int, int] = {}
    t = 0
    while i < len(pending) or waiting or any(active):
        while i < len(pending) and pending[i].step <= t:
            waiting.append(pending[i])
            i += 1
        for s in range(spec.slots):
            if active[s] == 0 and waiting:
                a = waiting.pop(0)
                active[s] = a.decode_steps()
                slot_rid[s] = a.rid
        batches.append(sum(1 for rem in active if rem > 0))
        adv_total = 0
        adv_max = 0
        for s in range(spec.slots):
            if active[s] > 0:
                rid = slot_rid[s]
                adv, drf, acc = sd.advance(rid, rounds[rid], active[s])
                rounds[rid] += 1
                drafted[rid] += drf
                accepted[rid] += acc
                adv_total += adv
                adv_max = max(adv_max, adv)
                active[s] -= adv
                if active[s] == 0:
                    completion_ticks[rid] = t
        advance.append(adv_total)
        substeps.append(adv_max)
        t += 1
        if t > max_ticks:
            raise ScenarioDrainError(
                spec.name, max_ticks,
                queues=dict(waiting=len(waiting),
                            pending=len(pending) - i),
                oldest_age=(t - min(a.step for a in waiting)
                            if waiting else None),
                last_batch=[rem for rem in active if rem > 0])
    return dict(per_tick_batch=batches, per_tick_advance=advance,
                per_tick_substeps=substeps, rounds=rounds,
                drafted=drafted, accepted=accepted,
                wasted={r: drafted[r] - accepted[r] for r in drafted},
                completion_ticks=completion_ticks)


# ---------------------------------------------------------------------
# Disaggregated prefill/decode scheduling (the cell pair's pure mirror)
# ---------------------------------------------------------------------

SLO_LATENCY = "latency"
SLO_THROUGHPUT = "throughput"
SLO_CLASSES = (SLO_LATENCY, SLO_THROUGHPUT)


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Scheduling knobs of the disaggregated prefill/decode cell pair.

    ``prefill_budget`` — prefills the prefill cell may perform per tick
    (``None`` = unbounded; the mirror-of-monolithic setting).
    ``handoff_bound`` — max prefilled requests allowed to sit in the
    KV-handoff queue awaiting a decode slot (``None`` = unbounded);
    the prefill cell stalls rather than overrun it.
    ``starvation_age`` — admission aging: a throughput-class request
    that has waited this many ticks outranks every latency-class
    request, so sustained latency bursts cannot starve the throughput
    class (the fuzzed no-starvation property).
    ``admission_capacity`` — SLO-aware load shedding: the admission
    queue never holds more than this many waiting requests (``None`` =
    unbounded).  Each arrival that pushes the queue over capacity sheds
    one request per :func:`_shed_pick` — the exact inverse of the
    admission order, so the lowest-priority request goes first and
    aging protection is preserved.  Shed requests leave the system
    (never prefilled, never decoded) and are reported per class.
    """

    prefill_budget: int | None = None
    handoff_bound: int | None = None
    starvation_age: int = 8
    admission_capacity: int | None = None

    def __post_init__(self):
        if self.prefill_budget is not None and self.prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 or None")
        if self.handoff_bound is not None and self.handoff_bound < 1:
            raise ValueError("handoff_bound must be >= 1 or None")
        if self.starvation_age < 0:
            raise ValueError("starvation_age must be >= 0")
        if (self.admission_capacity is not None
                and self.admission_capacity < 1):
            raise ValueError("admission_capacity must be >= 1 or None")

    @staticmethod
    def mirror() -> "DisaggConfig":
        """The config under which the cell pair replays the monolithic
        engine tick-exactly: unbounded prefill and handoff, one class."""
        return DisaggConfig()

    def to_record(self) -> dict:
        # admission_capacity is omitted when unset so records written
        # before shedding existed stay byte-identical (golden fixtures).
        rec = dataclasses.asdict(self)
        if rec["admission_capacity"] is None:
            del rec["admission_capacity"]
        return rec

    @staticmethod
    def from_record(rec: dict) -> "DisaggConfig":
        return DisaggConfig(**rec)


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Cross-cell decode-slot autoscaling — THE grow/shrink rule spec.

    The decode cell's KV cache stays allocated at its ``slots``
    capacity; autoscaling moves only the *admission limit* — how many
    slots may accept new work.  Growing is therefore free (raise the
    limit) and shrinking is graceful: busy slots above the limit finish
    their requests but are never refilled (lame-duck), which is what
    makes the rule tick-exactly mirrorable without cache reallocation.

    The rule, applied once at the END of every tick (after decode):

    1. ``pressure`` = waiting admissions whose age meets their class
       target (``latency_wait`` / ``throughput_wait`` ticks) — the
       per-class SLO wait telemetry the cells report.
    2. While ``cooldown`` ticks remain since the last action, only the
       countdown advances.
    3. Grow by one slot (up to ``max_slots``) when ``pressure > 0``.
    4. Otherwise, when nothing waits anywhere (admission + handoff
       empty) and fewer than ``limit`` slots are busy, an idle streak
       advances; ``idle_ticks`` consecutive idle ticks shrink the limit
       by one (down to ``min_slots``).
    5. Anything else resets the idle streak.

    The new limit takes effect at the next tick's admissions.
    ``simulate_disagg(..., autoscale=...)`` is the model-free
    implementation; ``serving/daemon.py``'s ``AutoscaleController`` is
    the independent real-cell one — the differential parity suite holds
    them together, like every prior scheduling feature.  ``max_slots``
    ``None`` means the scenario's slot capacity.
    """

    min_slots: int = 1
    max_slots: int | None = None
    start_slots: int | None = None     # None = min_slots
    latency_wait: int = 2
    throughput_wait: int = 6
    idle_ticks: int = 3
    cooldown: int = 2

    def __post_init__(self):
        if self.min_slots < 1:
            raise ValueError("min_slots must be >= 1")
        if self.max_slots is not None and self.max_slots < self.min_slots:
            raise ValueError("max_slots must be >= min_slots or None")
        if (self.start_slots is not None
                and self.start_slots < self.min_slots):
            raise ValueError("start_slots must be >= min_slots or None")
        if self.latency_wait < 0 or self.throughput_wait < 0:
            raise ValueError("class target waits must be >= 0")
        if self.idle_ticks < 1:
            raise ValueError("idle_ticks must be >= 1")
        if self.cooldown < 0:
            raise ValueError("cooldown must be >= 0")

    def class_wait(self, slo: str) -> int:
        return (self.latency_wait if slo == SLO_LATENCY
                else self.throughput_wait)

    def to_record(self) -> dict:
        # None fields omitted (like DisaggConfig.to_record) so records
        # stay minimal and byte-stable as defaults evolve.
        rec = dataclasses.asdict(self)
        for k in ("max_slots", "start_slots"):
            if rec[k] is None:
                del rec[k]
        return rec

    @staticmethod
    def from_record(rec: dict) -> "AutoscaleConfig":
        return AutoscaleConfig(**rec)


def assign_slo(spec: ScenarioSpec, frac_latency: float = 0.5,
               seed: int | None = None) -> dict[int, str]:
    """Seeded per-tenant SLO classes for a scenario's requests.

    Deterministic in (spec.seed, seed override): the same scenario
    always gets the same latency/throughput split, so SLO runs are as
    replayable as the schedule itself.
    """
    rng = np.random.default_rng(spec.seed + 17 if seed is None else seed)
    return {a.rid: (SLO_LATENCY if rng.random() < frac_latency
                    else SLO_THROUGHPUT)
            for a in spec.arrivals}


def _admission_pick(waiting: list, t: int, starvation_age: int) -> int:
    """Index of the next request to prefill — THE admission order spec.

    ``waiting`` entries are ``(enq_tick, seq, rid, slo)``.  Starved
    throughput requests (waited >= ``starvation_age`` ticks) outrank
    everything, oldest first; then latency FIFO; then throughput FIFO.
    With a single class this is plain FIFO — the mirror-of-monolithic
    degenerate case.  ``serving/cells.py``'s ``AdmissionQueue`` is the
    independent implementation of this same spec; the differential
    parity suite holds them together.
    """
    starved = [i for i, (enq, _, _, slo) in enumerate(waiting)
               if slo == SLO_THROUGHPUT and t - enq >= starvation_age]
    if starved:
        return min(starved, key=lambda i: waiting[i][:2])
    latency = [i for i, w in enumerate(waiting) if w[3] == SLO_LATENCY]
    pool = latency or range(len(waiting))
    return min(pool, key=lambda i: waiting[i][:2])


def _shed_pick(waiting: list, t: int, starvation_age: int) -> int:
    """Index of the request to shed under admission pressure — THE shed
    order spec, the exact inverse of :func:`_admission_pick`.

    ``waiting`` entries are ``(enq_tick, seq, rid, slo)``.  The youngest
    non-starved throughput-class request goes first (lowest class,
    least sunk wait); then the youngest latency-class request; only
    when every waiting request is a starved throughput request does one
    of those go (youngest first) — so aging protection survives
    shedding.  ``serving/cells.py``'s ``AdmissionQueue.shed`` is the
    independent implementation of this same spec.
    """
    fresh = [i for i, (enq, _, _, slo) in enumerate(waiting)
             if slo == SLO_THROUGHPUT and t - enq < starvation_age]
    if fresh:
        return max(fresh, key=lambda i: waiting[i][:2])
    latency = [i for i, w in enumerate(waiting) if w[3] == SLO_LATENCY]
    pool = latency or range(len(waiting))
    return max(pool, key=lambda i: waiting[i][:2])


def simulate_disagg(spec: ScenarioSpec,
                    disagg: DisaggConfig | None = None,
                    slo: dict[int, str] | None = None,
                    spec_decode: SpecDecodeConfig | None = None,
                    autoscale: AutoscaleConfig | None = None,
                    max_ticks: int = 100_000) -> dict:
    """Tick-exact model-free mirror of the disaggregated cell pair.

    The ``simulate_batches`` analogue for ``serving/cells.py``: per
    tick, (1) arrivals join the prefill cell's admission queue, (2) the
    prefill cell prefills up to ``prefill_budget`` requests — admission
    order per :func:`_admission_pick` — while the KV-handoff queue has
    room, (3) the decode cell admits handed-off requests FIFO into free
    slots, (4) one decode step runs over every active slot, freeing
    slots the moment their request completes (continuous batching).

    Returns per-tick decode batches / prefill counts / end-of-tick
    handoff depth plus per-request prefill/admit/completion ticks —
    everything the property suite and the real-cell parity test diff.
    With ``admission_capacity`` set, every arrival that leaves the
    waiting queue over capacity sheds one request per
    :func:`_shed_pick` (recorded in ``shed_ticks``) before the tick's
    prefills run.  Under ``DisaggConfig.mirror()`` with a single SLO
    class the decode batch trace equals ``simulate_batches(spec)`` tick
    for tick.  With ``spec_decode`` the decode cell runs one seeded
    accept/advance round per active slot per tick instead of a
    single-token decrement — the same :meth:`SpecDecodeConfig.advance`
    spec :func:`simulate_spec_decode` pins for the monolithic engine.
    With ``autoscale`` the decode admission limit follows the
    :class:`AutoscaleConfig` grow/shrink rule (applied at the end of
    every tick; the result gains a ``limits`` key — the limit in force
    each tick).
    """
    cfg = disagg or DisaggConfig.mirror()
    slo = slo or {}
    rounds: dict[int, int] = {a.rid: 0 for a in spec.arrivals}
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    decode_steps = {a.rid: a.decode_steps() for a in spec.arrivals}
    i = 0
    waiting: list[tuple] = []          # (enq_tick, seq, rid, slo)
    handoff: list[int] = []            # rids, FIFO
    active = [0] * spec.slots
    slot_rid = [-1] * spec.slots
    batches: list[int] = []
    prefills: list[int] = []
    depth: list[int] = []
    prefill_ticks: dict[int, int] = {}
    admit_ticks: dict[int, int] = {}
    completion_ticks: dict[int, int] = {}
    shed_ticks: dict[int, int] = {}
    max_depth = 0
    seq = 0
    t = 0
    # Autoscaling state: the admission limit in force, its per-tick
    # trace, and the rule's cooldown/idle counters (see AutoscaleConfig
    # — this block and serving/daemon.py's AutoscaleController are the
    # two implementations of that one spec).
    auto_max = (spec.slots if autoscale is None
                else min(autoscale.max_slots or spec.slots, spec.slots))
    limit = (spec.slots if autoscale is None
             else min(autoscale.start_slots or autoscale.min_slots,
                      auto_max))
    limits: list[int] = []
    cool = 0
    idle = 0
    while i < len(pending) or waiting or handoff or any(active):
        while i < len(pending) and pending[i].step <= t:
            a = pending[i]
            waiting.append((t, seq, a.rid, slo.get(a.rid, SLO_LATENCY)))
            seq += 1
            i += 1
            if (cfg.admission_capacity is not None
                    and len(waiting) > cfg.admission_capacity):
                _, _, rid_s, _ = waiting.pop(
                    _shed_pick(waiting, t, cfg.starvation_age))
                shed_ticks[rid_s] = t
        n = 0
        while ((cfg.prefill_budget is None or n < cfg.prefill_budget)
               and (cfg.handoff_bound is None
                    or len(handoff) < cfg.handoff_bound) and waiting):
            _, _, rid, _ = waiting.pop(
                _admission_pick(waiting, t, cfg.starvation_age))
            prefill_ticks[rid] = t
            handoff.append(rid)
            max_depth = max(max_depth, len(handoff))
            n += 1
        prefills.append(n)
        for s in range(limit):
            if active[s] == 0 and handoff:
                rid = handoff.pop(0)
                admit_ticks[rid] = t
                active[s] = decode_steps[rid]
                slot_rid[s] = rid
        batches.append(sum(1 for rem in active if rem > 0))
        for s in range(spec.slots):
            if active[s] > 0:
                if spec_decode is None:
                    active[s] -= 1
                else:
                    rid = slot_rid[s]
                    adv, _, _ = spec_decode.advance(
                        rid, rounds[rid], active[s])
                    rounds[rid] += 1
                    active[s] -= adv
                if active[s] == 0:
                    completion_ticks[slot_rid[s]] = t
        depth.append(len(handoff))
        if autoscale is not None:
            limits.append(limit)
            busy = sum(1 for rem in active if rem > 0)
            pressure = sum(1 for enq, _, _, s_cls in waiting
                           if t - enq >= autoscale.class_wait(s_cls))
            if cool > 0:
                cool -= 1
            elif pressure > 0 and limit < auto_max:
                limit += 1
                cool = autoscale.cooldown
                idle = 0
            elif not waiting and not handoff and busy < limit:
                idle += 1
                if idle >= autoscale.idle_ticks \
                        and limit > autoscale.min_slots:
                    limit -= 1
                    cool = autoscale.cooldown
                    idle = 0
            else:
                idle = 0
        t += 1
        if t > max_ticks:
            raise ScenarioDrainError(
                spec.name, max_ticks,
                queues=dict(waiting=len(waiting), handoff=len(handoff),
                            pending=len(pending) - i),
                oldest_age=(t - min(enq for enq, _, _, _ in waiting)
                            if waiting else None),
                last_batch=[rem for rem in active if rem > 0])
    out = dict(per_tick_batch=batches, per_tick_prefills=prefills,
               handoff_depth=depth, max_handoff_depth=max_depth,
               prefill_ticks=prefill_ticks, admit_ticks=admit_ticks,
               completion_ticks=completion_ticks,
               shed_ticks=shed_ticks, rounds=rounds)
    if autoscale is not None:
        out["limits"] = limits
    return out


def run_policy_over_trace(planner, policy, batches: Sequence[int],
                          fence: bool = True, spec=None,
                          policy_kw: dict | None = None):
    """Drive a controller over a recorded occupancy trace (no model).

    The closed loop the dry-run and the ``fleet/policy_*`` benchmark
    rows run: every non-idle batch size is shown to the policy once, in
    order.  Returns the controller (``.report()`` has the verdict).
    """
    from .policy import OffloadController
    controller = OffloadController(planner, policy=policy, fence=fence,
                                   spec=spec, **(policy_kw or {}))
    for b in batches:
        if b > 0:
            controller.observe(int(b))
    return controller


# ---------------------------------------------------------------------
# End-to-end: drive the real ServingEngine and emit a replayable trace
# ---------------------------------------------------------------------

def run_scenario(scenario: ScenarioSpec, cfg, params, planner,
                 policy: str = "per-step", fence: bool = True,
                 max_seq: int | None = None,
                 policy_kw: dict | None = None, mesh=None,
                 disagg: "bool | DisaggConfig" = False,
                 slo: dict[int, str] | None = None,
                 spec_decode: SpecDecodeConfig | None = None,
                 autoscale: AutoscaleConfig | None = None,
                 prefill_scope=None, decode_scope=None,
                 on_tick=None) -> dict:
    """Serve the scenario end to end (real model decode) under an
    adaptive offload controller; return the replayable trace record.

    The trace carries only platform-independent telemetry — scheduling,
    occupancy, offload decisions and planner-derived speedups (pure
    arithmetic over bit-exact engine cycle counts) — never model token
    values, so it can be pinned byte-exactly as a golden fixture.

    ``mesh`` — an optional lane-mesh build (a 1-D ``jax.sharding.Mesh``
    or a device count; see ``engine.configure_lane_mesh``): the run's
    PIM lane resolution then executes as one shard_map program per slab
    instead of the threaded dispatch.  Because mesh resolution is
    bit-identical, the emitted trace must not change — that is the mesh
    serve cell's conformance contract (the golden replay test).

    ``disagg`` — ``True`` (mirror config) or a :class:`DisaggConfig`:
    the scenario is served by the disaggregated prefill/decode cell
    pair (``serving/cells.py``) instead of the monolithic engine, with
    optional per-request SLO classes in ``slo`` (rid → class, see
    :func:`assign_slo`).  Under the mirror config with a single class
    the emitted trace's shared keys are byte-identical to the
    monolithic run — the disagg conformance contract — and the record
    gains a ``"disagg"`` key (cell/handoff/SLO telemetry + the embedded
    config, so the trace replays through the cells too).

    ``spec_decode`` — an optional :class:`SpecDecodeConfig`: the
    engine (monolithic or disagg) serves the scenario speculatively,
    advancing each request by its seeded accept/advance round per tick
    (see :func:`simulate_spec_decode`, the tick-exact mirror).  The
    trace gains a ``"spec_decode"`` key (embedded config + round
    telemetry) so it replays; vanilla traces are byte-unchanged.

    ``autoscale`` — an optional :class:`AutoscaleConfig` (requires
    ``disagg``): the decode cell's admission limit follows the
    grow/shrink rule via a ``serving/daemon.py`` ``AutoscaleController``
    and the trace gains an ``"autoscale"`` key (embedded config +
    per-tick limit trace) so it replays; fixed-slot traces are
    byte-unchanged.

    ``prefill_scope`` / ``decode_scope`` — optional per-cell
    :class:`~repro.core.engine.BackendScope` objects (require
    ``disagg``): each cell activates its scope around its tick work, so
    the two cells resolve lanes on independent backends with
    independent circuit breakers — a fault that degrades one cell's
    ladder never moves the other's.  Unscoped runs are byte-unchanged.

    ``on_tick`` — optional ``fn(t, engine)`` called at the top of every
    driver tick, before that tick's submissions.  The chaos harness
    (``serving/chaos.py``) uses it to fire scheduled fault timelines
    mid-run; plain runs leave it ``None``.
    """
    from repro.core.engine import lane_mesh_scope

    with lane_mesh_scope(mesh):
        return _run_scenario(scenario, cfg, params, planner, policy,
                             fence, max_seq, policy_kw, disagg, slo,
                             on_tick, spec_decode, autoscale,
                             prefill_scope, decode_scope)


def _run_scenario(scenario, cfg, params, planner, policy, fence,
                  max_seq, policy_kw, disagg=False, slo=None,
                  on_tick=None, spec_decode=None, autoscale=None,
                  prefill_scope=None, decode_scope=None) -> dict:
    from .engine import Request, ServingEngine
    from .policy import OffloadController

    controller = OffloadController(planner, policy=policy, fence=fence,
                                   **(policy_kw or {}))
    if max_seq is None:
        max_seq = max((a.prompt_len + a.max_new
                       for a in scenario.arrivals), default=16)
        max_seq = max(64, 2 * max_seq)
    slo = slo or {}
    if disagg:
        from .cells import DisaggServingEngine
        dcfg = disagg if isinstance(disagg, DisaggConfig) \
            else DisaggConfig.mirror()
        eng = DisaggServingEngine(cfg, params, slots=scenario.slots,
                                  max_seq=max_seq, disagg=dcfg,
                                  controller=controller,
                                  spec_decode=spec_decode,
                                  prefill_scope=prefill_scope,
                                  decode_scope=decode_scope)
    else:
        if autoscale is not None:
            raise ValueError("autoscale requires disagg serving "
                             "(the decode cell owns the slot limit)")
        if prefill_scope is not None or decode_scope is not None:
            raise ValueError("per-cell backend scopes require disagg "
                             "serving (the cells own scope activation)")
        eng = ServingEngine(cfg, params, slots=scenario.slots,
                            max_seq=max_seq, controller=controller,
                            spec_decode=spec_decode)
    scaler = None
    if autoscale is not None:
        from .daemon import AutoscaleController
        scaler = AutoscaleController(autoscale, eng)
    if spec_decode is not None:
        # Keep the hot small-shape draft lanes pinned at the MRU end of
        # the lane LRU for the whole run (see OffloadPlanner.touch_draft
        # — big replans/grids must not evict them).
        planner.plan_draft(fence=fence)
    rng = np.random.default_rng(scenario.seed + 1)   # token values only
    pending = sorted(scenario.arrivals, key=lambda a: (a.step, a.rid))
    reqs = {a.rid: Request(rid=a.rid,
                           prompt=rng.integers(0, cfg.vocab,
                                               size=a.prompt_len),
                           max_new=a.max_new)
            for a in pending}
    i = 0
    t = 0
    per_tick: list[int] = []
    while i < len(pending) or any(eng.active) or eng.waiting:
        if on_tick is not None:
            on_tick(t, eng)
        if spec_decode is not None:
            planner.touch_draft(fence=fence)
        while i < len(pending) and pending[i].step <= t:
            rid = pending[i].rid
            if disagg:
                eng.submit(reqs[rid], slo=slo.get(rid, SLO_LATENCY))
            else:
                eng.submit(reqs[rid])
            i += 1
        stepped = eng.step()
        per_tick.append(eng.step_batches[-1] if stepped else 0)
        if scaler is not None:
            scaler.observe(t)
        t += 1
        if t > 100_000:
            step_of = {a.rid: a.step for a in scenario.arrivals}
            if disagg:
                queued = ([e[2].rid for e in eng.prefill_cell
                           .queue._entries]
                          + [h.req.rid for h in eng.handoff._q])
                queues = dict(waiting=len(eng.prefill_cell.queue),
                              handoff=len(eng.handoff),
                              pending=len(pending) - i)
            else:
                queued = [r.rid for r in eng.waiting]
                queues = dict(waiting=len(eng.waiting),
                              pending=len(pending) - i)
            raise ScenarioDrainError(
                scenario.name, 100_000, queues=queues,
                oldest_age=(t - min(step_of[r] for r in queued)
                            if queued else None),
                last_batch=[r.rid for r in eng.active if r is not None])
    stats = eng.summary()
    shed = getattr(eng, "shed", {})
    assert all(r.done or r.rid in shed for r in reqs.values())
    trace = dict(
        scenario=scenario.to_record(),
        policy=controller.policy.name,
        fence=fence,
        per_tick_batch=per_tick,
        occupancy={str(k): v for k, v in
                   sorted(stats["batch_occupancy"].items())},
        steps=stats["steps"], tokens=stats["tokens"],
        prefills=stats["prefills"],
        controller=controller.report(),
        per_step=[r.to_record() for r in controller.trace],
    )
    if disagg:
        trace["disagg"] = stats["disagg"]
    if scaler is not None:
        trace["autoscale"] = scaler.report()
    if spec_decode is not None:
        trace["spec_decode"] = dict(config=spec_decode.to_record(),
                                    **eng.spec_report())
    return trace


def replay_batches(trace: dict) -> list[int]:
    """Re-derive the per-tick occupancy of a recorded trace from its
    embedded schedule alone (no model, no planner) — the replay hook.
    Speculative traces replay through their embedded
    :class:`SpecDecodeConfig` (the mirror's acceptance schedule is part
    of the record)."""
    spec = ScenarioSpec.from_record(trace["scenario"])
    if "spec_decode" in trace:
        sd = SpecDecodeConfig.from_record(trace["spec_decode"]["config"])
        return simulate_spec_decode(spec, sd)["per_tick_batch"]
    return simulate_batches(spec)


def replay_trace(trace: dict, cfg, params, planner, mesh=None) -> dict:
    """Re-serve a recorded trace end to end and return the fresh record.

    The scenario schedule, policy and fence mode are taken from the
    trace itself, so a replay is byte-comparable to the recording —
    under any ``mesh`` build, since mesh lane execution is bit-identical
    by contract.  This is how the pinned golden trace validates a mesh
    serve cell: ``replay_trace(golden, ..., mesh=N) == golden``.

    A trace recorded through the disaggregated cells carries its
    ``DisaggConfig`` and SLO assignment under ``"disagg"`` — the replay
    reconstructs the cell pair from the record alone, so the pinned
    ``tests/golden/disagg_trace.json`` validates the cells the same way.
    """
    disagg: "bool | DisaggConfig" = False
    slo = None
    spec_decode = None
    autoscale = None
    if "disagg" in trace:
        disagg = DisaggConfig.from_record(trace["disagg"]["config"])
        slo = {int(r): s for r, s in trace["disagg"]["slo"].items()}
    if "spec_decode" in trace:
        spec_decode = SpecDecodeConfig.from_record(
            trace["spec_decode"]["config"])
    if "autoscale" in trace:
        autoscale = AutoscaleConfig.from_record(
            trace["autoscale"]["config"])
    return run_scenario(ScenarioSpec.from_record(trace["scenario"]),
                        cfg, params, planner, policy=trace["policy"],
                        fence=trace["fence"], mesh=mesh,
                        disagg=disagg, slo=slo, spec_decode=spec_decode,
                        autoscale=autoscale)
