"""Trace-driven serving scenarios: seeded workloads + replayable traces.

A scenario is a deterministic arrival schedule — request id, arrival
tick, prompt length, decode budget — produced by a seeded generator.
Five load shapes cover the serving regimes the offload policies must
survive:

* ``steady``        — one request every few ticks, stable occupancy.
* ``bursty``        — Poisson arrivals whose rate spikes in short burst
                      windows (the queue oscillates across the offload
                      crossover batch).
* ``diurnal``       — sinusoidal arrival rate, a slow ramp up and down.
* ``prefill-heavy`` — few requests, long prompts, short decode budgets.
* ``drain-refill``  — waves separated by idle gaps (occupancy collapses
                      to zero and refills from empty).

``simulate_batches`` mirrors :class:`ServingEngine`'s admission and
completion semantics exactly (requests finish on their decode budget,
never on EOS), so a scenario's per-tick occupancy trace is available
*without* running a model — that is what the policy benchmarks, the
dry-run closed loop and the property tests drive.  ``run_scenario``
drives the real engine end to end (model decode included) and emits a
replayable trace record; one bursty trace is pinned byte-exactly in
``tests/golden/serve_trace.json``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of a scenario schedule (all scheduling, no tokens)."""

    rid: int
    step: int          # driver tick at which the request is submitted
    prompt_len: int
    max_new: int

    def decode_steps(self) -> int:
        # Prefill emits the first token; the engine marks a request done
        # after the decode step that reaches max_new, so a request holds
        # its slot for max(1, max_new - 1) decode steps.
        return max(1, self.max_new - 1)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    seed: int
    slots: int
    arrivals: tuple

    def to_record(self) -> dict:
        return dict(name=self.name, seed=self.seed, slots=self.slots,
                    arrivals=[dataclasses.asdict(a) for a in self.arrivals])

    @staticmethod
    def from_record(rec: dict) -> "ScenarioSpec":
        return ScenarioSpec(
            name=rec["name"], seed=rec["seed"], slots=rec["slots"],
            arrivals=tuple(Arrival(**a) for a in rec["arrivals"]))


def _pack(name: str, seed: int, slots: int, raw) -> ScenarioSpec:
    """Sort (step, order) and assign dense rids — determinism lives here."""
    arrivals = tuple(Arrival(rid=i, step=int(s), prompt_len=int(p),
                             max_new=int(m))
                     for i, (s, p, m) in enumerate(raw))
    return ScenarioSpec(name=name, seed=seed, slots=slots,
                        arrivals=arrivals)


def _steady(rng, slots: int, quick: bool):
    n = 8 if quick else 24
    gap = 2
    return [(i * gap, rng.integers(4, 12), rng.integers(4, 8))
            for i in range(n)]


def _bursty(rng, slots: int, quick: bool):
    horizon = 40 if quick else 120
    n_bursts = 2 if quick else 5
    burst_at = sorted(rng.choice(horizon - 6, size=n_bursts,
                                 replace=False))
    raw = []
    for t in range(horizon):
        lam = 0.12
        for b in burst_at:
            if b <= t < b + 3:
                lam = 1.6
        for _ in range(rng.poisson(lam)):
            raw.append((t, rng.integers(4, 12), rng.integers(3, 9)))
    return raw


def _diurnal(rng, slots: int, quick: bool):
    horizon = 48 if quick else 144
    period = horizon / 2
    raw = []
    for t in range(horizon):
        lam = 0.55 * (1.0 + math.sin(2.0 * math.pi * t / period))
        for _ in range(rng.poisson(lam)):
            raw.append((t, rng.integers(4, 12), rng.integers(3, 8)))
    return raw


def _prefill_heavy(rng, slots: int, quick: bool):
    n = 6 if quick else 16
    gap = 3
    return [(i * gap, rng.integers(24, 48), rng.integers(2, 5))
            for i in range(n)]


def _drain_refill(rng, slots: int, quick: bool):
    waves = 2 if quick else 4
    wave_size = slots + 2
    max_new_hi = 7
    # A wave of wave_size requests over `slots` drains in at most
    # ceil(wave_size / slots) * (max_new_hi - 1) decode ticks; the gap
    # guarantees an idle stretch between waves.
    wave_gap = -(-wave_size // slots) * (max_new_hi - 1) + 6
    raw = []
    for w in range(waves):
        for _ in range(wave_size):
            raw.append((w * wave_gap, rng.integers(4, 12),
                        rng.integers(3, max_new_hi)))
    return raw


SCENARIOS = {
    "steady": _steady,
    "bursty": _bursty,
    "diurnal": _diurnal,
    "prefill-heavy": _prefill_heavy,
    "drain-refill": _drain_refill,
}


def make_scenario(name: str, seed: int = 0, slots: int = 8,
                  quick: bool = False) -> ScenarioSpec:
    """Build a deterministic scenario: same (name, seed, slots, quick)
    always yields the identical arrival schedule."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"choose from {sorted(SCENARIOS)}")
    rng = np.random.default_rng(seed)
    return _pack(name, seed, slots, SCENARIOS[name](rng, slots, quick))


# ---------------------------------------------------------------------
# Pure occupancy simulation (ServingEngine's scheduling semantics)
# ---------------------------------------------------------------------

def simulate_batches(spec: ScenarioSpec, max_ticks: int = 100_000
                     ) -> list[int]:
    """Per-tick decode batch sizes of an engine driving this scenario.

    0 entries are idle ticks (all slots free, later arrivals pending) —
    the drain/refill gaps.  This mirrors ``ServingEngine`` exactly:
    admission at the start of a tick in arrival order, one decode step
    per tick per active slot, completion after ``decode_steps`` ticks
    (EOS never fires in scenario runs); the conformance test drives the
    real engine and asserts tick-for-tick equality.
    """
    pending = sorted(spec.arrivals, key=lambda a: (a.step, a.rid))
    i = 0
    waiting: list[Arrival] = []
    active = [0] * spec.slots
    batches: list[int] = []
    t = 0
    while i < len(pending) or waiting or any(active):
        while i < len(pending) and pending[i].step <= t:
            waiting.append(pending[i])
            i += 1
        for s in range(spec.slots):
            if active[s] == 0 and waiting:
                active[s] = waiting.pop(0).decode_steps()
        batches.append(sum(1 for rem in active if rem > 0))
        for s in range(spec.slots):
            if active[s] > 0:
                active[s] -= 1
        t += 1
        if t > max_ticks:
            raise RuntimeError(f"scenario {spec.name} did not drain "
                               f"within {max_ticks} ticks")
    return batches


def occupancy_trace(spec: ScenarioSpec) -> list[int]:
    """The non-idle batch sequence — what an offload policy observes."""
    return [b for b in simulate_batches(spec) if b > 0]


def run_policy_over_trace(planner, policy, batches: Sequence[int],
                          fence: bool = True, spec=None,
                          policy_kw: dict | None = None):
    """Drive a controller over a recorded occupancy trace (no model).

    The closed loop the dry-run and the ``fleet/policy_*`` benchmark
    rows run: every non-idle batch size is shown to the policy once, in
    order.  Returns the controller (``.report()`` has the verdict).
    """
    from .policy import OffloadController
    controller = OffloadController(planner, policy=policy, fence=fence,
                                   spec=spec, **(policy_kw or {}))
    for b in batches:
        if b > 0:
            controller.observe(int(b))
    return controller


# ---------------------------------------------------------------------
# End-to-end: drive the real ServingEngine and emit a replayable trace
# ---------------------------------------------------------------------

def run_scenario(scenario: ScenarioSpec, cfg, params, planner,
                 policy: str = "per-step", fence: bool = True,
                 max_seq: int | None = None,
                 policy_kw: dict | None = None, mesh=None) -> dict:
    """Serve the scenario end to end (real model decode) under an
    adaptive offload controller; return the replayable trace record.

    The trace carries only platform-independent telemetry — scheduling,
    occupancy, offload decisions and planner-derived speedups (pure
    arithmetic over bit-exact engine cycle counts) — never model token
    values, so it can be pinned byte-exactly as a golden fixture.

    ``mesh`` — an optional lane-mesh build (a 1-D ``jax.sharding.Mesh``
    or a device count; see ``engine.configure_lane_mesh``): the run's
    PIM lane resolution then executes as one shard_map program per slab
    instead of the threaded dispatch.  Because mesh resolution is
    bit-identical, the emitted trace must not change — that is the mesh
    serve cell's conformance contract (the golden replay test).
    """
    from repro.core.engine import lane_mesh_scope

    with lane_mesh_scope(mesh):
        return _run_scenario(scenario, cfg, params, planner, policy,
                             fence, max_seq, policy_kw)


def _run_scenario(scenario, cfg, params, planner, policy, fence,
                  max_seq, policy_kw) -> dict:
    from .engine import Request, ServingEngine
    from .policy import OffloadController

    controller = OffloadController(planner, policy=policy, fence=fence,
                                   **(policy_kw or {}))
    if max_seq is None:
        max_seq = max(a.prompt_len + a.max_new for a in scenario.arrivals)
        max_seq = max(64, 2 * max_seq)
    eng = ServingEngine(cfg, params, slots=scenario.slots, max_seq=max_seq,
                        controller=controller)
    rng = np.random.default_rng(scenario.seed + 1)   # token values only
    pending = sorted(scenario.arrivals, key=lambda a: (a.step, a.rid))
    reqs = {a.rid: Request(rid=a.rid,
                           prompt=rng.integers(0, cfg.vocab,
                                               size=a.prompt_len),
                           max_new=a.max_new)
            for a in pending}
    i = 0
    t = 0
    per_tick: list[int] = []
    while i < len(pending) or any(eng.active) or eng.waiting:
        while i < len(pending) and pending[i].step <= t:
            eng.submit(reqs[pending[i].rid])
            i += 1
        stepped = eng.step()
        per_tick.append(eng.step_batches[-1] if stepped else 0)
        t += 1
        if t > 100_000:
            raise RuntimeError("scenario did not drain")
    stats = eng.summary()
    assert all(r.done for r in reqs.values())
    return dict(
        scenario=scenario.to_record(),
        policy=controller.policy.name,
        fence=fence,
        per_tick_batch=per_tick,
        occupancy={str(k): v for k, v in
                   sorted(stats["batch_occupancy"].items())},
        steps=stats["steps"], tokens=stats["tokens"],
        prefills=stats["prefills"],
        controller=controller.report(),
        per_step=[r.to_record() for r in controller.trace],
    )


def replay_batches(trace: dict) -> list[int]:
    """Re-derive the per-tick occupancy of a recorded trace from its
    embedded schedule alone (no model, no planner) — the replay hook."""
    return simulate_batches(ScenarioSpec.from_record(trace["scenario"]))


def replay_trace(trace: dict, cfg, params, planner, mesh=None) -> dict:
    """Re-serve a recorded trace end to end and return the fresh record.

    The scenario schedule, policy and fence mode are taken from the
    trace itself, so a replay is byte-comparable to the recording —
    under any ``mesh`` build, since mesh lane execution is bit-identical
    by contract.  This is how the pinned golden trace validates a mesh
    serve cell: ``replay_trace(golden, ..., mesh=N) == golden``.
    """
    return run_scenario(ScenarioSpec.from_record(trace["scenario"]),
                        cfg, params, planner, policy=trace["policy"],
                        fence=trace["fence"], mesh=mesh)
