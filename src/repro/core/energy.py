"""Energy model for LPDDR5X / LP5X-PIM.

Per-command energies are derived from representative LPDDR5X IDD figures
(activate/precharge pair, read/write burst I/O + array access) plus PIM
compute-unit estimates; background power covers standby/clocking.  Values
are approximate — the paper does not publish circuit energy — and are
exposed on :class:`EnergyParams` so studies can re-parameterize.

The model is *counting based*: it consumes the opcode histogram of a
resolved stream plus the total runtime; it does not need to be inside the
cycle engine.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import commands as C
from .timing import SystemSpec


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    e_act_pj: float = 800.0      # ACT+PRE pair, one bank (row open energy)
    e_rd_pj: float = 350.0       # 32 B read burst (array + I/O)
    e_wr_pj: float = 330.0       # 32 B write burst
    e_rd_io_pj: float = 150.0    # I/O part (saved by PIM-internal access)
    e_mac_pj: float = 180.0      # per bank: 32 B internal read + MAC
    e_srf_pj: float = 120.0      # broadcast SRF/IRF write (per command)
    e_acc_rd_pj: float = 200.0   # ACC register read-out burst
    e_mov_pj: float = 260.0      # ACC -> DRAM internal move
    e_ref_pj: float = 25000.0    # REFab
    e_mode_pj: float = 500.0     # mode transition
    p_bg_mw_per_ch: float = 120.0  # background (standby + clock) per channel


def stream_energy_pj(counts: np.ndarray, total_cycles: int,
                     spec: SystemSpec,
                     params: EnergyParams = EnergyParams(),
                     active_banks: int = 16) -> dict:
    """Energy (pJ) for one channel given opcode counts and runtime."""
    t = spec.timings
    ns = total_cycles * t.tck_ns
    # ACT_MB opens `num_bankgroups` banks with one command.
    act_energy = (counts[C.ACT] * params.e_act_pj
                  + counts[C.ACT_MB] * params.e_act_pj * t.num_bankgroups)
    io_energy = (counts[C.RD] * params.e_rd_pj
                 + counts[C.WR] * params.e_wr_pj
                 + counts[C.RD_ACC] * params.e_acc_rd_pj
                 + (counts[C.WR_SRF] + counts[C.WR_IRF]) * params.e_srf_pj)
    # A broadcast MAC performs `active_banks` internal reads + MACs.
    mac_energy = counts[C.MAC] * params.e_mac_pj * active_banks
    misc = (counts[C.REFAB] * params.e_ref_pj
            + (counts[C.MODE_MB] + counts[C.MODE_SB]) * params.e_mode_pj
            + counts[C.MOV_ACC] * params.e_mov_pj)
    background = params.p_bg_mw_per_ch * 1e-3 * ns  # mW * ns = pJ
    total = act_energy + io_energy + mac_energy + misc + background
    return dict(total_pj=float(total), act_pj=float(act_energy),
                io_pj=float(io_energy), mac_pj=float(mac_energy),
                misc_pj=float(misc), background_pj=float(background),
                runtime_ns=float(ns))


def gemv_energy_summary(streams: list[np.ndarray], totals: np.ndarray,
                        spec: SystemSpec, flops: int,
                        params: EnergyParams = EnergyParams(),
                        active_banks: int = 16) -> dict:
    """Aggregate channel energies; report pJ/op for a GEMV of `flops`."""
    per_ch = [stream_energy_pj(C.op_counts(s), int(tc), spec, params,
                               active_banks)
              for s, tc in zip(streams, totals)]
    total_pj = sum(d["total_pj"] for d in per_ch)
    runtime_ns = max(d["runtime_ns"] for d in per_ch)
    return dict(total_pj=total_pj,
                pj_per_op=total_pj / max(flops, 1),
                runtime_ns=runtime_ns,
                channels=per_ch)
