"""Deterministic fault injection + the degradation-ladder primitives.

A real LP5X-PIM serving deployment survives backend faults, cache
corruption and queue pressure; this module is the harness that proves
the simulator's control layers do too.  It owns four small, shared
pieces the rest of the stack composes:

* **Clocks** — :class:`VirtualClock` / :class:`SystemClock` behind one
  protocol (callable ``now`` + ``sleep``).  ``training/fault.py``'s
  ``HeartbeatMonitor`` and the serving retry/backoff below share it, so
  no test ever real-sleeps: retries against a :class:`VirtualClock`
  advance simulated time only.
* **Structured events** — every injected fault and every degradation
  step is appended to a process-global, bounded event log
  (:func:`record_event` / :func:`events`), tagged with the serve tick
  (:func:`set_tick`), so chaos runs export a replayable incident
  record in their trace.
* **Seeded injection** — :class:`FaultInjector` arms site-keyed fault
  schedules (``backend.pallas``, ``backend.mesh``, ``backend.threaded``,
  ``backend.scan``, ``lane_cache``, ``warmstart``, ``handoff``,
  ``planner``, ``admission``); :func:`maybe_fail` is the zero-cost seam
  the engine and controller call at each fault site.  Injection is
  deterministic — a schedule is a list of (site, start, count) specs
  matched against per-site call counters, never wall-clock or RNG at
  fire time.
* **Absorption** — :class:`CircuitBreaker` (trips a rung open after K
  *consecutive* failures; success resets) and :func:`retry_call`
  (bounded retry with exponential backoff on the configured clock).
  ``core/engine.py`` stacks these into the backend degradation ladder
  pallas → mesh → threaded → single-device scan; because every rung is
  bit-identical by contract, a degraded resolve returns byte-exact
  results.

Everything here is plain stdlib and process-global with an explicit
:func:`reset` — ``tests/conftest.py`` calls it around every test the
same way it resets the lane backend state.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Iterable

# ---------------------------------------------------------------------------
# Clocks: the one shared virtual-clock helper (satellite: unify clocks)
# ---------------------------------------------------------------------------


class VirtualClock:
    """A manually-advanced clock: ``sleep`` moves time without waiting.

    Callable (``clock()`` == ``clock.now()``) so it drops into any API
    that takes a ``time.monotonic``-style callable — e.g.
    ``training.fault.HeartbeatMonitor(clock=VirtualClock())`` — while
    also providing the ``sleep`` the retry/backoff path needs.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self.sleeps: list[float] = []

    def __call__(self) -> float:
        return self._t

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        self._t += float(dt)
        return self._t

    def sleep(self, dt: float) -> None:
        self.sleeps.append(float(dt))
        self._t += float(dt)


class SystemClock:
    """The real clock behind the same protocol (monotonic + sleep)."""

    def __call__(self) -> float:
        return time.monotonic()

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        time.sleep(dt)


SYSTEM_CLOCK = SystemClock()


# ---------------------------------------------------------------------------
# Structured fault / degradation events
# ---------------------------------------------------------------------------

FAULT_SITES = (
    "backend.pallas", "backend.mesh", "backend.threaded", "backend.scan",
    "lane_cache", "warmstart", "handoff", "planner", "admission",
)

_EVENTS: deque = deque(maxlen=4096)
_EVENTS_LOCK = threading.Lock()
_TICK: int | None = None


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One structured incident-log entry.

    ``kind`` vocabulary: ``inject`` (a scheduled fault fired), ``fault``
    (a site raised — injected or real), ``retry`` (bounded backoff
    retry), ``degrade`` (ladder step-down / planner host-only
    fallback), ``trip`` / ``skip`` (circuit breaker opened / rung
    skipped while open), ``detect`` (poisoned cache entry or corrupt
    snapshot caught), ``shed`` (admission load shedding).
    """

    site: str
    kind: str
    detail: str = ""
    tick: int | None = None

    def to_record(self) -> dict:
        rec = dict(site=self.site, kind=self.kind, detail=self.detail)
        if self.tick is not None:
            rec["tick"] = self.tick
        return rec


def set_tick(t: int | None) -> None:
    """Tag subsequent events with serve tick ``t`` (None = untagged)."""
    global _TICK
    _TICK = None if t is None else int(t)


def record_event(site: str, kind: str, detail: str = "",
                 tick: int | None = None) -> FaultEvent:
    ev = FaultEvent(site=site, kind=kind, detail=detail,
                    tick=_TICK if tick is None else int(tick))
    with _EVENTS_LOCK:
        _EVENTS.append(ev)
    return ev


def events() -> list[dict]:
    """The event log as plain records (trace-exportable)."""
    with _EVENTS_LOCK:
        return [e.to_record() for e in _EVENTS]


def reset_events() -> None:
    with _EVENTS_LOCK:
        _EVENTS.clear()


# ---------------------------------------------------------------------------
# Seeded, deterministic fault injection
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Raised by :func:`maybe_fail` when an armed schedule matches."""

    def __init__(self, site: str, message: str = ""):
        self.site = site
        super().__init__(message or f"injected fault at {site}")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed fault: calls ``start .. start+count-1`` at ``site``
    raise (``count < 0`` = every call from ``start`` on — persistent)."""

    site: str
    start: int = 0
    count: int = 1
    message: str = ""

    def matches(self, call: int) -> bool:
        if call < self.start:
            return False
        return self.count < 0 or call < self.start + self.count


class FaultInjector:
    """Site-keyed deterministic fault schedules.

    Each :func:`maybe_fail` advances that site's call counter and fires
    iff an armed :class:`FaultSpec` covers the index — same schedule,
    same run, same faults, always.  ``arm(site, count)`` is the
    timeline-friendly form: *the next* ``count`` calls at ``site`` fail.
    """

    def __init__(self, specs: Iterable[FaultSpec] = ()):
        self.specs: list[FaultSpec] = list(specs)
        self.calls: dict[str, int] = {}
        self.injected = 0

    def arm(self, site: str, count: int = 1, start: int | None = None,
            message: str = "") -> FaultSpec:
        spec = FaultSpec(site=site, count=count, message=message,
                         start=(self.calls.get(site, 0)
                                if start is None else start))
        self.specs.append(spec)
        return spec

    def should_fail(self, site: str) -> FaultSpec | None:
        call = self.calls.get(site, 0)
        self.calls[site] = call + 1
        for spec in self.specs:
            if spec.site == site and spec.matches(call):
                self.injected += 1
                return spec
        return None


_INJECTOR: FaultInjector | None = None


def install_injector(inj: FaultInjector | None) -> None:
    global _INJECTOR
    _INJECTOR = inj


def injector() -> FaultInjector | None:
    return _INJECTOR


class fault_scope:
    """Context manager: install ``inj`` for the block, then restore."""

    def __init__(self, inj: FaultInjector | None):
        self._inj = inj

    def __enter__(self) -> FaultInjector | None:
        self._prev = _INJECTOR
        install_injector(self._inj)
        return self._inj

    def __exit__(self, *exc):
        install_injector(self._prev)
        return False


def maybe_fail(site: str) -> None:
    """The injection seam: no-op unless an installed schedule matches.

    The no-injector path is one global read — cheap enough for the
    engine's hot dispatch loop.
    """
    inj = _INJECTOR
    if inj is None:
        return
    spec = inj.should_fail(site)
    if spec is not None:
        record_event(site, "inject", spec.message or "scheduled fault")
        raise InjectedFault(site, spec.message)


# ---------------------------------------------------------------------------
# Circuit breaker: trip a rung open after K consecutive failures
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Per-key consecutive-failure breaker.

    ``record_failure`` returns True exactly when the K-th consecutive
    failure trips the key open; ``record_success`` closes it and zeroes
    the streak.  Half-open probing is deliberately absent: in this
    process model a tripped rung stays skipped until :func:`reset` (the
    conservative choice — a flapping backend must not oscillate the
    serve path).

    Breakers are per-:class:`~repro.core.engine.BackendScope`: the
    process breaker below guards the default scope only, and each serve
    cell's scope carries its own instance — a rung tripped by
    prefill-side faults no longer skips that rung for decode.  ``name``
    tags a scoped breaker's trip events (the anonymous process breaker
    keeps the classic event text).
    """

    def __init__(self, threshold: int = 3, name: str = ""):
        if threshold < 1:
            raise ValueError("breaker threshold must be >= 1")
        self.threshold = int(threshold)
        self.name = str(name)
        self.failures: dict[str, int] = {}
        self.open: set[str] = set()

    def record_failure(self, key: str) -> bool:
        n = self.failures.get(key, 0) + 1
        self.failures[key] = n
        if n >= self.threshold and key not in self.open:
            self.open.add(key)
            who = f" [{self.name}]" if self.name else ""
            record_event(key, "trip",
                         f"open after {n} consecutive failures "
                         f"(threshold {self.threshold}){who}")
            return True
        return False

    def record_success(self, key: str) -> None:
        self.failures[key] = 0
        self.open.discard(key)

    def tripped(self, key: str) -> bool:
        return key in self.open

    def info(self) -> dict:
        out = dict(threshold=self.threshold, open=sorted(self.open),
                   failures={k: v for k, v in sorted(self.failures.items())
                             if v})
        if self.name:
            # Only scoped (named) breakers carry the tag, so the golden
            # chaos traces' anonymous breaker info stays byte-identical.
            out["name"] = self.name
        return out


_BREAKER = CircuitBreaker()


def backend_breaker() -> CircuitBreaker:
    """The process breaker guarding the engine's backend ladder."""
    return _BREAKER


def configure_breaker(threshold: int) -> CircuitBreaker:
    """Replace the backend breaker (fresh state) with threshold K."""
    global _BREAKER
    _BREAKER = CircuitBreaker(threshold)
    return _BREAKER


# ---------------------------------------------------------------------------
# Bounded retry with backoff (shared by engine rungs + planner calls)
# ---------------------------------------------------------------------------

_RETRY = {"retries": 1, "backoff": 0.02, "clock": SYSTEM_CLOCK}


def configure_retry(retries: int | None = None,
                    backoff: float | None = None,
                    clock=None) -> dict:
    """Set the process retry policy; None leaves a field unchanged."""
    if retries is not None:
        if retries < 0:
            raise ValueError("retries must be >= 0")
        _RETRY["retries"] = int(retries)
    if backoff is not None:
        _RETRY["backoff"] = float(backoff)
    if clock is not None:
        _RETRY["clock"] = clock
    return dict(_RETRY)


class retry_scope:
    """Context manager: temporary retry policy (e.g. a VirtualClock so
    a chaos run's backoffs never real-sleep)."""

    def __init__(self, retries: int | None = None,
                 backoff: float | None = None, clock=None):
        self._kw = dict(retries=retries, backoff=backoff, clock=clock)

    def __enter__(self) -> dict:
        self._prev = dict(_RETRY)
        return configure_retry(**self._kw)

    def __exit__(self, *exc):
        _RETRY.update(self._prev)
        return False


def retry_call(fn: Callable, site: str, retries: int | None = None,
               backoff: float | None = None, clock=None):
    """Run ``fn`` with the injection seam + bounded backoff retries.

    Each attempt first passes through :func:`maybe_fail(site)` (so armed
    transient faults are absorbed exactly like real transient raises),
    then calls ``fn``.  Every failure is recorded; the last one
    propagates once retries are exhausted.
    """
    r = _RETRY["retries"] if retries is None else int(retries)
    b = _RETRY["backoff"] if backoff is None else float(backoff)
    clk = clock if clock is not None else _RETRY["clock"]
    for attempt in range(r + 1):
        try:
            maybe_fail(site)
            return fn()
        except Exception as e:  # noqa: BLE001 - every rung fault lands here
            record_event(site, "fault", f"{type(e).__name__}: {e}")
            if attempt >= r:
                raise
            record_event(site, "retry",
                         f"attempt {attempt + 1}/{r} after "
                         f"{type(e).__name__}")
            clk.sleep(b * (2 ** attempt))


# ---------------------------------------------------------------------------
# Process hygiene
# ---------------------------------------------------------------------------


def reset() -> None:
    """Restore every process-global here to its boot state (tests)."""
    global _BREAKER
    install_injector(None)
    reset_events()
    set_tick(None)
    _BREAKER = CircuitBreaker()
    _RETRY.update(retries=1, backoff=0.02, clock=SYSTEM_CLOCK)
