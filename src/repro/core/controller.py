"""Memory-controller model: request scheduling -> command streams.

The paper's memory controller "analyzes host memory requests and schedules
them to maximize processing throughput while strictly adhering to LPDDR5X
standard timing constraints".  For in-order per-channel streams this is a
*policy* question (which command next), and the timing engine enforces the
constraints.  This module provides the two policies the evaluation needs:

* :func:`sequential_read_stream` — the non-PIM baseline of Fig. 4: a
  sequential weight read of ``nbytes`` per channel using FR-FCFS-style
  open-page scheduling with bank interleaving (the throughput-maximal
  policy for a streaming access pattern: ACT latencies of bank *k+1* are
  hidden under the data bursts of bank *k*).
* :func:`interleaved_rw_stream` — mixed read/write streaming (used by
  host<->PIM data movement phases and tests).

Both generators are vectorized numpy (no Python-per-command loops) so that
multi-MB workloads build in milliseconds.
"""
from __future__ import annotations

import numpy as np

from . import commands as C
from .timing import SystemSpec


def _bank_interleaved_bursts(nbytes: int, spec: SystemSpec,
                             op: int) -> np.ndarray:
    """Open-page, bank-interleaved streaming over `nbytes` of one channel."""
    t = spec.timings
    nb = t.num_banks
    bursts_total = int(np.ceil(nbytes / t.burst_bytes))
    cols_per_row = t.page_bytes // t.burst_bytes

    # Layout: rows striped across banks; within (bank, row) sequential cols.
    # Command order: for each row-group, for each bank: ACT; then sweep
    # columns round-robin across banks (maximizes bus utilization); then
    # PRE per bank.  We emit ACT_b / cols / PRE_b blocks per bank but
    # interleave columns across banks inside a row-group.
    n_rowgroups = int(np.ceil(bursts_total / (cols_per_row * nb)))
    out = []
    remaining = bursts_total
    for rg in range(n_rowgroups):
        group = min(remaining, cols_per_row * nb)
        banks_used = int(np.ceil(group / cols_per_row))
        # ACTs first (engine hides them under prior data where possible).
        acts = np.zeros((banks_used, 4), dtype=np.int32)
        acts[:, 0] = C.ACT
        acts[:, 1] = np.arange(banks_used)
        acts[:, 2] = rg
        out.append(acts)
        # Column sweep, round-robin across the used banks.
        idx = np.arange(group, dtype=np.int32)
        cas = np.zeros((group, 4), dtype=np.int32)
        cas[:, 0] = op
        cas[:, 1] = idx % banks_used
        cas[:, 2] = rg
        cas[:, 3] = idx // banks_used
        out.append(cas)
        pres = np.zeros((banks_used, 4), dtype=np.int32)
        pres[:, 0] = C.PRE
        pres[:, 1] = np.arange(banks_used)
        out.append(pres)
        remaining -= group
    if not out:
        return np.zeros((0, 4), dtype=np.int32)
    return np.concatenate(out, axis=0)


def sequential_read_stream(nbytes_per_channel: int,
                           spec: SystemSpec) -> np.ndarray:
    """Non-PIM baseline: stream-read `nbytes_per_channel` (Fig. 4 baseline)."""
    return _bank_interleaved_bursts(nbytes_per_channel, spec, C.RD)


def sequential_write_stream(nbytes_per_channel: int,
                            spec: SystemSpec) -> np.ndarray:
    return _bank_interleaved_bursts(nbytes_per_channel, spec, C.WR)


def interleaved_rw_stream(nbytes_rd: int, nbytes_wr: int,
                          spec: SystemSpec) -> np.ndarray:
    rd = _bank_interleaved_bursts(nbytes_rd, spec, C.RD)
    wr = _bank_interleaved_bursts(nbytes_wr, spec, C.WR)
    return np.concatenate([rd, wr], axis=0)


def with_refresh(stream: np.ndarray, spec: SystemSpec) -> np.ndarray:
    """Insert PREA+REFAB roughly every tREFI worth of commands.

    Command-count spacing approximates time spacing for streaming patterns
    (every command occupies >= 1 CK); exact refresh placement is a
    controller policy, and this conservative variant never violates tREFI
    for streams whose average command occupancy is >= 1 CK.
    """
    if not spec.refresh_enabled or stream.shape[0] == 0:
        return stream
    cyc = spec.derive_cycles()
    period = max(cyc.cREFI // 2, 16)  # conservative: every tREFI/2 cycles
    chunks = []
    for start in range(0, stream.shape[0], period):
        chunks.append(stream[start:start + period])
        chunks.append(np.array([[C.PREA, 0, 0, 0], [C.REFAB, 0, 0, 0]],
                               dtype=np.int32))
    return np.concatenate(chunks, axis=0)
