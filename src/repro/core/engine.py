"""JAX cycle-accurate timing engine.

The same semantics as ``engine_ref.RefEngine`` expressed as a
``jax.lax.scan`` over the command stream with a ``lax.switch`` on the
opcode.  The scan carry holds the full channel timing state; each step
emits the command's issue cycle.  The engine is jit-compiled (one
compilation per ``TimingCycles`` instance and stream length bucket) and
``vmap``-ed over the channel axis, giving ~10^6-10^7 resolved commands/s on
one CPU core — two to three orders of magnitude over the Python oracle,
which is what makes the full Fig-4 sweeps tractable.

On TPU the same scan runs on the scalar/vector units and the *fleet*
dimensions (channels × design-space points) become the parallel axes —
see DESIGN.md §2.1/§2.3 for the hardware-adaptation discussion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import commands as C
from .timing import TimingCycles

NEG = -(1 << 30)
I32 = jnp.int32


def _fresh_state(nb: int):
    z = jnp.zeros((), I32)
    neg = jnp.full((), NEG, I32)
    return dict(
        open_row=jnp.full((nb,), -1, I32),
        ready_act=jnp.zeros((nb,), I32),
        act_cycle=jnp.full((nb,), NEG, I32),
        rd_cycle=jnp.full((nb,), NEG, I32),
        wr_end=jnp.full((nb,), NEG, I32),
        faw=jnp.full((4,), NEG, I32),
        faw_i=z, last_act=neg, last_actmb=neg, last_cas=neg,
        bus_free=z, bus_dir=z, cmd_free=z,
        last_mac=neg, srf_ready=z, mac_pipe_end=z,
        mode=z, mode_ready=z, drain=z, fence_until=z,
    )


def _build_step(c: TimingCycles):
    nb = c.num_banks
    bank_ids = jnp.arange(nb, dtype=I32)

    def base_t0(st):
        return jnp.maximum(jnp.maximum(st["cmd_free"], st["fence_until"]),
                           st["mode_ready"])

    # Each branch: (st, a, b, col) -> (st, t)
    def op_nop(st, a, b, col):
        return st, base_t0(st)

    def op_act(st, a, b, col):
        t0 = base_t0(st)
        t = jnp.maximum(t0, st["ready_act"][a])
        t = jnp.maximum(t, st["act_cycle"][a] + c.cRC)
        t = jnp.maximum(t, st["last_act"] + c.cRRD)
        t = jnp.maximum(t, st["faw"][st["faw_i"]] + c.cFAW)
        st = dict(st)
        st["open_row"] = st["open_row"].at[a].set(b)
        st["act_cycle"] = st["act_cycle"].at[a].set(t)
        st["last_act"] = t
        st["faw"] = st["faw"].at[st["faw_i"]].set(t)
        st["faw_i"] = (st["faw_i"] + 1) % 4
        st["cmd_free"] = t + c.cACT
        st["drain"] = jnp.maximum(st["drain"], t + c.cRCD)
        return st, t

    def op_pre(st, a, b, col):
        t0 = base_t0(st)
        t = jnp.maximum(t0, st["act_cycle"][a] + c.cRAS)
        t = jnp.maximum(t, st["rd_cycle"][a] + c.cRTP)
        t = jnp.maximum(t, st["wr_end"][a] + c.cWR)
        st = dict(st)
        st["open_row"] = st["open_row"].at[a].set(-1)
        st["ready_act"] = st["ready_act"].at[a].set(t + c.cRP)
        st["cmd_free"] = t + c.cPRE
        st["drain"] = jnp.maximum(st["drain"], t + c.cRP)
        return st, t

    def op_prea(st, a, b, col):
        t0 = base_t0(st)
        t = jnp.maximum(t0, jnp.max(st["act_cycle"]) + c.cRAS)
        t = jnp.maximum(t, jnp.max(st["rd_cycle"]) + c.cRTP)
        t = jnp.maximum(t, jnp.max(st["wr_end"]) + c.cWR)
        t = jnp.maximum(t, st["last_mac"] + c.cRTP)
        st = dict(st)
        st["open_row"] = jnp.full((nb,), -1, I32)
        st["ready_act"] = jnp.full((nb,), 0, I32) + t + c.cRP
        st["cmd_free"] = t + c.cPRE
        st["drain"] = jnp.maximum(st["drain"], t + c.cRP)
        return st, t

    def op_rd(st, a, b, col):
        t0 = base_t0(st)
        turn = jnp.where(st["bus_dir"] == 1, c.cWTR, 0)
        t = jnp.maximum(t0, st["act_cycle"][a] + c.cRCD)
        t = jnp.maximum(t, st["last_cas"] + c.cCCD)
        t = jnp.maximum(t, st["bus_free"] + turn - c.cRL)
        t = jnp.maximum(t, st["wr_end"][a] + c.cWTR)
        st = dict(st)
        st["rd_cycle"] = st["rd_cycle"].at[a].set(t)
        st["last_cas"] = t
        st["bus_free"] = t + c.cRL + c.cBURST
        st["bus_dir"] = jnp.zeros((), I32)
        st["cmd_free"] = t + c.cCAS
        st["drain"] = jnp.maximum(st["drain"], t + c.cRL + c.cBURST)
        return st, t

    def op_wr(st, a, b, col):
        t0 = base_t0(st)
        turn = jnp.where(st["bus_dir"] == 0, c.cRTW, 0)
        t = jnp.maximum(t0, st["act_cycle"][a] + c.cRCD)
        t = jnp.maximum(t, st["last_cas"] + c.cCCD)
        t = jnp.maximum(t, st["bus_free"] + turn - c.cWL)
        end = t + c.cWL + c.cBURST
        st = dict(st)
        st["wr_end"] = st["wr_end"].at[a].set(end)
        st["last_cas"] = t
        st["bus_free"] = end
        st["bus_dir"] = jnp.ones((), I32)
        st["cmd_free"] = t + c.cCAS
        st["drain"] = jnp.maximum(st["drain"], end)
        return st, t

    def op_refab(st, a, b, col):
        t0 = base_t0(st)
        t = jnp.maximum(t0, jnp.max(st["ready_act"]))
        st = dict(st)
        st["ready_act"] = jnp.zeros((nb,), I32) + t + c.cRFC
        st["cmd_free"] = t + c.cACT
        st["drain"] = jnp.maximum(st["drain"], t + c.cRFC)
        return st, t

    def _mode(st, new_mode):
        t = jnp.maximum(base_t0(st), st["drain"])
        st = dict(st)
        st["mode"] = jnp.full((), new_mode, I32)
        st["mode_ready"] = t + c.cMODE
        st["cmd_free"] = t + c.cACT
        st["drain"] = jnp.maximum(st["drain"], t + c.cMODE)
        return st, t

    def op_mode_mb(st, a, b, col):
        return _mode(st, 1)

    def op_mode_sb(st, a, b, col):
        return _mode(st, 0)

    def op_act_mb(st, a, b, col):
        t0 = base_t0(st)
        mask = (bank_ids % 4) == a
        t = jnp.maximum(t0, st["last_actmb"] + c.cRRDMB)
        t = jnp.maximum(t, st["last_act"] + c.cRRD)
        t = jnp.maximum(t, jnp.max(jnp.where(mask, st["ready_act"], NEG)))
        t = jnp.maximum(t, jnp.max(jnp.where(mask, st["act_cycle"], NEG)) + c.cRC)
        st = dict(st)
        st["open_row"] = jnp.where(mask, b, st["open_row"])
        st["act_cycle"] = jnp.where(mask, t, st["act_cycle"])
        st["last_act"] = t
        st["last_actmb"] = t
        st["faw"] = st["faw"].at[st["faw_i"]].set(t)
        st["faw_i"] = (st["faw_i"] + 1) % 4
        st["cmd_free"] = t + c.cACT
        st["drain"] = jnp.maximum(st["drain"], t + c.cRCD)
        return st, t

    def _wr_reg(st, is_srf):
        t0 = base_t0(st)
        turn = jnp.where(st["bus_dir"] == 0, c.cRTW, 0)
        t = jnp.maximum(t0, st["last_cas"] + c.cSRFI)
        t = jnp.maximum(t, st["bus_free"] + turn - c.cWL)
        t = jnp.maximum(t, st["last_mac"] + c.cMACWR)
        end = t + c.cWL + c.cBURST
        st = dict(st)
        if is_srf:
            st["srf_ready"] = jnp.maximum(st["srf_ready"], end)
        st["last_cas"] = t
        st["bus_free"] = end
        st["bus_dir"] = jnp.ones((), I32)
        st["cmd_free"] = t + c.cCAS
        st["drain"] = jnp.maximum(st["drain"], end)
        return st, t

    def op_wr_srf(st, a, b, col):
        return _wr_reg(st, True)

    def op_wr_irf(st, a, b, col):
        return _wr_reg(st, False)

    def op_mac(st, a, b, col):
        t0 = base_t0(st)
        t = jnp.maximum(t0, st["last_mac"] + c.cMACI)
        t = jnp.maximum(t, st["srf_ready"])
        t = jnp.maximum(t, jnp.max(st["act_cycle"]) + c.cRCD)
        st = dict(st)
        st["last_mac"] = t
        st["rd_cycle"] = jnp.zeros((nb,), I32) + t
        st["mac_pipe_end"] = t + c.cMACPIPE
        st["cmd_free"] = t + c.cMACCMD
        st["drain"] = jnp.maximum(st["drain"], t + c.cMACPIPE)
        return st, t

    def op_rd_acc(st, a, b, col):
        t0 = base_t0(st)
        turn = jnp.where(st["bus_dir"] == 1, c.cWTR, 0)
        t = jnp.maximum(t0, st["mac_pipe_end"])
        t = jnp.maximum(t, st["last_cas"] + c.cCCD)
        t = jnp.maximum(t, st["bus_free"] + turn - c.cRL)
        st = dict(st)
        st["last_cas"] = t
        st["bus_free"] = t + c.cRL + c.cBURST
        st["bus_dir"] = jnp.zeros((), I32)
        st["cmd_free"] = t + c.cCAS
        st["drain"] = jnp.maximum(st["drain"], t + c.cRL + c.cBURST)
        return st, t

    def op_mov_acc(st, a, b, col):
        t0 = base_t0(st)
        t = jnp.maximum(t0, st["mac_pipe_end"])
        t = jnp.maximum(t, st["last_cas"] + c.cCCD)
        st = dict(st)
        st["wr_end"] = jnp.maximum(st["wr_end"], t + c.cMOV)
        st["last_cas"] = t
        st["cmd_free"] = t + c.cCAS
        st["drain"] = jnp.maximum(st["drain"], t + c.cMOV)
        return st, t

    def op_fence(st, a, b, col):
        t = st["drain"] + c.cFENCE
        st = dict(st)
        st["fence_until"] = t
        st["cmd_free"] = t
        st["drain"] = t
        return st, t

    branches = [op_nop, op_act, op_pre, op_prea, op_rd, op_wr, op_refab,
                op_mode_mb, op_mode_sb, op_act_mb, op_prea, op_wr_srf,
                op_wr_irf, op_mac, op_rd_acc, op_mov_acc, op_fence]
    assert len(branches) == C.NUM_OPCODES

    def step(st, cmd):
        op, a, b, col = cmd[0], cmd[1], cmd[2], cmd[3]
        st, t = jax.lax.switch(op, branches, st, a, b, col)
        return st, t

    return step


@functools.lru_cache(maxsize=16)
def make_engine(cyc: TimingCycles):
    """Build the jitted resolver for one timing configuration.

    Returns ``fn(streams)`` where ``streams`` is int32 ``(C, N, 4)`` and the
    result is ``(issue (C, N) int32, total (C,) int32)``.
    """
    step = _build_step(cyc)
    nb = cyc.num_banks

    def run_one(stream):
        st0 = _fresh_state(nb)
        st, issue = jax.lax.scan(step, st0, stream)
        return issue, st["drain"]

    batched = jax.jit(jax.vmap(run_one))

    def fn(streams: np.ndarray):
        streams = jnp.asarray(streams, dtype=I32)
        issue, total = batched(streams)
        return np.asarray(issue), np.asarray(total)

    return fn


def run_fleet(cyc: TimingCycles,
              stream_sets: list[list[np.ndarray]]
              ) -> list[np.ndarray]:
    """Resolve many simulations in one vmapped engine call.

    ``stream_sets`` is a list of per-channel stream lists (one entry per
    design/workload point).  All streams are padded to a common length
    and resolved as a single (n_points*n_channels)-wide batch — the
    "simulation fleet" axis of DESIGN.md §2.1 (on TPU this is the
    data-parallel axis of the design-space sweep).

    Returns the per-point total-cycle arrays (n_channels,).
    """
    flat = [s for ss in stream_sets for s in ss]
    counts = [len(ss) for ss in stream_sets]
    if not flat:
        return []
    batch = C.pad_streams(flat)
    _, totals = run_streams(cyc, batch)
    out = []
    i = 0
    for n in counts:
        out.append(totals[i:i + n])
        i += n
    return out


def run_streams(cyc: TimingCycles, streams) -> tuple[np.ndarray, np.ndarray]:
    """Resolve a list/array of per-channel streams; pads to equal length."""
    if isinstance(streams, list):
        streams = C.pad_streams(streams)
    if streams.ndim == 2:
        streams = streams[None]
    n = streams.shape[1]
    # Bucket lengths to powers of two to bound recompilation.
    bucket = 1 << max(4, (n - 1).bit_length())
    if bucket != n:
        pad = np.zeros((streams.shape[0], bucket - n, 4), dtype=np.int32)
        streams = np.concatenate([np.asarray(streams), pad], axis=1)
    issue, total = make_engine(cyc)(streams)
    return issue[:, :n], total
