"""JAX cycle-accurate timing engine — the fleet execution core.

The same semantics as ``engine_ref.RefEngine`` expressed as a
``jax.lax.scan`` over the command stream with a branchless, opcode-masked
step (see ``_build_step``).  The scan carry is a :class:`ChannelState`
pytree; each step emits the command's issue cycle.

Unlike the original per-spec design (one compilation per ``TimingCycles``
instance), the timing configuration is a *traced* pytree argument of the
scan step: :class:`TimingCycles` is registered as a JAX dataclass whose
cycle fields are data leaves and whose ``num_banks`` (which fixes array
shapes) is static metadata.  One jitted resolver per bank count is
``vmap``-ed over the flat *(design point x channel)* fleet axis, with both
the stream length and the fleet width padded to power-of-two buckets, so
the total number of XLA compilations is O(log points * log length) and —
critically — independent of how many distinct ``SystemSpec`` variants are
in flight.  That is what makes design-space sweeps (Fig. 4 grids, HW-knob
surfaces) dispatch-bound work into one engine call: ~10^6-10^7 resolved
commands/s per CPU core, and on TPU the fleet axis is the data-parallel
axis of the sweep (DESIGN.md §2.1/§2.3).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from collections import OrderedDict
from typing import Callable, Hashable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

try:                                   # jax >= 0.6 promotes it to jax.*
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from . import commands as C
from . import faults
from .timing import TimingCycles

NEG = -(1 << 30)
I32 = jnp.int32


@dataclasses.dataclass
class ChannelState:
    """Per-channel timing state carried through the scan (a pytree).

    Vector fields are per-bank (length ``num_banks``) except ``faw``
    (sliding window of the last four ACT issue cycles).
    """

    open_row: jax.Array
    ready_act: jax.Array
    act_cycle: jax.Array
    rd_cycle: jax.Array
    wr_end: jax.Array
    faw: jax.Array
    faw_i: jax.Array
    last_act: jax.Array
    last_actmb: jax.Array
    last_cas: jax.Array
    bus_free: jax.Array
    bus_dir: jax.Array
    cmd_free: jax.Array
    last_mac: jax.Array
    srf_ready: jax.Array
    mac_pipe_end: jax.Array
    mode: jax.Array
    mode_ready: jax.Array
    drain: jax.Array
    fence_until: jax.Array


jax.tree_util.register_dataclass(
    ChannelState,
    data_fields=[f.name for f in dataclasses.fields(ChannelState)],
    meta_fields=[],
)

_replace = dataclasses.replace


def _fresh_state(nb: int) -> ChannelState:
    z = jnp.zeros((), I32)
    neg = jnp.full((), NEG, I32)
    return ChannelState(
        open_row=jnp.full((nb,), -1, I32),
        ready_act=jnp.zeros((nb,), I32),
        act_cycle=jnp.full((nb,), NEG, I32),
        rd_cycle=jnp.full((nb,), NEG, I32),
        wr_end=jnp.full((nb,), NEG, I32),
        faw=jnp.full((4,), NEG, I32),
        faw_i=z, last_act=neg, last_actmb=neg, last_cas=neg,
        bus_free=z, bus_dir=z, cmd_free=z,
        last_mac=neg, srf_ready=z, mac_pipe_end=z,
        mode=z, mode_ready=z, drain=z, fence_until=z,
    )


def _build_step(nb: int):
    """Build the scan step for ``nb`` banks.

    The step is *branchless*: instead of a ``lax.switch`` over 17 opcode
    branches (each of which vmap would execute in full, building 17
    alternate channel states per cycle), the issue-time candidates of all
    opcodes are computed from shared subexpressions and gathered by
    opcode, and every state field is written exactly once under opcode
    masks.  ``c`` is a *traced* :class:`TimingCycles` — the timing
    configuration is data, not a compile-time constant, so one
    compilation serves every spec variant.  Semantics are bit-identical
    to ``engine_ref.RefEngine`` (the oracle tests enforce this).
    """
    bank_ids = jnp.arange(nb, dtype=I32)

    def step(c, st, cmd):
        op, a, b, _col = cmd[0], cmd[1], cmd[2], cmd[3]

        # ---- opcode predicates (scalars) -------------------------------
        is_nop = op == C.NOP
        is_act = op == C.ACT
        is_pre = op == C.PRE
        is_prea = (op == C.PREA) | (op == C.PRE_MB)
        is_rd = op == C.RD
        is_wr = op == C.WR
        is_refab = op == C.REFAB
        is_mode_mb = op == C.MODE_MB
        is_mode_sb = op == C.MODE_SB
        is_mode = is_mode_mb | is_mode_sb
        is_actmb = op == C.ACT_MB
        is_wrsrf = op == C.WR_SRF
        is_wrreg = is_wrsrf | (op == C.WR_IRF)
        is_mac = op == C.MAC
        is_rdacc = op == C.RD_ACC
        is_mov = op == C.MOV_ACC
        is_fence = op == C.FENCE
        is_actfam = is_act | is_actmb
        rd_bus = is_rd | is_rdacc
        wr_bus = is_wr | is_wrreg
        sets_cas = rd_bus | wr_bus | is_mov

        # ---- shared subexpressions -------------------------------------
        t0 = jnp.maximum(jnp.maximum(st.cmd_free, st.fence_until),
                         st.mode_ready)
        act_a = st.act_cycle[a]
        onehot_a = bank_ids == a
        quad = (bank_ids % 4) == a
        max_act = jnp.max(st.act_cycle)
        turn_r = jnp.where(st.bus_dir == 1, c.cWTR, 0)
        turn_w = jnp.where(st.bus_dir == 0, c.cRTW, 0)
        prea_t = jnp.maximum(
            jnp.maximum(t0, max_act + c.cRAS),
            jnp.maximum(jnp.maximum(jnp.max(st.rd_cycle) + c.cRTP,
                                    jnp.max(st.wr_end) + c.cWR),
                        st.last_mac + c.cRTP))
        mode_t = jnp.maximum(t0, st.drain)
        wrreg_t = jnp.maximum(
            jnp.maximum(t0, st.last_cas + c.cSRFI),
            jnp.maximum(st.bus_free + turn_w - c.cWL,
                        st.last_mac + c.cMACWR))

        # ---- issue-time candidates, gathered by opcode -----------------
        cand = jnp.stack([
            t0,                                                  # NOP
            jnp.maximum(jnp.maximum(t0, st.ready_act[a]),        # ACT
                        jnp.maximum(jnp.maximum(act_a + c.cRC,
                                                st.last_act + c.cRRD),
                                    st.faw[st.faw_i] + c.cFAW)),
            jnp.maximum(jnp.maximum(t0, act_a + c.cRAS),         # PRE
                        jnp.maximum(st.rd_cycle[a] + c.cRTP,
                                    st.wr_end[a] + c.cWR)),
            prea_t,                                              # PREA
            jnp.maximum(jnp.maximum(t0, act_a + c.cRCD),         # RD
                        jnp.maximum(jnp.maximum(st.last_cas + c.cCCD,
                                                st.bus_free + turn_r
                                                - c.cRL),
                                    st.wr_end[a] + c.cWTR)),
            jnp.maximum(jnp.maximum(t0, act_a + c.cRCD),         # WR
                        jnp.maximum(st.last_cas + c.cCCD,
                                    st.bus_free + turn_w - c.cWL)),
            jnp.maximum(t0, jnp.max(st.ready_act)),              # REFAB
            mode_t,                                              # MODE_MB
            mode_t,                                              # MODE_SB
            jnp.maximum(                                         # ACT_MB
                jnp.maximum(t0, st.last_actmb + c.cRRDMB),
                jnp.maximum(
                    st.last_act + c.cRRD,
                    jnp.maximum(
                        jnp.max(jnp.where(quad, st.ready_act, NEG)),
                        jnp.max(jnp.where(quad, st.act_cycle, NEG))
                        + c.cRC))),
            prea_t,                                              # PRE_MB
            wrreg_t,                                             # WR_SRF
            wrreg_t,                                             # WR_IRF
            jnp.maximum(jnp.maximum(t0, st.last_mac + c.cMACI),  # MAC
                        jnp.maximum(st.srf_ready,
                                    max_act + c.cRCD)),
            jnp.maximum(jnp.maximum(t0, st.mac_pipe_end),        # RD_ACC
                        jnp.maximum(st.last_cas + c.cCCD,
                                    st.bus_free + turn_r - c.cRL)),
            jnp.maximum(jnp.maximum(t0, st.mac_pipe_end),        # MOV_ACC
                        st.last_cas + c.cCCD),
            st.drain + c.cFENCE,                                 # FENCE
        ])
        t = cand[op]

        # Per-opcode command-bus occupancy and drain horizon (FENCE: 0 so
        # max(drain, t) == t, matching the drain=t of the branch form).
        zero = jnp.zeros((), I32)
        cmd_add = jnp.stack([
            zero, c.cACT, c.cPRE, c.cPRE, c.cCAS, c.cCAS, c.cACT,
            c.cACT, c.cACT, c.cACT, c.cPRE, c.cCAS, c.cCAS, c.cMACCMD,
            c.cCAS, c.cCAS, zero])
        rdburst = c.cRL + c.cBURST
        wrburst = c.cWL + c.cBURST
        drain_add = jnp.stack([
            zero, c.cRCD, c.cRP, c.cRP, rdburst, wrburst, c.cRFC,
            c.cMODE, c.cMODE, c.cRCD, c.cRP, wrburst, wrburst,
            c.cMACPIPE, rdburst, c.cMOV, zero])
        end_w = t + wrburst

        # ---- masked single-write updates per state field ---------------
        open_row = jnp.where(is_act & onehot_a, b, st.open_row)
        open_row = jnp.where(is_pre & onehot_a, -1, open_row)
        open_row = jnp.where(is_prea, -1, open_row)
        open_row = jnp.where(is_actmb & quad, b, open_row)

        ready_act = jnp.where(is_pre & onehot_a, t + c.cRP, st.ready_act)
        ready_act = jnp.where(is_prea, t + c.cRP, ready_act)
        ready_act = jnp.where(is_refab, t + c.cRFC, ready_act)

        act_cycle = jnp.where((is_act & onehot_a) | (is_actmb & quad), t,
                              st.act_cycle)

        rd_cycle = jnp.where(is_rd & onehot_a, t, st.rd_cycle)
        rd_cycle = jnp.where(is_mac, t, rd_cycle)

        wr_end = jnp.where(is_wr & onehot_a, end_w, st.wr_end)
        wr_end = jnp.where(is_mov, jnp.maximum(wr_end, t + c.cMOV), wr_end)

        faw = jnp.where(is_actfam, st.faw.at[st.faw_i].set(t), st.faw)
        faw_i = jnp.where(is_actfam, (st.faw_i + 1) % 4, st.faw_i)

        st = ChannelState(
            open_row=open_row,
            ready_act=ready_act,
            act_cycle=act_cycle,
            rd_cycle=rd_cycle,
            wr_end=wr_end,
            faw=faw,
            faw_i=faw_i,
            last_act=jnp.where(is_actfam, t, st.last_act),
            last_actmb=jnp.where(is_actmb, t, st.last_actmb),
            last_cas=jnp.where(sets_cas, t, st.last_cas),
            bus_free=jnp.where(rd_bus, t + rdburst,
                               jnp.where(wr_bus, end_w, st.bus_free)),
            bus_dir=jnp.where(rd_bus, 0,
                              jnp.where(wr_bus, 1, st.bus_dir)),
            cmd_free=jnp.where(is_nop, st.cmd_free, t + cmd_add[op]),
            last_mac=jnp.where(is_mac, t, st.last_mac),
            srf_ready=jnp.where(is_wrsrf,
                                jnp.maximum(st.srf_ready, end_w),
                                st.srf_ready),
            mac_pipe_end=jnp.where(is_mac, t + c.cMACPIPE,
                                   st.mac_pipe_end),
            mode=jnp.where(is_mode_mb, 1,
                           jnp.where(is_mode_sb, 0, st.mode)),
            mode_ready=jnp.where(is_mode, t + c.cMODE, st.mode_ready),
            drain=jnp.where(is_nop, st.drain,
                            jnp.maximum(st.drain, t + drain_add[op])),
            fence_until=jnp.where(is_fence, t, st.fence_until),
        )
        return st, t

    return step


# ---------------------------------------------------------------------------
# The fleet resolver: one compilation per (num_banks, fleet/length bucket).
# ---------------------------------------------------------------------------

_RESOLVERS: dict[tuple[int, int], Callable] = {}
_MESH_RESOLVERS: dict[tuple[int, Mesh, int], Callable] = {}
_PALLAS_RESOLVERS: dict[tuple[int, int], Callable] = {}

# Scan unroll factor: amortizes the compiled loop's per-step overhead
# (the step body is ~a hundred tiny int32 ops, so trip-count overhead is
# a real fraction of the cycle-resolution cost on CPU).  Bit-identical
# to unroll=1 — the parity/conformance suites run against the oracle.
# Default 4; override with configure_scan_unroll() or REPRO_SCAN_UNROLL.
_SCAN_UNROLL = 4
_SCAN_UNROLL_OVERRIDE: int | None = None


def configure_scan_unroll(n: int | None) -> int:
    """Set the scan unroll factor (None restores env/default).

    Unroll is a pure lowering knob — every value is bit-identical to
    unroll=1 (asserted by the parity suite); resolvers are cached per
    (num_banks, unroll), so flipping it never invalidates compiled
    programs for the other settings.
    """
    global _SCAN_UNROLL_OVERRIDE
    if n is not None and int(n) < 1:
        raise ValueError(f"scan unroll must be >= 1, got {n}")
    _SCAN_UNROLL_OVERRIDE = None if n is None else int(n)
    return scan_unroll()


def scan_unroll() -> int:
    """The active scan unroll factor (override > REPRO_SCAN_UNROLL > 4)."""
    if _SCAN_UNROLL_OVERRIDE is not None:
        return _SCAN_UNROLL_OVERRIDE
    env = int(os.environ.get("REPRO_SCAN_UNROLL", "0") or 0)
    return env if env >= 1 else _SCAN_UNROLL


def _lane_runner(num_banks: int, unroll: int | None = None):
    """The single-lane scan ``(cyc, stream) -> (issue, total)`` for one
    bank count — the body the vmapped, shard_map and Pallas resolvers
    all wrap, so every backend shares semantics by construction."""
    step = _build_step(num_banks)
    if unroll is None:
        unroll = scan_unroll()

    def run_one(cyc, stream):
        def body(st, cmd):
            return step(cyc, st, cmd)

        st, issue = jax.lax.scan(body, _fresh_state(num_banks), stream,
                                 unroll=unroll)
        return issue, st.drain

    return run_one


def _fleet_resolver(num_banks: int):
    """The jitted resolver for one bank count.

    ``fn(cycs, streams)`` where ``cycs`` is a :class:`TimingCycles` pytree
    stacked along the fleet axis (every data leaf shape ``(F,)``) and
    ``streams`` is int32 ``(F, N, 4)``; returns ``(issue (F, N), total
    (F,))``.  The timing configuration is traced, so the jit cache keys
    only on shapes — new spec variants reuse the existing executable.
    """
    key = (num_banks, scan_unroll())
    fn = _RESOLVERS.get(key)
    if fn is None:
        fn = jax.jit(jax.vmap(_lane_runner(num_banks, key[1])))
        _RESOLVERS[key] = fn
    return fn


def _mesh_resolver(num_banks: int, mesh: Mesh):
    """The jitted ``shard_map`` resolver for one (bank count, mesh).

    Same signature as :func:`_fleet_resolver`, but the fleet axis is a
    *mesh* axis: the ``(F, ...)`` inputs are sharded over the mesh's
    ``lanes`` dimension and every device runs the identical vmapped scan
    on its ``F / mesh.size`` rows — ONE compiled SPMD program per
    (num_banks, per-shard width bucket, length bucket), so
    :func:`compile_cache_size` stays as flat under a mesh as under the
    threaded dispatch.  Lanes are independent, so the program contains no
    collectives and results are bit-identical to the single-device path.
    """
    key = (num_banks, mesh, scan_unroll())
    fn = _MESH_RESOLVERS.get(key)
    if fn is None:
        spec = PartitionSpec(mesh.axis_names[0])
        fn = jax.jit(_shard_map(jax.vmap(_lane_runner(num_banks, key[2])),
                                mesh=mesh, in_specs=(spec, spec),
                                out_specs=(spec, spec)))
        _MESH_RESOLVERS[key] = fn
    return fn


def _pallas_resolver(num_banks: int):
    """The jitted Pallas resolver for one bank count.

    Same signature as :func:`_fleet_resolver`; the fleet axis becomes the
    Pallas grid and the per-lane channel state lives in VMEM/registers
    for the whole command stream (see ``kernels/lane_scan.py``).  Lazily
    imported so the engine has no hard dependency on the kernels package.
    """
    from repro.kernels import lane_scan

    key = (num_banks, scan_unroll())
    fn = _PALLAS_RESOLVERS.get(key)
    if fn is None:
        fn = lane_scan.make_lane_resolver(num_banks, unroll=key[1])
        _PALLAS_RESOLVERS[key] = fn
    return fn


def compile_cache_size() -> int:
    """Number of engine executables compiled so far (all resolvers).

    One per (num_banks, fleet-width bucket, stream-length bucket) — for
    the mesh resolvers the width bucket is *per shard*, so the count is
    independent of the mesh size; the traced timing configuration
    contributes nothing, which is what the fleet tests assert across
    ``SystemSpec`` variants.
    """
    return (sum(fn._cache_size() for fn in _RESOLVERS.values())
            + sum(fn._cache_size() for fn in _MESH_RESOLVERS.values())
            + sum(fn._cache_size() for fn in _PALLAS_RESOLVERS.values()))


# ---------------------------------------------------------------------------
# Lane-resolver backend selection: "scan" is the vmapped lax.scan family
# (single-device / threaded / shard_map dispatch above it); "pallas" swaps
# the per-slab executable for the Pallas lane kernel, keeping the dedupe /
# LRU / slab machinery identical.  "auto" resolves to pallas when the
# kernel is supported on this backend, scan otherwise — and an explicit
# "pallas" request ALSO falls back to scan when unsupported (capability-
# detected fallback; the parity suites pin bit-identity between the two).
#
# Execution follows the DEGRADATION LADDER pallas → mesh → threaded →
# single-device scan (see _ladder_rungs): a run starts on the highest
# configured rung and, on failure — a kernel raise, a mesh shard loss, an
# injected chaos fault — steps down after bounded retries, with a circuit
# breaker skipping rungs that have failed K consecutive resolves.
# Because every rung is bit-identical by contract, a degraded resolve
# returns byte-exact results; every step-down is recorded as a
# structured event (core/faults.py).
#
# Backend configuration lives in a BackendScope: the process keeps ONE
# default scope behind the classic configure_* API (so single-cell
# callers never see scopes), and serving cells each carry their own —
# one cell's mesh, backend, ladder and circuit breaker can no longer
# bleed into the other's (the old process-global _LANE_BACKEND /
# _LANE_MESH state meant a breaker tripped by prefill-side faults
# skipped that rung for decode too).
# ---------------------------------------------------------------------------

_LANE_BACKENDS = ("scan", "pallas", "auto")


@dataclasses.dataclass
class BackendScope:
    """One lane-execution scope: requested backend, lane mesh, device
    cap and its OWN circuit breaker.

    ``None`` fields fall through to the same environment defaults the
    old module globals used (``REPRO_LANE_BACKEND`` /
    ``REPRO_LANE_DEVICES``), so a fresh scope behaves exactly like an
    unconfigured process.  Serving cells construct one scope each and
    activate it around their tick work (:class:`backend_scope`), which
    is what keeps a prefill-side degradation or breaker trip from ever
    changing the decode cell's ladder.  ``mesh`` accepts an ``int`` n
    (builds a 1-D ``lanes`` mesh over the first n devices) or a
    prebuilt 1-D mesh.
    """

    backend: str | None = None
    mesh: "Mesh | int | None" = None
    max_devices: int | None = None
    breaker: "faults.CircuitBreaker | None" = dataclasses.field(
        default_factory=faults.CircuitBreaker)
    name: str = ""

    def __post_init__(self):
        if self.backend is not None:
            b = str(self.backend).lower()
            if b not in _LANE_BACKENDS:
                raise ValueError(f"lane backend must be one of "
                                 f"{_LANE_BACKENDS}, got {self.backend!r}")
            self.backend = b
        if self.mesh is not None:
            if isinstance(self.mesh, int):
                self.mesh = build_lane_mesh(self.mesh)
            elif len(self.mesh.axis_names) != 1:
                raise ValueError(f"lane mesh must be 1-D, got axes "
                                 f"{self.mesh.axis_names}")

    def scope_breaker(self) -> "faults.CircuitBreaker":
        """This scope's breaker; the default scope (``breaker=None``)
        delegates to the process breaker so ``faults.configure_breaker``
        and the chaos harness keep their classic behavior."""
        return (self.breaker if self.breaker is not None
                else faults.backend_breaker())

    def describe(self) -> dict:
        """Trace-exportable view: what this scope resolves to here."""
        return dict(
            name=self.name,
            backend=lane_backend(self),
            resolved=resolved_lane_backend(self),
            mesh=(None if self.mesh is None else int(self.mesh.size)),
            devices=len(lane_devices(self)),
            rungs=ladder_rungs(self),
            breaker=self.scope_breaker().info())


# The process-default scope: what the classic configure_* API mutates
# and what resolve_lanes runs under when no scope is active.  Its
# breaker field stays None so faults.configure_breaker() keeps
# governing the default ladder.
_DEFAULT_SCOPE = BackendScope(breaker=None, name="default")
_ACTIVE_SCOPE: BackendScope | None = None


def default_backend_scope() -> BackendScope:
    """The process-default scope (the classic configure_* target)."""
    return _DEFAULT_SCOPE


def active_backend_scope() -> BackendScope:
    """The scope lane resolution runs under right now — the default
    scope unless a :class:`backend_scope` block is active."""
    return _ACTIVE_SCOPE if _ACTIVE_SCOPE is not None else _DEFAULT_SCOPE


class backend_scope:
    """Context manager: activate ``scope`` for every lane resolve in
    the block (``None`` = the process-default scope), then restore.

    Serving cells wrap their per-tick work in this so planner →
    executor → resolve_fleet chains land in the cell's scope without
    plumbing a parameter through every layer."""

    def __init__(self, scope: BackendScope | None):
        self._scope = scope

    def __enter__(self) -> BackendScope:
        global _ACTIVE_SCOPE
        self._prev = _ACTIVE_SCOPE
        _ACTIVE_SCOPE = self._scope
        return active_backend_scope()

    def __exit__(self, *exc):
        global _ACTIVE_SCOPE
        _ACTIVE_SCOPE = self._prev
        return False


def reset_backend_scopes() -> None:
    """Deactivate any active scope and restore the default scope's
    fields to boot state (tests/conftest.py hygiene)."""
    global _ACTIVE_SCOPE
    _ACTIVE_SCOPE = None
    _DEFAULT_SCOPE.backend = None
    _DEFAULT_SCOPE.mesh = None
    _DEFAULT_SCOPE.max_devices = None


def configure_lane_backend(name: str | None) -> str:
    """Select the default scope's lane-resolver backend ("scan" |
    "pallas" | "auto").

    ``None`` restores the default (REPRO_LANE_BACKEND env var, else
    "scan").  Returns the *requested* backend; the capability-checked
    choice is :func:`resolved_lane_backend`.
    """
    if name is not None:
        name = str(name).lower()
        if name not in _LANE_BACKENDS:
            raise ValueError(f"lane backend must be one of "
                             f"{_LANE_BACKENDS}, got {name!r}")
    _DEFAULT_SCOPE.backend = name
    return lane_backend()


def lane_backend(scope: BackendScope | None = None) -> str:
    """The requested lane backend (scope > env > "scan")."""
    scope = active_backend_scope() if scope is None else scope
    if scope.backend is not None:
        return scope.backend
    env = os.environ.get("REPRO_LANE_BACKEND", "").lower()
    return env if env in _LANE_BACKENDS else "scan"


def resolved_lane_backend(scope: BackendScope | None = None) -> str:
    """The backend slabs will actually run on: "scan" or "pallas".

    "pallas"/"auto" requests degrade to "scan" when the Pallas kernel is
    not runnable here (capability probe, cached per process).
    """
    req = lane_backend(scope)
    if req == "scan":
        return "scan"
    from repro.kernels import lane_scan
    return "pallas" if lane_scan.pallas_lane_supported() else "scan"


class lane_backend_scope:
    """Context manager: run lane resolution on ``name``, then restore the
    previous backend (benchmarks, parity tests)."""

    def __init__(self, name: str | None):
        self._name = name

    def __enter__(self):
        self._prev = _DEFAULT_SCOPE.backend
        return configure_lane_backend(self._name)

    def __exit__(self, *exc):
        _DEFAULT_SCOPE.backend = self._prev
        return False


def _length_bucket(n: int) -> int:
    """Pad stream lengths to {2^k, 3*2^(k-2)} buckets (>= 16).

    The intermediate 3/4 point keeps the NOP-tail waste under 1.5x (vs 2x
    for pure powers of two); the extra executables are cheap because they
    are shared across every spec variant.
    """
    n = max(n, 1)
    b = 1 << max(4, (n - 1).bit_length())
    three_q = (3 * b) // 4
    return three_q if (n <= three_q and three_q >= 16) else b


# Widest fleet slab per engine call: beyond this the per-step state no
# longer fits cache and per-lane cost rises again, so larger groups are
# split into <=_MAX_WIDTH chunks instead of padded to the next power.
_MAX_WIDTH = 128


def _fleet_bucket(n: int) -> int:
    """Pad the fleet width to powers of two (>= 4) to bound recompiles."""
    return 1 << max(2, (max(n, 1) - 1).bit_length())


def stack_cycles(cycs: Sequence[TimingCycles]) -> TimingCycles:
    """Stack timing configs leaf-wise into one fleet-axis pytree.

    All configs must share ``num_banks`` (static metadata — it fixes the
    channel-state shapes).
    """
    return jax.tree.map(lambda *xs: jnp.asarray(xs), *cycs)


@dataclasses.dataclass
class FleetResult:
    """Resolved timing for one fleet point (one spec + channel streams).

    ``issue`` entries are ``None`` when the fleet was resolved with
    ``need_issue=False`` (totals-only — the sweep/serving fast path).
    """

    issue: list[np.ndarray | None]  # per-channel issue cycles, true lengths
    totals: np.ndarray              # (n_channels,) int32 total cycles


# ---------------------------------------------------------------------------
# Resolved-lane LRU: (TimingCycles, stream key) -> (total, issue | None).
#
# Serving loops (per-step PIM telemetry, offload plan grids) re-resolve the
# *same* lanes every decode step / replan; with planner-provided structural
# keys the repeat costs a dict lookup instead of an engine dispatch.  Totals
# are always cached; issue arrays only up to ``_LANE_ISSUE_BYTES`` so the
# cache stays memory-light (totals are what the sweep/serving layers use).
# ---------------------------------------------------------------------------

# Entries are (total, issue | None, integrity tag): the tag is a cheap
# constant-time checksum verified on every hit, so a poisoned entry —
# bit-flipped totals, truncated issue arrays — is detected and the lane
# falls back to a cold resolve instead of serving stale timing.
_LANE_CACHE: "OrderedDict[tuple, tuple[int, np.ndarray | None, int]]" = \
    OrderedDict()
_LANE_CACHE_LOCK = threading.Lock()
_LANE_CACHE_MAX = 4096
_LANE_ISSUE_BYTES = 1 << 16
_LANE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _lane_tag(total: int, issue: np.ndarray | None) -> int:
    """Constant-time integrity tag over a cache entry.

    Deliberately not cryptographic — it runs on the hit fast path, so it
    mixes the total with the issue array's endpoints and size instead of
    hashing the full buffer.  That catches the realistic poison modes
    (flipped totals, truncation, swapped arrays); byte-level interior
    corruption of a cached issue array is out of scope.
    """
    h = (int(total) * 0x9E3779B1) & 0xFFFFFFFF
    if issue is not None and issue.size:
        h ^= (int(issue[0]) * 31 + int(issue[-1]) * 17
              + int(issue.size)) & 0xFFFFFFFF
    return h


def configure_lane_cache(maxsize: int) -> None:
    """Set the lane-cache capacity (entries); 0 disables caching.

    Calling with the capacity already in effect is a no-op: entries AND
    the hit/miss/eviction counters survive, so policies that account
    against the counters (the sticky epoch watches ``misses``) are not
    skewed by a redundant reconfiguration.  A capacity *change* keeps the
    old semantics — entries dropped, counters zeroed — which is also the
    explicit fresh-state escape hatch (or use :func:`lane_cache_reset`).
    """
    global _LANE_CACHE_MAX
    maxsize = max(0, int(maxsize))
    with _LANE_CACHE_LOCK:
        if maxsize == _LANE_CACHE_MAX:
            return
        _LANE_CACHE_MAX = maxsize
        _LANE_CACHE.clear()
        for k in _LANE_STATS:
            _LANE_STATS[k] = 0


def lane_cache_reset() -> None:
    """Drop every cached lane AND zero the counters (capacity survives).

    The test/benchmark fresh-state primitive now that re-configuring an
    unchanged capacity no longer clears."""
    with _LANE_CACHE_LOCK:
        _LANE_CACHE.clear()
        for k in _LANE_STATS:
            _LANE_STATS[k] = 0


def lane_cache_clear() -> None:
    """Drop every cached lane (capacity and stats counters survive)."""
    with _LANE_CACHE_LOCK:
        _LANE_CACHE.clear()


def lane_cache_info() -> dict:
    """Lane-LRU counters.  ``misses`` is the fleet-resolve count signal
    serving policies watch: a replan against a warm cache leaves it
    untouched, so a growing miss count means real engine work happened
    (cache cleared, capacity pressure, or genuinely new lanes)."""
    with _LANE_CACHE_LOCK:
        return dict(size=len(_LANE_CACHE), maxsize=_LANE_CACHE_MAX,
                    hits=_LANE_STATS["hits"], misses=_LANE_STATS["misses"],
                    evictions=_LANE_STATS["evictions"])


def lane_cache_export() -> list[tuple]:
    """Snapshot the lane LRU as ``[(key, total, issue | None), ...]`` in
    LRU order (oldest first, so re-importing preserves eviction order).

    Keys are ``(TimingCycles, 0, structural key)`` / ``(TimingCycles, 1,
    length, byte digest)`` tuples — plain frozen dataclasses, enums and
    bytes, so the snapshot pickles (see ``core/warmstart.py`` for the
    versioned, fingerprinted on-disk format).
    """
    with _LANE_CACHE_LOCK:
        return [(k, total, issue)
                for k, (total, issue, _tag) in _LANE_CACHE.items()]


def lane_cache_import(entries: Iterable[tuple]) -> int:
    """Insert exported entries into the lane LRU; returns the count kept.

    Deliberately silent on the stats counters: warm-starting a process
    from a snapshot is not engine work, so policies watching ``misses``
    see the same world as after an in-process warm-up.  Entries beyond
    capacity evict oldest-first without bumping the eviction counter.
    """
    n = 0
    with _LANE_CACHE_LOCK:
        if _LANE_CACHE_MAX <= 0:
            return 0
        for key, total, issue in entries:
            if issue is not None:
                issue = np.asarray(issue)
                issue.setflags(write=False)
            total = int(total)
            _LANE_CACHE[key] = (total, issue, _lane_tag(total, issue))
            _LANE_CACHE.move_to_end(key)
            n += 1
        while len(_LANE_CACHE) > _LANE_CACHE_MAX:
            _LANE_CACHE.popitem(last=False)
            n -= 1
    return n


def lane_cache_touch(pairs: Iterable[tuple]) -> int:
    """Mark structurally-keyed lanes most-recently-used; returns hits.

    ``pairs`` are ``(TimingCycles, structural key)`` — the identity a
    planner hands :func:`resolve_lanes` via ``keys`` (byte-hash-keyed
    entries cannot be addressed without their bytes and are not the use
    case).  Present entries move to the MRU end of the lane LRU; absent
    ones are ignored.  This is the eviction shield for *hot small-shape
    lanes*: a speculative-decode serve touches its tiny draft-GEMV
    lanes every tick, so capacity pressure from big heterogeneous spec
    grids evicts cold sweep lanes instead of the lanes the next tick
    needs.  Deliberately silent on the hit/miss counters — touching is
    not engine work, and policies watching ``misses`` (sticky epochs)
    must not see phantom activity.
    """
    n = 0
    with _LANE_CACHE_LOCK:
        for cyc, key in pairs:
            ukey = (cyc, 0, key)
            if ukey in _LANE_CACHE:
                _LANE_CACHE.move_to_end(ukey)
                n += 1
    return n


def _lane_cache_get(key, need_issue: bool):
    if _LANE_CACHE_MAX <= 0:
        return None
    with _LANE_CACHE_LOCK:
        ent = _LANE_CACHE.get(key)
        if ent is None or (need_issue and ent[1] is None):
            _LANE_STATS["misses"] += 1
            return None
        total, issue, tag = ent
        if tag != _lane_tag(total, issue):
            # Poisoned entry: evict and fall back cold — never serve a
            # stale lane.  Counted as a miss (the caller re-resolves).
            del _LANE_CACHE[key]
            _LANE_STATS["misses"] += 1
            faults.record_event("lane_cache", "detect",
                                "poisoned entry evicted (tag mismatch)")
            return None
        _LANE_CACHE.move_to_end(key)
        _LANE_STATS["hits"] += 1
        return (total, issue)


def _lane_cache_put(key, total: int, issue: np.ndarray | None) -> None:
    if _LANE_CACHE_MAX <= 0:
        return
    if issue is not None and issue.nbytes > _LANE_ISSUE_BYTES:
        issue = None
    with _LANE_CACHE_LOCK:
        prev = _LANE_CACHE.get(key)
        if issue is None and prev is not None:
            issue = prev[1]          # never downgrade a cached issue array
        _LANE_CACHE[key] = (total, issue, _lane_tag(total, issue))
        _LANE_CACHE.move_to_end(key)
        while len(_LANE_CACHE) > _LANE_CACHE_MAX:
            _LANE_CACHE.popitem(last=False)
            _LANE_STATS["evictions"] += 1


def lane_cache_poison(n: int = 1, seed: int = 0) -> int:
    """Chaos hook: corrupt the totals of up to ``n`` cached entries in
    place (stale tags, so the integrity check catches them on the next
    hit or :func:`lane_cache_verify` sweep).  Returns entries poisoned.
    """
    rng = np.random.default_rng(seed)
    with _LANE_CACHE_LOCK:
        keys = list(_LANE_CACHE)
        if not keys:
            return 0
        picks = rng.choice(len(keys), size=min(int(n), len(keys)),
                           replace=False)
        for i in picks:
            total, issue, tag = _LANE_CACHE[keys[i]]
            _LANE_CACHE[keys[i]] = (total + 1 + int(rng.integers(1000)),
                                    issue, tag)
        return len(picks)


def lane_cache_verify() -> int:
    """Integrity sweep: evict every poisoned entry (tag mismatch),
    recording one ``detect`` event each; returns the eviction count.

    The scrub analogue of the per-hit check in ``_lane_cache_get`` —
    chaos timelines schedule it so detection is deterministic even for
    entries no request touches again.
    """
    with _LANE_CACHE_LOCK:
        bad = [k for k, (total, issue, tag) in _LANE_CACHE.items()
               if tag != _lane_tag(total, issue)]
        for k in bad:
            del _LANE_CACHE[k]
    for _ in bad:
        faults.record_event("lane_cache", "detect",
                            "poisoned entry evicted (scrub)")
    return len(bad)


# ---------------------------------------------------------------------------
# Multi-device lane sharding: slabs are load-balanced (greedy, by padded
# step count) across the visible JAX devices and dispatched from one
# worker thread per device — the lane axis is embarrassingly parallel, so
# results are bit-identical to the single-device path.  On a stock CPU
# backend there is exactly one device (single-device fallback, no threads);
# ``--xla_force_host_platform_device_count=N`` turns a multi-core host
# into an N-device fleet (how CI and the benchmarks exercise this).
# ---------------------------------------------------------------------------

def configure_lane_devices(n: int | None) -> None:
    """Cap the devices the default scope shards over (None = env/all)."""
    _DEFAULT_SCOPE.max_devices = n


def lane_devices(scope: BackendScope | None = None) -> list:
    """Devices the lane resolver shards over (default-backend order)."""
    devs = jax.devices()
    scope = active_backend_scope() if scope is None else scope
    n = scope.max_devices
    if n is None:
        n = int(os.environ.get("REPRO_LANE_DEVICES", "0") or 0) or len(devs)
    return devs[: max(1, min(n, len(devs)))]


# ---------------------------------------------------------------------------
# Mesh-sharded lane execution: when a 1-D ``lanes`` mesh is configured,
# every bucketed slab resolves as ONE jitted shard_map program whose fleet
# axis is sharded over the mesh — the compiled-program-per-(banks, bucket)
# story of the ROADMAP's fleet axis at any device count.  The thread-per-
# device dispatch above remains the fallback and the parity oracle
# (tests/test_mesh.py asserts bit-identity between the two).
# ---------------------------------------------------------------------------

def build_lane_mesh(n: int) -> Mesh:
    """Construct (without configuring) a 1-D ``lanes`` mesh over the
    first ``n`` visible devices — the one place that validates lane-mesh
    sizes (``launch.mesh.make_lane_mesh`` delegates here)."""
    devs = jax.devices()
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"lane mesh size {n} needs 1..{len(devs)} of the "
            f"visible devices (hint: XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})")
    return Mesh(np.array(devs[:n]), ("lanes",))


def configure_lane_mesh(mesh: "Mesh | int | None") -> Mesh | None:
    """Select the default scope's mesh backend for lane resolution.

    ``None`` restores the threaded fallback; an ``int`` n builds a 1-D
    ``lanes`` mesh over the first n visible devices; a prebuilt 1-D
    :class:`jax.sharding.Mesh` is used as-is (its single axis is the lane
    axis, whatever its name).  Returns the configured mesh (or None).
    """
    if mesh is None:
        _DEFAULT_SCOPE.mesh = None
        return None
    if isinstance(mesh, int):
        mesh = build_lane_mesh(mesh)
    if len(mesh.axis_names) != 1:
        raise ValueError(f"lane mesh must be 1-D, got axes "
                         f"{mesh.axis_names}")
    _DEFAULT_SCOPE.mesh = mesh
    return mesh


def lane_mesh(scope: BackendScope | None = None) -> Mesh | None:
    """The configured lane mesh (None = threaded dispatch)."""
    scope = active_backend_scope() if scope is None else scope
    return scope.mesh


class lane_mesh_scope:
    """Context manager: run lane resolution under ``mesh``, then restore
    the previous backend (used by the serve cell, benchmarks, tests)."""

    def __init__(self, mesh: "Mesh | int | None"):
        self._mesh = mesh

    def __enter__(self):
        self._prev = _DEFAULT_SCOPE.mesh
        return configure_lane_mesh(self._mesh)

    def __exit__(self, *exc):
        _DEFAULT_SCOPE.mesh = self._prev
        return False


def _mesh_width(n: int, m: int) -> int:
    """Global fleet width for ``n`` lanes on an ``m``-way mesh.

    The *per-shard* width is power-of-two bucketed (so the executable
    count stays O(log width), exactly like the threaded path) and every
    shard gets the same shape — the global width is ``m`` times that.
    """
    return _fleet_bucket(-(-n // m)) * m


# Padded slab buffers are reused across resolve calls (serving loops
# re-pack identical shapes every step); each shape keeps at most two
# spares.  Buffers are only recycled after the call's device arrays are
# materialized, so aliasing device_put backends stay safe.
_SLAB_POOL: dict[tuple[int, int], list[np.ndarray]] = {}
_SLAB_POOL_LOCK = threading.Lock()


def _take_slab(width: int, length: int) -> np.ndarray:
    with _SLAB_POOL_LOCK:
        spares = _SLAB_POOL.get((width, length))
        buf = spares.pop() if spares else None
    if buf is None:
        return np.zeros((width, length, 4), dtype=np.int32)
    buf.fill(0)
    return buf


def _give_slab(buf: np.ndarray) -> None:
    key = (buf.shape[0], buf.shape[1])
    with _SLAB_POOL_LOCK:
        spares = _SLAB_POOL.setdefault(key, [])
        if len(spares) < 2:
            spares.append(buf)


def _ladder_rungs(scope: BackendScope | None = None) -> list[str]:
    """The degradation ladder for ``scope`` (default: the active
    scope), highest rung first: pallas → mesh → threaded →
    single-device scan.

    Only configured rungs appear — "pallas" when the resolved backend is
    the Pallas kernel, "mesh" when a lane mesh is configured, "threaded"
    when more than one device is visible — and "scan" is always the
    terminal rung (a single-device vmapped lax.scan needs nothing but
    the default device).  Execution starts on the first rung whose
    breaker is closed and steps down on failure; since every rung is
    bit-identical by contract, where a resolve lands never changes its
    bytes.
    """
    scope = active_backend_scope() if scope is None else scope
    rungs = []
    if resolved_lane_backend(scope) == "pallas":
        rungs.append("pallas")
    if lane_mesh(scope) is not None:
        rungs.append("mesh")
    if len(lane_devices(scope)) > 1:
        rungs.append("threaded")
    rungs.append("scan")
    return rungs


def ladder_rungs(scope: BackendScope | None = None) -> list[str]:
    """Public view of a scope's degradation ladder (highest first) —
    what the chaos harness arms fault schedules against.  With no
    argument this is the active scope's ladder (the default scope
    unless a cell's :class:`backend_scope` block is live)."""
    return _ladder_rungs(scope)


def resolve_lanes(
    lanes: Sequence[tuple[TimingCycles, np.ndarray]],
    keys: Sequence[Hashable | None] | None = None,
    need_issue: bool = True,
    scope: BackendScope | None = None,
) -> list[tuple[np.ndarray | None, int]]:
    """Resolve a flat list of (timing config, stream) lanes.

    This is the primitive under ``resolve_fleet``: lanes are deduplicated,
    grouped by ``(num_banks, length bucket)``, and each group becomes one
    vmapped engine call per <=128-lane slab with NOP tail padding
    (semantics-preserving: NOP advances nothing).  Lanes may use
    *different* ``TimingCycles`` — the config rides along the fleet axis
    as traced data.  Returns ``(issue cycles, total cycles)`` per lane,
    in input order; issue arrays are read-only (deduplicated lanes and
    the resolved-lane LRU share them).

    Backend: execution walks the degradation ladder pallas → mesh →
    threaded → single-device scan (:func:`_ladder_rungs`), starting on
    the highest configured rung — the Pallas kernel when the resolved
    backend is "pallas" (:func:`configure_lane_backend`), else ONE
    ``shard_map`` program per slab over a configured lane mesh
    (:func:`configure_lane_mesh`), else thread-dispatched slabs across
    ``lane_devices()``.  A rung that raises (kernel fault, shard loss,
    injected chaos) is retried with backoff and then stepped past, its
    breaker counting toward a trip; every rung is bit-identical by
    contract (tests/test_mesh.py, tests/test_pallas_resolver and the
    conformance battery), so a degraded resolve returns byte-exact
    results.

    ``keys`` — optional per-lane *structural* identity: a hashable value
    the planner guarantees to determine the stream bytes (equal key ==
    byte-identical stream under the same config).  Keyed lanes dedupe —
    and hit the resolved-lane LRU — without hashing megabytes of int32;
    ``None`` entries fall back to the byte hash.  Cache *misses* are
    additionally deduplicated by byte hash (one hash per unique key, not
    per lane), so structurally-distinct requests whose streams coincide
    — e.g. equal-byte baselines of different dtypes — still resolve
    once.  ``need_issue=False`` skips materializing per-command issue
    cycles (totals-only, the ``run_many``/serving path) and makes totals
    LRU hits possible for lanes whose issue arrays were too large to
    cache.

    ``scope`` — the :class:`BackendScope` to resolve under (ladder,
    mesh, devices AND circuit breaker); defaults to the active scope,
    so cells that activate their scope with :class:`backend_scope` need
    not pass it explicitly.
    """
    scope = active_backend_scope() if scope is None else scope
    lanes = list(lanes)
    uniq: list[list] = []              # [cyc, stream, ukey]
    lane_of: list[int] = []            # flat lane -> unique lane
    uniq_index: dict = {}
    for i, (cyc, s) in enumerate(lanes):
        k = keys[i] if keys is not None else None
        if k is not None:
            ukey = (cyc, 0, k)
        else:
            s = np.ascontiguousarray(s, dtype=np.int32)
            ukey = (cyc, 1, s.shape[0],
                    hashlib.blake2b(s.tobytes(), digest_size=16).digest())
        u = uniq_index.get(ukey)
        if u is None:
            u = len(uniq)
            uniq_index[ukey] = u
            uniq.append([cyc, s, ukey])
        lane_of.append(u)

    issues: list[np.ndarray | None] = [None] * len(uniq)
    totals = np.zeros(len(uniq), dtype=np.int32)
    misses: list[int] = []
    for u, (cyc, s, ukey) in enumerate(uniq):
        ent = _lane_cache_get(ukey, need_issue)
        if ent is not None:
            totals[u] = ent[0]
            issues[u] = ent[1] if need_issue else None
        else:
            misses.append(u)

    # Second-level dedupe of the misses by byte identity; ``todo`` holds
    # one representative per distinct (config, bytes), ``alias`` the
    # cache-key lanes that share its result.
    todo: list[int] = []
    alias: dict[int, list[int]] = {}
    hash_index: dict = {}
    for u in misses:
        cyc, s, _ukey = uniq[u]
        s = np.ascontiguousarray(s, dtype=np.int32)
        uniq[u][1] = s
        hkey = (cyc, s.shape[0],
                hashlib.blake2b(s.tobytes(), digest_size=16).digest())
        rep = hash_index.get(hkey)
        if rep is None:
            hash_index[hkey] = u
            todo.append(u)
            alias[u] = []
        else:
            alias[rep].append(u)

    groups: dict[tuple[int, int], list[int]] = {}
    for u in todo:
        cyc, s, _ukey = uniq[u]
        groups.setdefault((cyc.num_banks, _length_bucket(s.shape[0])),
                          []).append(u)

    done: dict[int, bool] = {u: False for u in todo}

    def _store(chunk: list[int], iss, tot) -> None:
        """Write one slab's rows (true lengths) into the result arrays
        and the lane LRU — shared by every ladder rung, and the reason
        padded tail rows never contribute: only ``chunk`` rows are ever
        read back.  Marks the reps done so a rung failing mid-way hands
        only the unfinished remainder to the next rung."""
        for row, u in enumerate(chunk):
            if need_issue:
                # copy: a view would pin the whole padded slab;
                # read-only: results are shared between deduped
                # lanes and the LRU, so mutation must be an error
                arr = iss[row, : uniq[u][1].shape[0]].copy()
                arr.setflags(write=False)
                issues[u] = arr
            for v in (u, *alias[u]):
                totals[v] = tot[row]
                issues[v] = issues[u]
                _lane_cache_put(uniq[v][2], int(tot[row]), issues[u])
            done[u] = True

    def _pending_groups() -> dict[tuple[int, int], list[int]]:
        return {gk: left for gk, idxs in sorted(groups.items())
                if (left := [u for u in idxs if not done[u]])}

    def _run_mesh() -> None:
        # Mesh rung: every (banks, length-bucket) group runs as ONE
        # shard_map program per <=(128 x mesh) slab — the fleet axis is
        # sharded over the ``lanes`` mesh axis, the width is padded so
        # each shard gets the same power-of-two bucket, and tail rows
        # (config of lane 0, all-NOP streams) are masked by _store.
        mesh = lane_mesh(scope)
        sharding = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))
        m = mesh.size
        for (nb, length), idxs in _pending_groups().items():
            for lo in range(0, len(idxs), _MAX_WIDTH * m):
                chunk = idxs[lo:lo + _MAX_WIDTH * m]
                width = _mesh_width(len(chunk), m)
                buf = _take_slab(width, length)
                for row, u in enumerate(chunk):
                    s = uniq[u][1]
                    buf[row, : s.shape[0]] = s
                cycs = [uniq[u][0] for u in chunk]
                cycs += [cycs[0]] * (width - len(chunk))
                placed = (jax.device_put(stack_cycles(cycs), sharding),
                          jax.device_put(buf, sharding))
                iss, tot = _mesh_resolver(nb, mesh)(*placed)
                tot = np.asarray(tot)
                _store(chunk, np.asarray(iss) if need_issue else None, tot)
                _give_slab(buf)

    def _run_sharded(resolver, devs) -> None:
        # Chunk each group into <=128-lane slabs, then greedily balance
        # the slabs across devices by padded step count (width x
        # length).  The per-slab executable is the rung's (scan vs
        # Pallas); everything around it — dedupe, LRU, pooling,
        # dispatch — is shared.  With one device this degenerates to
        # the single-device scan (no worker threads).
        slabs: list[tuple[int, list[int], int, int]] = []
        for (nb, length), idxs in _pending_groups().items():
            for lo in range(0, len(idxs), _MAX_WIDTH):
                chunk = idxs[lo:lo + _MAX_WIDTH]
                slabs.append((nb, chunk, _fleet_bucket(len(chunk)),
                              length))
        loads = [0] * len(devs)
        assignment = [0] * len(slabs)
        for i in sorted(range(len(slabs)),
                        key=lambda j: -(slabs[j][2] * slabs[j][3])):
            d = loads.index(min(loads))
            assignment[i] = d
            loads[d] += slabs[i][2] * slabs[i][3]

        # Pack + place in the main thread (the pooled host buffer is
        # free for reuse once device_put has copied it); execute per
        # device in worker threads — jit execution releases the GIL, so
        # devices overlap.
        borrowed: list[np.ndarray] = []
        per_dev: list[list] = [[] for _ in devs]
        for i, (nb, chunk, width, length) in enumerate(slabs):
            buf = _take_slab(width, length)
            for row, u in enumerate(chunk):
                s = uniq[u][1]
                buf[row, : s.shape[0]] = s
            cycs = [uniq[u][0] for u in chunk]
            cycs += [cycs[0]] * (width - len(chunk))
            dev = devs[assignment[i]]
            placed = (jax.device_put(stack_cycles(cycs), dev),
                      jax.device_put(buf, dev))
            borrowed.append(buf)
            per_dev[assignment[i]].append((nb, chunk, placed))

        def _run_dev(jobs) -> None:
            for nb, chunk, (cycs, batch) in jobs:
                iss, tot = resolver(nb)(cycs, batch)
                tot = np.asarray(tot)
                _store(chunk, np.asarray(iss) if need_issue else None,
                       tot)

        act = [jobs for jobs in per_dev if jobs]
        if len(act) <= 1:
            for jobs in act:
                _run_dev(jobs)
        else:
            errors: list[BaseException] = []

            def _worker(jobs) -> None:
                try:
                    _run_dev(jobs)
                except BaseException as e:      # re-raised below
                    errors.append(e)

            workers = [threading.Thread(target=_worker, args=(jobs,))
                       for jobs in act[1:]]
            for w in workers:
                w.start()
            try:
                _run_dev(act[0])
            finally:
                for w in workers:
                    w.join()
            if errors:
                raise errors[0]
        for buf in borrowed:
            _give_slab(buf)

    def _run_rung(rung: str) -> None:
        if rung == "mesh":
            _run_mesh()
        elif rung == "pallas":
            _run_sharded(_pallas_resolver, lane_devices(scope))
        elif rung == "threaded":
            _run_sharded(_fleet_resolver, lane_devices(scope))
        else:                                   # single-device scan
            _run_sharded(_fleet_resolver, lane_devices(scope)[:1])

    # Walk the degradation ladder: start on the highest closed rung,
    # absorb transient faults with bounded retries, step down on
    # persistent failure (counting it toward the rung's breaker).  The
    # terminal scan rung is never skipped; if IT fails after retries the
    # error propagates — there is nothing below.
    if todo:
        breaker = scope.scope_breaker()
        rungs = _ladder_rungs(scope)
        for i, rung in enumerate(rungs):
            site = "backend." + rung
            terminal = i == len(rungs) - 1
            if not terminal and breaker.tripped(site):
                faults.record_event(site, "skip", "circuit open")
                continue
            try:
                faults.retry_call(lambda: _run_rung(rung), site)
                breaker.record_success(site)
                break
            except Exception as e:  # noqa: BLE001 - ladder absorbs it
                breaker.record_failure(site)
                if terminal:
                    raise
                faults.record_event(
                    site, "degrade",
                    f"stepping down to backend.{rungs[i + 1]}: "
                    f"{type(e).__name__}: {e}")

    return [(issues[lane_of[i]], int(totals[lane_of[i]]))
            for i in range(len(lane_of))]


def resolve_fleet(
    points: Sequence[tuple[TimingCycles, Iterable[np.ndarray]]],
    keys: Sequence[Sequence[Hashable | None]] | None = None,
    need_issue: bool = True,
    scope: BackendScope | None = None,
) -> list[FleetResult]:
    """Resolve many (timing config, per-channel streams) points at once.

    Flattens the *(point x channel)* fleet into lanes, resolves them with
    one :func:`resolve_lanes` pass (dedupe + bucketed vmapped engine
    calls), and regroups per point.  This absorbs the old ``run_fleet``
    helper and is the single resolution path for every layer above.
    ``keys`` optionally carries per-point per-channel structural stream
    keys (see :func:`resolve_lanes`); ``need_issue=False`` is the
    totals-only fast path.
    """
    flat: list[tuple[TimingCycles, np.ndarray]] = []
    flat_keys: list = []
    owner: list[int] = []
    for pi, (cyc, streams) in enumerate(points):
        pkeys = keys[pi] if keys is not None else None
        for ci, s in enumerate(streams):
            flat.append((cyc, s))
            flat_keys.append(pkeys[ci] if pkeys is not None else None)
            owner.append(pi)

    resolved = resolve_lanes(flat, keys=flat_keys if keys is not None
                             else None, need_issue=need_issue, scope=scope)
    out = [FleetResult(issue=[], totals=np.zeros(0, np.int32))
           for _ in points]
    per_point: list[list[int]] = [[] for _ in points]
    for pi, (iss, tot) in zip(owner, resolved):
        out[pi].issue.append(iss)
        per_point[pi].append(tot)
    for pi, fr in enumerate(out):
        fr.totals = np.asarray(per_point[pi], dtype=np.int32)
    return out


def run_streams(cyc: TimingCycles, streams) -> tuple[np.ndarray, np.ndarray]:
    """Resolve a list/array of per-channel streams; pads to equal length."""
    if isinstance(streams, list):
        streams = C.pad_streams(streams)
    streams = np.asarray(streams, dtype=np.int32)
    if streams.ndim == 2:
        streams = streams[None]
    if streams.shape[0] == 0:
        return (np.zeros((0, streams.shape[1]), dtype=np.int32),
                np.zeros((0,), dtype=np.int32))
    fr = resolve_fleet([(cyc, list(streams))])[0]
    return np.stack(fr.issue), fr.totals
