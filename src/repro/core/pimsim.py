"""LP5X-PIM Sim — top-level simulator API (the paper's Fig. 1 box).

``PimSimulator`` is the user-facing facade over the HW model (timing
engine, memory controller, device model) and the SW model (PIM Kernel:
Data Mapper + Executor).  Benchmarks, the serving offload planner and the
examples all talk to this class.

Every query path — ``gemv``, ``baseline``, ``speedup``, ``sweep`` — routes
through :meth:`run_many`, which dedupes requests against the result cache
and resolves all cache misses in one batched engine call (the fleet API).
Requests carry their own ``SystemSpec`` (the simulator's spec is only the
default), so a *design-space grid* — heterogeneous specs x models x
shapes — is also a single ``resolve_fleet`` dispatch: that is the
spec-vectorized facade the Fig-4-style sweeps and LP-Spec-style
architecture/dataflow co-optimization loops run on.
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

from repro.core.timing import DEFAULT_SYSTEM, SystemSpec
from repro.pimkernel.executor import (FunctionalGemv, GemvRequest,
                                      PimExecutor, PimResult)
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType


class PimSimulator:
    def __init__(self, spec: SystemSpec | None = None):
        self.spec = spec or DEFAULT_SYSTEM
        self.executor = PimExecutor(self.spec)
        self._cache: dict = {}

    def clear_cache(self) -> None:
        """Drop memoized request results; the next query re-resolves
        through the engine (offload replans route here via
        ``OffloadPlanner.invalidate``)."""
        self._cache.clear()

    # ------------------------------------------------------------------
    def run_many(self, reqs: Sequence[GemvRequest]) -> list[PimResult]:
        """Resolve many requests; cache-hit dedupe + one engine batch.

        Requests without an explicit spec run under the simulator's
        default; mixed-spec request lists share the single batch.
        """
        reqs = [r.resolved(self.spec) for r in reqs]
        missing, seen = [], set()
        for r in reqs:
            if r.key not in self._cache and r.key not in seen:
                missing.append(r)
                seen.add(r.key)
        if missing:
            for r, res in zip(missing, self.executor.run_many(missing)):
                self._cache[r.key] = res
        return [self._cache[r.key] for r in reqs]

    def gemv(self, H: int, W: int, dtype: PimDType | str,
             fence: bool = False, reshape: bool = False,
             flush: str = "bus",
             spec: SystemSpec | None = None) -> PimResult:
        return self.run_many([GemvRequest.pim(H, W, dtype, fence=fence,
                                              reshape=reshape, flush=flush,
                                              spec=spec)])[0]

    def baseline(self, H: int, W: int, dtype: PimDType | str,
                 spec: SystemSpec | None = None) -> PimResult:
        return self.run_many([GemvRequest.baseline(H, W, dtype,
                                                   spec=spec)])[0]

    def speedup(self, H: int, W: int, dtype: PimDType | str,
                fence: bool = False, reshape: bool = False,
                spec: SystemSpec | None = None) -> float:
        """PIM speedup vs sequential-weight-read baseline (Fig. 4)."""
        base, pim = self.run_many([
            GemvRequest.baseline(H, W, dtype, spec=spec),
            GemvRequest.pim(H, W, dtype, fence=fence, reshape=reshape,
                            spec=spec),
        ])
        return base.ns / pim.ns

    def gemv_functional(self, weights: np.ndarray, x: np.ndarray,
                        dtype: PimDType | str, **kw):
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        return self.executor.run_gemv_functional(weights, x, dtype, **kw)

    def gemv_functional_many(self, items: Sequence[FunctionalGemv]):
        """Batched HW/SW co-simulation: one timing dispatch for all items."""
        return self.executor.run_functional_many(items)

    # ------------------------------------------------------------------
    def sweep(self, dims: list[int], dtypes=None, axis: str = "activation",
              base_dim: int = 4096, fence: bool = False,
              reshape: bool = False,
              specs: Sequence[SystemSpec] | None = None) -> dict:
        """Paper Fig. 4 sweeps: vary one dimension, fix the other at 4096.

        axis='activation' varies W (input dim, top panels); axis='output'
        varies H (bottom panels).  The whole grid — every (spec, dtype,
        dim) point plus its baseline — is resolved as one fleet batch.

        With ``specs=None`` (the default spec) the result is
        ``{dtype: [speedups]}``; with a list of design variants it is
        ``{spec_index: {dtype: [speedups]}}`` — the Fig-4 surface per
        variant, still from the single batched engine query.
        """
        dtypes = [PimDType.parse(d) if isinstance(d, str) else d
                  for d in (dtypes or ALL_DTYPES)]
        single = specs is None
        specs = [self.spec] if single else list(specs)
        reqs: list[GemvRequest] = []
        for sp in specs:
            for dt in dtypes:
                for d in dims:
                    H, W = (base_dim, d) if axis == "activation" \
                        else (d, base_dim)
                    reqs.append(GemvRequest.baseline(H, W, dt, spec=sp))
                    reqs.append(GemvRequest.pim(H, W, dt, fence=fence,
                                                reshape=reshape, spec=sp))
        res = self.run_many(reqs)
        it = iter(res)
        surfaces: dict = {}
        for si, _sp in enumerate(specs):
            out: dict = {}
            for dt in dtypes:
                row = []
                for _d in dims:
                    base = next(it)
                    pim = next(it)
                    row.append(base.ns / pim.ns)
                out[dt.name] = row
            surfaces[si] = out
        return surfaces[0] if single else surfaces


@functools.lru_cache(maxsize=4)
def default_simulator() -> PimSimulator:
    return PimSimulator()
