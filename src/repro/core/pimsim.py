"""LP5X-PIM Sim — top-level simulator API (the paper's Fig. 1 box).

``PimSimulator`` is the user-facing facade over the HW model (timing
engine, memory controller, device model) and the SW model (PIM Kernel:
Data Mapper + Executor).  Benchmarks, the serving offload planner and the
examples all talk to this class.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.timing import DEFAULT_SYSTEM, SystemSpec
from repro.pimkernel.executor import PimExecutor, PimResult
from repro.pimkernel.tileconfig import ALL_DTYPES, PimDType


class PimSimulator:
    def __init__(self, spec: SystemSpec | None = None):
        self.spec = spec or DEFAULT_SYSTEM
        self.executor = PimExecutor(self.spec)
        self._cache: dict = {}

    # ------------------------------------------------------------------
    def gemv(self, H: int, W: int, dtype: PimDType | str,
             fence: bool = False, reshape: bool = False,
             flush: str = "bus") -> PimResult:
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        key = ("pim", H, W, dtype, fence, reshape, flush)
        if key not in self._cache:
            self._cache[key] = self.executor.run_gemv(
                H, W, dtype, fence=fence, reshape=reshape, flush=flush)
        return self._cache[key]

    def baseline(self, H: int, W: int, dtype: PimDType | str) -> PimResult:
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        key = ("base", H, W, dtype)
        if key not in self._cache:
            self._cache[key] = self.executor.run_baseline(H, W, dtype)
        return self._cache[key]

    def speedup(self, H: int, W: int, dtype: PimDType | str,
                fence: bool = False, reshape: bool = False) -> float:
        """PIM speedup vs sequential-weight-read baseline (Fig. 4)."""
        return (self.baseline(H, W, dtype).ns
                / self.gemv(H, W, dtype, fence=fence, reshape=reshape).ns)

    def gemv_functional(self, weights: np.ndarray, x: np.ndarray,
                        dtype: PimDType | str, **kw):
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        return self.executor.run_gemv_functional(weights, x, dtype, **kw)

    # ------------------------------------------------------------------
    def sweep(self, dims: list[int], dtypes=None, axis: str = "activation",
              base_dim: int = 4096, fence: bool = False,
              reshape: bool = False) -> dict:
        """Paper Fig. 4 sweeps: vary one dimension, fix the other at 4096.

        axis='activation' varies W (input dim, top panels); axis='output'
        varies H (bottom panels).
        """
        dtypes = dtypes or ALL_DTYPES
        out: dict = {}
        for dt in dtypes:
            row = []
            for d in dims:
                H, W = (base_dim, d) if axis == "activation" else (d, base_dim)
                row.append(self.speedup(H, W, dt, fence=fence,
                                        reshape=reshape))
            out[dt.name] = row
        return out


@functools.lru_cache(maxsize=4)
def default_simulator() -> PimSimulator:
    return PimSimulator()
