"""LP5X-PIM hardware model: timing engine, controller, device, energy."""
from .timing import SystemSpec, LpddrTimings, PimSpec, DEFAULT_SYSTEM  # noqa: F401
from .pimsim import PimSimulator  # noqa: F401
