"""DRAM + PIM command encoding.

A command stream is an int32 array of shape ``(N, 4)``::

    [opcode, bank_or_quad, row_or_slot, col_or_idx]

Opcode semantics (bank = DRAM bank id 0..15, quad = one bank per bank
group, i.e. banks ``{bg*4 + q}`` for ``bg in 0..3``):

====  =========  =============================================================
code  name       meaning
====  =========  =============================================================
0     NOP        padding; consumes nothing
1     ACT        activate ``row`` in ``bank``                       (SB mode)
2     PRE        precharge ``bank``                                 (SB mode)
3     PREA       precharge all banks
4     RD         BL16 read  ``bank``/open row/``col``               (SB mode)
5     WR         BL16 write ``bank``/open row/``col``               (SB mode)
6     REFAB      all-bank refresh (banks must be precharged)
7     MODE_MB    SB -> MB transition (drains channel first)
8     MODE_SB    MB -> SB transition (drains channel first)
9     ACT_MB     broadcast activate ``row`` in quad ``q`` (4 banks) (MB mode)
10    PRE_MB     broadcast precharge all 16 banks                   (MB mode)
11    WR_SRF     broadcast 32 B write into SRF slot ``row``         (MB mode)
12    WR_IRF     broadcast IRF/config write                         (MB mode)
13    MAC        broadcast MAC: every bank reads ``col`` of its open row,
                 multiplies against SRF operands, accumulates into ACC
14    RD_ACC     read 32 B of ACC registers from ``bank`` over the bus
15    MOV_ACC    internal ACC -> DRAM move (no data-bus usage)
16    FENCE      memory fence: drain channel, stall ``cFENCE`` cycles
====  =========  =============================================================

``FENCE`` is not a DRAM command — it models the host-side ordering stall the
paper evaluates in §3.2 (150 ns between successive tiles).
"""
from __future__ import annotations

import numpy as np

NOP = 0
ACT = 1
PRE = 2
PREA = 3
RD = 4
WR = 5
REFAB = 6
MODE_MB = 7
MODE_SB = 8
ACT_MB = 9
PRE_MB = 10
WR_SRF = 11
WR_IRF = 12
MAC = 13
RD_ACC = 14
MOV_ACC = 15
FENCE = 16

NUM_OPCODES = 17

OP_NAMES = [
    "NOP", "ACT", "PRE", "PREA", "RD", "WR", "REFAB", "MODE_MB", "MODE_SB",
    "ACT_MB", "PRE_MB", "WR_SRF", "WR_IRF", "MAC", "RD_ACC", "MOV_ACC",
    "FENCE",
]


def single(op: int, a: int = 0, b: int = 0, c: int = 0) -> np.ndarray:
    """One command as a (1, 4) int32 block."""
    return np.array([[op, a, b, c]], dtype=np.int32)


def repeat_block(op: int, count: int, a: int = 0, b: int = 0,
                 c_start: int = 0, c_step: int = 1) -> np.ndarray:
    """``count`` commands with a striding last field as one (count, 4)
    block — the vectorized building brick shared by :class:`StreamBuilder`
    and the block-synthesizing GEMV kernel."""
    block = np.empty((max(count, 0), 4), dtype=np.int32)
    if count > 0:
        block[:, 0] = op
        block[:, 1] = a
        block[:, 2] = b
        block[:, 3] = c_start + c_step * np.arange(count, dtype=np.int32)
    return block


class StreamBuilder:
    """Append-only builder for command streams (numpy int32 (N,4))."""

    __slots__ = ("_chunks", "_n")

    def __init__(self) -> None:
        self._chunks: list[np.ndarray] = []
        self._n = 0

    def emit(self, op: int, a: int = 0, b: int = 0, c: int = 0) -> None:
        self._chunks.append(single(op, a, b, c))
        self._n += 1

    def emit_block(self, arr: np.ndarray) -> None:
        assert arr.ndim == 2 and arr.shape[1] == 4
        self._chunks.append(np.asarray(arr, dtype=np.int32))
        self._n += arr.shape[0]

    def emit_repeat(self, op: int, count: int, a: int = 0, b: int = 0,
                    c_start: int = 0, c_step: int = 1) -> None:
        """Emit ``count`` commands with a striding last field (vectorized)."""
        if count <= 0:
            return
        self._chunks.append(repeat_block(op, count, a, b, c_start, c_step))
        self._n += count

    def __len__(self) -> int:
        return self._n

    def build(self) -> np.ndarray:
        if not self._chunks:
            return np.zeros((0, 4), dtype=np.int32)
        out = np.concatenate(self._chunks, axis=0)
        self._chunks = [out]
        return out


def pad_streams(streams: list[np.ndarray]) -> np.ndarray:
    """Stack variable-length streams into (C, Nmax, 4), NOP padded."""
    n = max((s.shape[0] for s in streams), default=0)
    out = np.zeros((len(streams), n, 4), dtype=np.int32)
    for i, s in enumerate(streams):
        out[i, : s.shape[0]] = s
    return out


def op_counts(stream: np.ndarray) -> np.ndarray:
    """Histogram of opcodes, length NUM_OPCODES."""
    return np.bincount(stream[:, 0], minlength=NUM_OPCODES)
