"""LPDDR5X timing / PIM device parameters.

All primary timing values are given in nanoseconds and converted to integer
command-clock (CK) cycles.  LPDDR5X-9600 operates the data bus at 9600 MT/s
per pin with WCK = 4.8 GHz and CK = 1.2 GHz (WCK:CK = 4:1).  One BL16 burst
moves 32 B per 16-bit channel and occupies 2 CK on the data bus, hence
seamless bursts at tCCD = 2 CK deliver 19.2 GB/s per channel.

JEDEC JESD209-5C timing values are speed-bin dependent; the numbers below
are representative round values documented in DESIGN.md §2.2.  PIM-specific
values (MAC interval, SRF/ACC capacities, mode-transition time, ...) are the
calibration knobs of the model — the JEDEC standard does not cover them and
the paper keeps the circuit details confidential, so they are fit so that
the paper's published speedups emerge (see EXPERIMENTS.md §Paper).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class LpddrTimings:
    """JEDEC-style analog timing values for one LPDDR5X channel (ns)."""

    ck_ghz: float = 1.2          # command clock (CK); tCK = 0.8333 ns
    data_rate_mtps: int = 9600   # per-pin data rate
    channel_bits: int = 16       # DQ width per channel
    burst_len: int = 16          # BL16
    num_bankgroups: int = 4
    banks_per_group: int = 4
    page_bytes: int = 2048       # row buffer per bank
    # --- core timings (ns) ---
    tRCD: float = 18.0
    tRP: float = 18.0
    tRAS: float = 42.0
    tRC: float = 60.0
    tRRD: float = 7.5
    tFAW: float = 30.0
    tCCD_ck: int = 2             # CAS-to-CAS, in CK (BL16 seamless)
    tRTP: float = 7.5
    tWR: float = 34.0
    tWTR: float = 10.0
    tRTW_bus: float = 5.0        # extra data-bus turnaround rd->wr
    tRL: float = 15.0            # read latency (CAS to data)
    tWL: float = 9.0             # write latency
    tRFCab: float = 280.0        # all-bank refresh (8 Gb die)
    tREFI: float = 3904.0
    cmd_act_ck: int = 2          # ACT occupies 2 CA slots (ACT-1/ACT-2)
    cmd_cas_ck: int = 2          # RD/WR occupy 2 CA slots
    cmd_pre_ck: int = 1

    @property
    def tck_ns(self) -> float:
        return 1.0 / self.ck_ghz

    @property
    def num_banks(self) -> int:
        return self.num_bankgroups * self.banks_per_group

    @property
    def burst_bytes(self) -> int:
        return self.burst_len * self.channel_bits // 8  # 32 B

    @property
    def channel_gbps(self) -> float:
        """Peak data bandwidth per channel in GB/s."""
        return self.data_rate_mtps * 1e6 * self.channel_bits / 8 / 1e9


@dataclasses.dataclass(frozen=True)
class PimSpec:
    """LP5X-PIM block parameters (per-bank PIM units).  Calibrated knobs."""

    srf_bytes: int = 512         # source register file (input-vector chunk)
    acc_regs: int = 64           # 32-bit accumulators -> T_h
    acc_bytes_per_reg: int = 4
    irf_entries: int = 32        # instruction register file depth
    mac_interval_ck: int = 3     # broadcast MAC command spacing (CK)
    mac_cmd_ck: int = 1          # CA-bus slots a MAC occupies
    mac_pipe_ck: int = 18        # MAC pipeline depth (drain before readout)
    mac_wr_gap_ck: int = 12      # last MAC -> SRF/IRF write turnaround
    srf_wr_interval_ck: int = 14  # WR_SRF/WR_IRF spacing (SRF write port)
    tRRD_mb_ck: int = 30         # ACT_MB -> ACT_MB spacing (power limited)
    tMODE_ns: float = 150.0      # SB<->MB mode transition
    mov_acc_ck: int = 16         # ACC -> DRAM internal move per burst
    irf_setup_cmds: int = 16     # WR_IRF commands to program a kernel
    irf_chunk_cmds: int = 4      # per-chunk IRF/config rewrites
    max_reshape_split: int = 2   # column-split bound (IRF addressing)
    fence_restart_pre: bool = True   # fences force row close (ordering)

    @property
    def acc_file_bytes(self) -> int:
        return self.acc_regs * self.acc_bytes_per_reg


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """Full reference memory system: LPDDR5X-9600, 4 channels (paper §3)."""

    timings: LpddrTimings = dataclasses.field(default_factory=LpddrTimings)
    pim: PimSpec = dataclasses.field(default_factory=PimSpec)
    num_channels: int = 4
    num_ranks: int = 1
    fence_ns: float = 150.0      # static memory-fence latency (paper §3.2)
    refresh_enabled: bool = False

    @property
    def total_pim_blocks(self) -> int:
        return self.num_channels * self.num_ranks * self.timings.num_banks

    def derive_cycles(self) -> "TimingCycles":
        t = self.timings
        p = self.pim

        def ck(ns: float) -> int:
            return int(math.ceil(ns / t.tck_ns - 1e-9))

        return TimingCycles(
            tck_ns=t.tck_ns,
            num_banks=t.num_banks,
            cRCD=ck(t.tRCD), cRP=ck(t.tRP), cRAS=ck(t.tRAS), cRC=ck(t.tRC),
            cRRD=ck(t.tRRD), cFAW=ck(t.tFAW), cCCD=t.tCCD_ck,
            cRTP=ck(t.tRTP), cWR=ck(t.tWR), cWTR=ck(t.tWTR),
            cRTW=ck(t.tRTW_bus), cRL=ck(t.tRL), cWL=ck(t.tWL),
            cBURST=t.tCCD_ck, cRFC=ck(t.tRFCab), cREFI=ck(t.tREFI),
            cACT=t.cmd_act_ck, cCAS=t.cmd_cas_ck, cPRE=t.cmd_pre_ck,
            cMODE=ck(p.tMODE_ns), cMACI=p.mac_interval_ck,
            cMACCMD=p.mac_cmd_ck, cMACPIPE=p.mac_pipe_ck,
            cMACWR=p.mac_wr_gap_ck, cSRFI=p.srf_wr_interval_ck,
            cRRDMB=p.tRRD_mb_ck, cMOV=p.mov_acc_ck,
            cFENCE=ck(self.fence_ns),
        )


@dataclasses.dataclass(frozen=True)
class TimingCycles:
    """All constraints in integer CK cycles — shared by both engines.

    Registered as a JAX pytree so the cycle engine can take the timing
    configuration as a *traced* argument: every cycle field (and the
    engine-unused ``tck_ns``) is a data leaf, while ``num_banks`` — which
    fixes the channel-state array shapes — stays static metadata.  Stacking
    many instances leaf-wise yields the per-point timing data of a
    simulation fleet (`engine.stack_cycles`), which is how one compiled
    resolver serves every ``SystemSpec`` variant.
    """

    tck_ns: float
    num_banks: int
    cRCD: int; cRP: int; cRAS: int; cRC: int
    cRRD: int; cFAW: int; cCCD: int
    cRTP: int; cWR: int; cWTR: int; cRTW: int
    cRL: int; cWL: int; cBURST: int
    cRFC: int; cREFI: int
    cACT: int; cCAS: int; cPRE: int
    cMODE: int; cMACI: int; cMACCMD: int; cMACPIPE: int
    cMACWR: int; cSRFI: int; cRRDMB: int; cMOV: int
    cFENCE: int

    def as_tuple(self) -> tuple:
        return dataclasses.astuple(self)


try:  # register lazily so numpy-only users never pay the jax import
    import jax.tree_util as _jtu

    _jtu.register_dataclass(
        TimingCycles,
        data_fields=[f.name for f in dataclasses.fields(TimingCycles)
                     if f.name != "num_banks"],
        meta_fields=["num_banks"],
    )
except ImportError:  # pragma: no cover - jax is a hard dep elsewhere
    pass


# A default spec used across tests/benchmarks.
DEFAULT_SYSTEM = SystemSpec()
