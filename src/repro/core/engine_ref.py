"""Pure-Python reference timing engine (the oracle).

Resolves the issue cycle of every command in a stream under the LPDDR5X +
PIM timing constraints.  Semantics here are authoritative; the JAX engine
(`engine.py`) must produce bit-identical issue cycles (asserted by unit and
hypothesis tests).

The engine is *command-level cycle-accurate*: every JEDEC constraint is an
explicit ``max(last_event + t_constraint, ...)`` term, which is equivalent
to an event-driven simulation for in-order per-channel streams (the memory
controller's scheduling policy lives in the stream generators — see
``core/controller.py`` and ``pimkernel/gemv.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import commands as C
from .timing import TimingCycles

NEG = -(1 << 30)  # "never happened"


@dataclasses.dataclass
class ChannelState:
    """Mutable timing state for one channel (single rank)."""

    nb: int
    open_row: np.ndarray         # (nb,) int; -1 closed
    ready_act: np.ndarray        # (nb,) earliest ACT (precharge done)
    act_cycle: np.ndarray        # (nb,) last ACT issue
    rd_cycle: np.ndarray         # (nb,) last RD issue (tRTP)
    wr_end: np.ndarray           # (nb,) last WR data end (tWR)
    faw: np.ndarray              # (4,) ring of last ACT cycles
    faw_i: int = 0
    last_act: int = NEG          # any-bank ACT (tRRD)
    last_actmb: int = NEG
    last_cas: int = NEG          # any CAS (tCCD)
    bus_free: int = 0            # data bus free cycle
    bus_dir: int = 0             # 0 = rd, 1 = wr
    cmd_free: int = 0            # next CA-bus slot
    last_mac: int = NEG
    srf_ready: int = 0           # SRF contents usable
    mac_pipe_end: int = 0        # MAC pipeline drained
    mode: int = 0                # 0 = SB, 1 = MB
    mode_ready: int = 0
    drain: int = 0               # running max completion (fences/modes)
    fence_until: int = 0

    @classmethod
    def fresh(cls, nb: int) -> "ChannelState":
        return cls(
            nb=nb,
            open_row=np.full(nb, -1, dtype=np.int64),
            ready_act=np.zeros(nb, dtype=np.int64),
            act_cycle=np.full(nb, NEG, dtype=np.int64),
            rd_cycle=np.full(nb, NEG, dtype=np.int64),
            wr_end=np.full(nb, NEG, dtype=np.int64),
            faw=np.full(4, NEG, dtype=np.int64),
        )


def _quad_banks(q: int, nb: int) -> list[int]:
    """ACT_MB quad q activates one bank per bank group: banks {bg*4 + q}."""
    return [bg * 4 + q for bg in range(nb // 4)]


class RefEngine:
    """Reference resolver.  ``run`` returns (issue_cycles, total_cycles)."""

    def __init__(self, cyc: TimingCycles, validate: bool = True):
        self.c = cyc
        self.validate = validate

    def run(self, stream: np.ndarray) -> tuple[np.ndarray, int]:
        c = self.c
        st = ChannelState.fresh(c.num_banks)
        issue = np.zeros(stream.shape[0], dtype=np.int64)
        for i in range(stream.shape[0]):
            op, a, b, col = (int(x) for x in stream[i])
            issue[i] = self._step(st, op, a, b, col)
        return issue, int(st.drain)

    # ------------------------------------------------------------------
    def _step(self, st: ChannelState, op: int, a: int, b: int, col: int) -> int:
        c = self.c
        t0 = max(st.cmd_free, st.fence_until, st.mode_ready)

        if op == C.NOP:
            return t0

        if op == C.ACT:
            if self.validate:
                assert st.mode == 0, "ACT only in SB mode"
                assert st.open_row[a] == -1, f"bank {a} already open"
            t = max(t0, int(st.ready_act[a]), int(st.act_cycle[a]) + c.cRC,
                    st.last_act + c.cRRD, int(st.faw[st.faw_i]) + c.cFAW)
            st.open_row[a] = b
            st.act_cycle[a] = t
            st.last_act = t
            st.faw[st.faw_i] = t
            st.faw_i = (st.faw_i + 1) % 4
            st.cmd_free = t + c.cACT
            st.drain = max(st.drain, t + c.cRCD)
            return t

        if op == C.PRE:
            t = max(t0, int(st.act_cycle[a]) + c.cRAS,
                    int(st.rd_cycle[a]) + c.cRTP, int(st.wr_end[a]) + c.cWR)
            st.open_row[a] = -1
            st.ready_act[a] = t + c.cRP
            st.cmd_free = t + c.cPRE
            st.drain = max(st.drain, t + c.cRP)
            return t

        if op == C.PREA or op == C.PRE_MB:
            t = max(t0, int(st.act_cycle.max()) + c.cRAS,
                    int(st.rd_cycle.max()) + c.cRTP,
                    int(st.wr_end.max()) + c.cWR,
                    st.last_mac + c.cRTP)
            st.open_row[:] = -1
            st.ready_act[:] = t + c.cRP
            st.cmd_free = t + c.cPRE
            st.drain = max(st.drain, t + c.cRP)
            return t

        if op == C.RD:
            if self.validate:
                assert st.mode == 0 and st.open_row[a] == b, "RD row mismatch"
            turn = c.cWTR if st.bus_dir == 1 else 0
            t = max(t0, int(st.act_cycle[a]) + c.cRCD, st.last_cas + c.cCCD,
                    st.bus_free + turn - c.cRL,
                    int(st.wr_end[a]) + c.cWTR)
            st.rd_cycle[a] = t
            st.last_cas = t
            st.bus_free = t + c.cRL + c.cBURST
            st.bus_dir = 0
            st.cmd_free = t + c.cCAS
            st.drain = max(st.drain, t + c.cRL + c.cBURST)
            return t

        if op == C.WR:
            if self.validate:
                assert st.mode == 0 and st.open_row[a] == b, "WR row mismatch"
            turn = c.cRTW if st.bus_dir == 0 else 0
            t = max(t0, int(st.act_cycle[a]) + c.cRCD, st.last_cas + c.cCCD,
                    st.bus_free + turn - c.cWL)
            st.wr_end[a] = t + c.cWL + c.cBURST
            st.last_cas = t
            st.bus_free = t + c.cWL + c.cBURST
            st.bus_dir = 1
            st.cmd_free = t + c.cCAS
            st.drain = max(st.drain, t + c.cWL + c.cBURST)
            return t

        if op == C.REFAB:
            if self.validate:
                assert (st.open_row == -1).all(), "REFAB needs all precharged"
            t = max(t0, int(st.ready_act.max()))
            st.ready_act[:] = t + c.cRFC
            st.cmd_free = t + c.cACT
            st.drain = max(st.drain, t + c.cRFC)
            return t

        if op in (C.MODE_MB, C.MODE_SB):
            t = max(t0, st.drain)
            st.mode = 1 if op == C.MODE_MB else 0
            st.mode_ready = t + c.cMODE
            st.cmd_free = t + c.cACT
            st.drain = max(st.drain, t + c.cMODE)
            return t

        if op == C.ACT_MB:
            if self.validate:
                assert st.mode == 1, "ACT_MB only in MB mode"
            banks = _quad_banks(a, st.nb)
            t = max(t0, st.last_actmb + c.cRRDMB, st.last_act + c.cRRD,
                    max(int(st.ready_act[x]) for x in banks),
                    max(int(st.act_cycle[x]) for x in banks) + c.cRC)
            for x in banks:
                st.open_row[x] = b
                st.act_cycle[x] = t
            st.last_act = t
            st.last_actmb = t
            st.faw[st.faw_i] = t
            st.faw_i = (st.faw_i + 1) % 4
            st.cmd_free = t + c.cACT
            st.drain = max(st.drain, t + c.cRCD)
            return t

        if op in (C.WR_SRF, C.WR_IRF):
            turn = c.cRTW if st.bus_dir == 0 else 0
            t = max(t0, st.last_cas + c.cSRFI,
                    st.bus_free + turn - c.cWL,
                    st.last_mac + c.cMACWR)
            end = t + c.cWL + c.cBURST
            if op == C.WR_SRF:
                st.srf_ready = max(st.srf_ready, end)
            st.last_cas = t
            st.bus_free = end
            st.bus_dir = 1
            st.cmd_free = t + c.cCAS
            st.drain = max(st.drain, end)
            return t

        if op == C.MAC:
            if self.validate:
                assert st.mode == 1, "MAC only in MB mode"
                assert (st.open_row >= 0).all() or True  # partial fills allowed
            t = max(t0, st.last_mac + c.cMACI, st.srf_ready,
                    int(st.act_cycle.max()) + c.cRCD)
            st.last_mac = t
            st.rd_cycle[:] = t              # MAC reads the open rows
            st.mac_pipe_end = t + c.cMACPIPE
            st.cmd_free = t + c.cMACCMD
            st.drain = max(st.drain, st.mac_pipe_end)
            return t

        if op == C.RD_ACC:
            turn = c.cWTR if st.bus_dir == 1 else 0
            t = max(t0, st.mac_pipe_end, st.last_cas + c.cCCD,
                    st.bus_free + turn - c.cRL)
            st.last_cas = t
            st.bus_free = t + c.cRL + c.cBURST
            st.bus_dir = 0
            st.cmd_free = t + c.cCAS
            st.drain = max(st.drain, t + c.cRL + c.cBURST)
            return t

        if op == C.MOV_ACC:
            t = max(t0, st.mac_pipe_end, st.last_cas + c.cCCD)
            st.wr_end[:] = np.maximum(st.wr_end, t + c.cMOV)
            st.last_cas = t
            st.cmd_free = t + c.cCAS
            st.drain = max(st.drain, t + c.cMOV)
            return t

        if op == C.FENCE:
            # The host-side fence latency is paid per fence instruction:
            # the fence retires cFENCE after the channel drains.
            t = st.drain + c.cFENCE
            st.fence_until = t
            st.cmd_free = t
            st.drain = t
            return t

        raise ValueError(f"unknown opcode {op}")
