"""Functional LP5X-PIM device model (behavioral fidelity layer).

Interprets a GEMV command stream *at burst granularity* against the
per-bank DRAM images produced by the Data Mapper: ACT_MB tracks open rows,
WR_SRF fills the source register files (payload side-band), MAC executes
the IRF program step (decode 32 B of weights from the open row, multiply
against the SRF window, accumulate), RD_ACC snapshots the accumulator
file.  The output must equal ``W @ x`` computed by numpy — asserted by the
behavioral tests — which is the "consistent behavioral accuracy" the paper
claims for the integrated HW/SW model.

The interpreter is deliberately independent from the stream *generator*:
it trusts only the command stream, the DRAM images, and the IRF program,
so layout or codegen bugs cannot cancel out.
"""
from __future__ import annotations

import numpy as np

from repro.core import commands as C
from repro.pimkernel import codegen
from repro.pimkernel.datamapper import PimLayout

BURST = 32


class PimDeviceModel:
    """Functional interpreter for one channel."""

    def __init__(self, layout: PimLayout, program: codegen.PimProgram,
                 channel: int,
                 dram: dict[tuple[int, int, int], np.ndarray]):
        self.layout = layout
        self.program = program
        self.ch = channel
        spec = layout.spec
        self.page = spec.timings.page_bytes
        self.nb = spec.timings.num_banks
        self.nr = spec.num_ranks
        self.dram = {(r, b): dram[(channel, r, b)]
                     for r in range(self.nr) for b in range(self.nb)}
        is_fp = layout.tc.dtype.is_fp
        self.acc_dtype = np.float64 if is_fp else np.int64
        self.srf = {(r, b): np.zeros(layout.tc.srf_wr_cmds * BURST, np.uint8)
                    for r in range(self.nr) for b in range(self.nb)}
        self.acc = {(r, b): np.zeros(layout.tc.t_h, self.acc_dtype)
                    for r in range(self.nr) for b in range(self.nb)}
        self.open_row = np.full(self.nb, -1, dtype=np.int64)
        self.pc = 0
        self.round = -1
        self.bankmap: dict[tuple[int, int], tuple[int, int]] = {}
        self.snapshots: dict[tuple[int, int, int], np.ndarray] = {}
        self._flushed: set[tuple[int, int]] = set()

    # ------------------------------------------------------------------
    def _enter_round(self, rnd: int) -> None:
        self.round = rnd
        self.bankmap.clear()
        self._flushed.clear()
        for logical in self.layout.active_logicals(rnd):
            r2, (ch, rank, bank) = self.layout.place(logical)
            if ch == self.ch:
                self.bankmap[(rank, bank)] = (logical // self.layout.split,
                                              logical % self.layout.split)
        for key in self.acc:
            self.acc[key][:] = 0

    def run(self, stream: np.ndarray,
            payloads: dict[int, np.ndarray]) -> dict:
        tc = self.layout.tc
        prog = self.program
        for i in range(stream.shape[0]):
            op, a, b, col = (int(v) for v in stream[i])
            if op == C.MAC:
                svals_cache: dict[int, np.ndarray] = {}
                for (rank, bank), (_h, g) in self.bankmap.items():
                    row = int(self.open_row[bank])
                    assert row >= 0, "MAC on closed row"
                    byte = row * self.page + col * BURST
                    img = self.dram[(rank, bank)]
                    raw = img[byte:byte + BURST]
                    w = codegen.decode_w_burst(raw, tc.dtype)
                    srf_vals = codegen.decode_srf(self.srf[(rank, bank)],
                                                  tc.dtype)
                    o = int(prog.srf_off[self.pc])
                    seg = srf_vals[o:o + prog.n_elems]
                    acc_i = int(prog.acc_idx[self.pc])
                    if tc.dtype.is_fp:
                        self.acc[(rank, bank)][acc_i] += float(
                            np.dot(w.astype(np.float64),
                                   seg.astype(np.float64)))
                    else:
                        self.acc[(rank, bank)][acc_i] += int(
                            np.dot(w.astype(np.int64),
                                   seg.astype(np.int64)))
                self.pc += 1
            elif op == C.ACT_MB:
                banks = [bg * 4 + a for bg in range(self.nb // 4)]
                for bk in banks:
                    self.open_row[bk] = b
            elif op == C.PRE_MB or op == C.PREA:
                self.open_row[:] = -1
            elif op == C.WR_SRF:
                data = payloads.get(i)
                if data is not None:
                    for (rank, bank), (_h, g) in self.bankmap.items():
                        if g == a:
                            self.srf[(rank, bank)][
                                b * BURST:(b + 1) * BURST] = data
            elif op == C.WR_IRF:
                if b == 1:  # chunk-start marker
                    self.pc = 0
                    if a != self.round:
                        self._enter_round(a)
            elif op == C.RD_ACC:
                key = (b, a)  # (rank, bank)
                if key in self.bankmap and key not in self._flushed:
                    self._flushed.add(key)
                    self.snapshots[(b, a, self.round)] = \
                        self.acc[key].copy()
            # NOP/ACT/PRE/RD/WR/REFAB/MODE_*/FENCE/MOV_ACC: no functional
            # effect on the GEMV datapath model.
        return self.snapshots


def execute_gemv(layout: PimLayout, program: codegen.PimProgram,
                 dram: dict, streams, payloads) -> np.ndarray:
    """Run all channels' streams; assemble y (padded_h) from ACC snapshots."""
    is_fp = layout.tc.dtype.is_fp
    y = np.zeros(layout.padded_h, dtype=np.float64 if is_fp else np.int64)
    snaps = {}
    for ch in range(layout.spec.num_channels):
        dev = PimDeviceModel(layout, program, ch, dram)
        snaps[ch] = dev.run(streams[ch], payloads[ch])
    for logical in range(layout.n_logical):
        rnd, (ch, rank, bank) = layout.place(logical)
        h = logical // layout.split
        acc = snaps[ch].get((rank, bank, rnd))
        assert acc is not None, f"missing flush for logical {logical}"
        y[h * layout.tc.t_h:(h + 1) * layout.tc.t_h] += acc
    return y[: layout.H]
