"""Persistent warm-start caches: XLA compile cache + on-disk lane LRU.

A fresh simulator process pays two cold-start costs before it reaches
steady-state throughput: XLA recompilation of the fleet resolvers
(seconds per (num_banks, width-bucket, length-bucket) triple) and a cold
resolved-lane LRU (every structural stream key re-resolved once).  Both
are pure caches of deterministic computations, so both persist:

* :func:`enable_compilation_cache` points JAX's persistent compilation
  cache at ``<cache_dir>/xla`` — the second process deserializes the
  compiled executables instead of rebuilding them.
* :func:`save_lane_snapshot` / :func:`load_lane_snapshot` round-trip the
  engine's resolved-lane LRU (``engine.lane_cache_export`` /
  ``lane_cache_import``) through a versioned, fingerprinted pickle at
  ``<cache_dir>/lanes.pkl``, so a fresh serve process replays cached
  lanes with *zero* fleet resolves.

The snapshot is advisory, never load-bearing: the fingerprint (blake2b
over the snapshot format version, the opcode table, and the
``TimingCycles`` field layout) rejects snapshots written by a different
engine revision, and *any* failure to read — truncated file, corrupt
pickle, wrong version, wrong fingerprint — degrades to a cold cache
instead of raising.  Writes are atomic (tmp + ``os.replace``) so a
crashed writer can at worst leave the previous snapshot in place.

The launchers (``launch/serve.py`` / ``dryrun.py`` / ``train.py``) and
benchmarks wire this behind ``--cache-dir`` / ``REPRO_CACHE_DIR`` via
:func:`enable_warm_start` at startup and :func:`save_warm_start` at exit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile

from . import commands as C
from . import engine
from . import faults
from .timing import TimingCycles

SNAPSHOT_VERSION = 1
_MAGIC = b"repro-lane-snapshot"

_ENV_CACHE_DIR = "REPRO_CACHE_DIR"


def cache_dir_from_env() -> str | None:
    """The ``REPRO_CACHE_DIR`` env knob (None when unset/empty)."""
    d = os.environ.get(_ENV_CACHE_DIR, "").strip()
    return d or None


def snapshot_fingerprint() -> str:
    """Engine-revision fingerprint a snapshot must match to load.

    Hashes the things a cached ``(key -> total, issue)`` mapping is only
    valid under: the snapshot format version, the opcode table (names and
    count — renumbering opcodes silently changes stream semantics), and
    the ``TimingCycles`` field layout (keys embed ``TimingCycles``
    instances; a field added or reordered means old totals no longer
    describe the same timing model).
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(_MAGIC)
    h.update(str(SNAPSHOT_VERSION).encode())
    h.update((",".join(C.OP_NAMES) + f":{C.NUM_OPCODES}").encode())
    h.update(",".join(
        f.name for f in dataclasses.fields(TimingCycles)).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Lane-LRU snapshot
# ---------------------------------------------------------------------------

def lane_snapshot_path(cache_dir: str) -> str:
    return os.path.join(cache_dir, "lanes.pkl")


def save_lane_snapshot(cache_dir: str) -> int:
    """Atomically write the current lane LRU under ``cache_dir``.

    Returns the number of entries written.  An empty cache still writes a
    (valid, empty) snapshot — "warm but empty" and "never saved" are
    different states to a replay harness.
    """
    os.makedirs(cache_dir, exist_ok=True)
    entries = engine.lane_cache_export()
    payload = {
        "magic": _MAGIC,
        "version": SNAPSHOT_VERSION,
        "fingerprint": snapshot_fingerprint(),
        "entries": entries,
    }
    path = lane_snapshot_path(cache_dir)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, prefix=".lanes-", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            f.flush()
            os.fsync(f.fileno())
        faults.maybe_fail("warmstart")   # crash-mid-write injection seam
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return len(entries)


def load_lane_snapshot(cache_dir: str) -> int:
    """Load a lane snapshot into the engine's LRU; returns entries kept.

    Corruption-tolerant by contract: any failure mode — missing file,
    truncation, un-unpicklable bytes, version or fingerprint mismatch,
    malformed entries — returns 0 and leaves the cache cold.  Never
    raises.
    """
    path = lane_snapshot_path(cache_dir)
    if not os.path.exists(path):
        return 0
    try:
        faults.maybe_fail("warmstart")   # corrupt-read injection seam
        with open(path, "rb") as f:
            payload = pickle.load(f)
        reason = _reject_reason(payload)
        if reason is None:
            return engine.lane_cache_import(payload["entries"])
    except Exception as e:  # noqa: BLE001 - cold start beats a crash
        reason = f"{type(e).__name__}: {e}"
    faults.record_event("warmstart", "detect",
                        f"snapshot rejected, cold start: {reason}")
    return 0


def _reject_reason(payload) -> str | None:
    """Why a decoded snapshot payload is unusable (None = valid)."""
    if not isinstance(payload, dict):
        return f"payload is {type(payload).__name__}, not dict"
    if payload.get("magic") != _MAGIC:
        return "bad magic"
    if payload.get("version") != SNAPSHOT_VERSION:
        return f"version {payload.get('version')!r} != {SNAPSHOT_VERSION}"
    if payload.get("fingerprint") != snapshot_fingerprint():
        return "engine fingerprint mismatch"
    if not isinstance(payload.get("entries"), list):
        return "entries is not a list"
    return None


# ---------------------------------------------------------------------------
# XLA persistent compilation cache
# ---------------------------------------------------------------------------

def enable_compilation_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at ``<cache_dir>/xla``.

    Thresholds are dropped to zero so even the engine's small resolver
    jits persist (the defaults skip sub-second compiles, which is exactly
    the population a simulator cold-start is made of).  Version-tolerant:
    tries the modern ``jax.config`` flags first, falls back to the
    ``compilation_cache.set_cache_dir`` API, and reports False (warm
    start degrades to lane snapshot only) if neither exists.
    """
    xla_dir = os.path.join(cache_dir, "xla")
    os.makedirs(xla_dir, exist_ok=True)
    import jax

    try:
        jax.config.update("jax_compilation_cache_dir", xla_dir)
        try:
            jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
            jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        except Exception:  # noqa: BLE001 - thresholds are best-effort
            pass
        return True
    except Exception:      # noqa: BLE001 - older jax: legacy API below
        pass
    try:
        from jax.experimental.compilation_cache import compilation_cache
        compilation_cache.set_cache_dir(xla_dir)
        return True
    except Exception:      # noqa: BLE001 - no persistent cache available
        return False


# ---------------------------------------------------------------------------
# One-call launcher wiring
# ---------------------------------------------------------------------------

def enable_warm_start(cache_dir: str | None = None) -> dict:
    """Enable every persistent cache under ``cache_dir`` (or env knob).

    Returns a small report ``{"cache_dir", "compile_cache", "lanes"}``;
    with no directory configured it is a no-op reporting
    ``{"cache_dir": None, ...}`` so launchers can call it
    unconditionally.
    """
    cache_dir = cache_dir or cache_dir_from_env()
    if not cache_dir:
        return {"cache_dir": None, "compile_cache": False, "lanes": 0}
    os.makedirs(cache_dir, exist_ok=True)
    ok = enable_compilation_cache(cache_dir)
    lanes = load_lane_snapshot(cache_dir)
    return {"cache_dir": cache_dir, "compile_cache": ok, "lanes": lanes}


def save_warm_start(cache_dir: str | None = None) -> int:
    """Persist the lane LRU under ``cache_dir`` (or env knob); returns
    entries written, or -1 when no directory is configured (no-op)."""
    cache_dir = cache_dir or cache_dir_from_env()
    if not cache_dir:
        return -1
    try:
        return save_lane_snapshot(cache_dir)
    except Exception as e:  # noqa: BLE001 - persistence is advisory
        # A failed save must never take the serve epilogue down with
        # it; the previous snapshot (if any) is still in place.
        faults.record_event("warmstart", "fault",
                            f"snapshot save failed: "
                            f"{type(e).__name__}: {e}")
        return -1
