"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax use.

Single pod: (16, 16) = 256 chips, axes (data, model) — TPU v5e pod slice.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
composes with data for batch sharding, so only the gradient all-reduce
(training) crosses the inter-pod links — the deployment-standard layout.
"""
from __future__ import annotations

import jax


# §Perf knob: alternate factorization of the same chips, e.g. (64, 4)
# for small-model training where 16-way TP over-pays in activation
# all-reduces.  None = the assignment's production shapes.
MESH_OVERRIDE = None


def make_production_mesh(*, multi_pod: bool = False):
    if MESH_OVERRIDE is not None and not multi_pod:
        return jax.make_mesh(MESH_OVERRIDE, ("data", "model"))
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-sharding axes of a mesh (pod composes with data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh(model: int = 1):
    """Tiny mesh over the locally visible devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_lane_mesh(n: int | None = None):
    """1-D ``lanes`` mesh for the engine's shard_map lane execution.

    This is the mesh the simulation-fleet axis shards over (see
    ``engine.configure_lane_mesh``) — orthogonal to the model meshes
    above, which shard *workload* tensors.  ``n=None`` takes every
    visible device; on a CPU host force the device count first
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    from repro.core.engine import build_lane_mesh

    return build_lane_mesh(len(jax.devices()) if n is None else n)
