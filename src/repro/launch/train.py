"""Training launcher.

Runs any assigned architecture (full or ``--smoke`` reduced config) with
the production training loop: sharded params, microbatched gradient
accumulation, optional gradient compression, async checkpointing, and the
fault-tolerance control plane (heartbeats + elastic re-mesh drill with
``--simulate-failure``).

On this CPU container the mesh is the locally visible device set; on a
real pod the same script runs under the 16x16 / 2x16x16 meshes of
launch/mesh.py (see launch/dryrun.py for the compile-level proof).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.training.fault import HeartbeatMonitor, elastic_plan
from repro.training.grad_compress import CompressionConfig
from repro.training.trainer import TrainConfig, Trainer
from repro.training import checkpoint as CKPT


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk", "int8+topk"])
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--simulate-failure", action="store_true",
                    help="drill: drop a host mid-run, re-plan, restore")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent XLA compile-cache directory (skips "
                         "step-function recompilation across runs); also "
                         "via REPRO_CACHE_DIR")
    args = ap.parse_args()

    from repro.core import warmstart
    warm = warmstart.enable_warm_start(args.cache_dir)
    if warm["cache_dir"]:
        print(f"warm start: cache-dir {warm['cache_dir']} "
              f"(compile cache {'on' if warm['compile_cache'] else 'off'})",
              flush=True)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{cfg.name} trains on stub embeddings; use the "
                         "dry-run for its full-shape training cells")

    tcfg = TrainConfig(lr=args.lr, warmup=max(args.steps // 10, 5),
                       total_steps=args.steps,
                       microbatches=args.microbatches,
                       compression=CompressionConfig(args.compression),
                       ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, remat=not args.smoke)
    trainer = Trainer(cfg, tcfg)
    if args.restore and trainer.restore_latest():
        print(f"restored step {trainer.step} from {args.ckpt_dir}")

    src = SyntheticLM(cfg.vocab, seed=0)

    def batches():
        step = trainer.step
        while True:
            yield {k: jnp.asarray(v)
                   for k, v in src.batch(step, args.batch,
                                         args.seq).items()}
            step += 1

    if args.simulate_failure:
        half = args.steps // 2
        trainer.train(batches(), steps=half)
        trainer.ckpt.save(trainer.step, (trainer.params, trainer.opt))
        trainer.ckpt.wait()
        print("== simulating host failure ==")
        mon = HeartbeatMonitor(4, timeout_s=1.0, clock=lambda: 100.0)
        mon.hosts[2].last_beat = 0.0
        dead = mon.sweep()
        plan = elastic_plan(mon.alive_hosts, devices_per_host=1,
                            model_parallel=1,
                            global_batch=args.batch,
                            latest_ckpt=CKPT.latest_step(args.ckpt_dir))
        print(f"dead hosts {dead}; survivor plan: dp={plan.data_parallel}"
              f" batch-={plan.drop_batch} restore@{plan.restore_step}")
        # elastic restart: fresh trainer, restore, continue
        trainer = Trainer(cfg, tcfg)
        assert trainer.restore_latest()
        print(f"restored at step {trainer.step}; continuing")
        trainer.train(batches(), steps=args.steps - half)
    else:
        trainer.train(batches(), steps=args.steps)

    final = trainer.history[-1]["loss"] if trainer.history else float("nan")
    print(f"done: step={trainer.step} final_loss={final:.4f}")


if __name__ == "__main__":
    main()
