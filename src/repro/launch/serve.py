"""Serving launcher: continuous-batching decode + PIM offload telemetry.

The paper's kind is inference (LP5X-PIM accelerates decode GEMV), so this
is the primary end-to-end driver: it serves a model with batched
requests and reports, per decode step, what the LP5X-PIM offload would
deliver on the reference LPDDR5X-9600 x 4ch memory system.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.pimsim import PimSimulator
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import OffloadPlanner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fence", action="store_true", default=True)
    args = ap.parse_args()

    full_cfg = ARCHS[args.arch]
    cfg = smoke_config(full_cfg) if args.smoke else full_cfg
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{cfg.name} serves stub embeddings; "
                         "see launch/dryrun.py for its decode cells")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # Offload plan computed against the FULL architecture (the simulator
    # works on real matrix sizes regardless of the smoke model we run).
    planner = OffloadPlanner(full_cfg, PimSimulator())
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=128,
                        planner=planner)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab,
                                               size=4 + i % 8),
                           max_new=args.max_new))
    t0 = time.perf_counter()
    stats = eng.run(max_steps=2000)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests: {stats['tokens']} tokens in "
          f"{stats['steps']} steps ({dt:.2f}s host wall)")
    tel = stats["pim_telemetry"]
    print(f"PIM offload telemetry (arch={full_cfg.name}, "
          f"batch={tel['batch']}):")
    print(f"  decode GEMV time host-only : {tel['host_ns']/1e3:10.1f} us")
    print(f"  with LP5X-PIM offload      : {tel['mixed_ns']/1e3:10.1f} us")
    print(f"  speedup {tel['speedup']:.2f}x; offloaded "
          f"{len(tel['offloaded'])}/{tel['n_sites']} GEMV sites")


if __name__ == "__main__":
    main()
