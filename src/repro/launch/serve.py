"""Serving launcher: continuous-batching decode + PIM offload telemetry.

The paper's kind is inference (LP5X-PIM accelerates decode GEMV), so this
is the primary end-to-end driver: it serves a model with batched
requests and reports, per decode step, what the LP5X-PIM offload would
deliver on the reference LPDDR5X-9600 x 4ch memory system.

With ``--scenario`` the launcher becomes the closed-loop policy testbed:
a seeded workload (steady / bursty / diurnal / prefill-heavy /
drain-refill) drives the engine end to end under an adaptive offload
controller (``--policy per-step|hysteresis|sticky``) and the run reports
realized vs oracle speedup, decision switches and planner queries.

``--disagg`` serves through the disaggregated prefill/decode cell pair
(``serving/cells.py``) instead of the monolithic engine — optionally
bounded (``--prefill-budget`` / ``--handoff-bound`` /
``--admission-capacity``) and SLO-mixed (``--slo FRAC`` = latency-class
fraction, the rest throughput class with ``--starvation-age`` aging) —
and reports the handoff-queue and per-class telemetry on top of the
offload report.

``--daemon`` serves the scenario through the long-running
:class:`~repro.serving.daemon.ServeDaemon` (always the disaggregated
cell pair): asynchronous ingestion, drain/shutdown accounting, optional
SLO-driven decode autoscaling (``--autoscale``, bounded below by
``--min-slots``), an optional completion cap (``--max-requests``), and
streaming trace export (``--trace-out FILE`` writes tick-ordered JSONL
chunks in bounded memory; ``TraceWriter.load`` reassembles a trace
byte-identical to the in-memory path).

``--chaos`` runs the scenario under a seeded fault timeline
(``serving/chaos.py``, seed via ``--faults``): injected backend
failures, lane-cache poison/eviction storms, planner timeouts and
handoff pressure, absorbed by the degradation ladder.  The run must
complete with zero unhandled exceptions — results stay byte-exact by
the backend contract — and the report includes the incident record.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core import engine as lane_engine
from repro.core import warmstart
from repro.core.pimsim import PimSimulator
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.offload import OffloadPlanner
from repro.serving.policy import POLICIES, resolve_policy
from repro.serving.scenarios import (SCENARIOS, DisaggConfig,
                                     SpecDecodeConfig, assign_slo,
                                     make_scenario, resolve_scenario,
                                     run_scenario)


def _disagg_config(args) -> "DisaggConfig | bool":
    """The cell-pair config from the CLI knobs (False when not asked)."""
    if not args.disagg:
        if args.slo is not None:
            raise SystemExit("--slo requires --disagg (SLO classes are "
                             "a property of the cell pair's admission)")
        return False
    return DisaggConfig(prefill_budget=args.prefill_budget,
                        handoff_bound=args.handoff_bound,
                        starvation_age=args.starvation_age,
                        admission_capacity=args.admission_capacity)


def _print_disagg_report(rec: dict) -> None:
    hand = rec["handoff"]
    bound = hand["bound"] if hand["bound"] is not None else "unbounded"
    print(f"  KV handoff queue     : {hand['handoffs']} handoffs, peak "
          f"depth {hand['max_depth']} (bound {bound})")
    for cls, per in rec["per_class"].items():
        print(f"  SLO {cls:<11}      : {per['completed']}/"
              f"{per['submitted']} done, mean admit wait "
              f"{per['mean_admit_wait']:.2f} ticks, mean latency "
              f"{per['mean_completion_ticks']:.2f} ticks")


def run_scenario_mode(args, full_cfg, cfg, params, mesh=None,
                      t_start: float | None = None) -> None:
    planner = OffloadPlanner(full_cfg, PimSimulator())
    # Time-to-first-batch: main() entry through the first offload plan —
    # the window that contains every cold-start cost (XLA compiles, lane
    # resolves).  Parseable row; benchmarks/coldstart_smoke.py asserts a
    # warm process improves it.
    planner.plan(fence=args.fence)
    if t_start is not None:
        ttfb = time.perf_counter() - t_start
        print(f"serve/time_to_first_batch,{ttfb:.3f}", flush=True)
    spec = make_scenario(args.scenario, seed=args.seed, slots=args.slots,
                         quick=args.quick)
    disagg = _disagg_config(args)
    slo = (assign_slo(spec, frac_latency=args.slo)
           if args.slo is not None else None)
    spec_decode = (SpecDecodeConfig(draft_len=args.draft_len,
                                    acceptance=args.acceptance,
                                    seed=args.seed)
                   if args.scenario == "spec-decode" else None)
    t0 = time.perf_counter()
    if args.chaos:
        from repro.serving.chaos import run_chaos_scenario
        trace = run_chaos_scenario(cfg, params, planner, scenario=spec,
                                   seed=args.faults, policy=args.policy,
                                   fence=args.fence, mesh=mesh,
                                   disagg=disagg, slo=slo,
                                   spec_decode=spec_decode)
    else:
        trace = run_scenario(spec, cfg, params, planner,
                             policy=args.policy, fence=args.fence,
                             mesh=mesh, disagg=disagg, slo=slo,
                             spec_decode=spec_decode)
    dt = time.perf_counter() - t0
    rep = trace["controller"]
    mode = "disagg cells" if disagg else "monolithic engine"
    print(f"scenario {args.scenario} (seed={args.seed}, "
          f"{len(spec.arrivals)} requests, {args.slots} slots, {mode}) "
          f"under policy {args.policy}: {trace['tokens']} tokens in "
          f"{trace['steps']} steps ({dt:.2f}s host wall)")
    occ = ", ".join(f"{b}:{c}" for b, c in trace["occupancy"].items())
    print(f"  batch occupancy      : {occ}")
    print(f"  realized speedup     : {rep['realized_speedup']:.3f}x "
          f"(oracle {rep['oracle_speedup']:.3f}x, "
          f"efficiency {rep['efficiency']:.3f})")
    print(f"  decision switches    : {rep['switches']}; planner queries "
          f"{rep['planner_queries']}/{rep['steps']} steps; "
          f"replans {rep['replans']}")
    if disagg:
        _print_disagg_report(trace["disagg"])
    if "spec_decode" in trace:
        _print_spec_decode_report(trace["spec_decode"], planner, args)
    if args.chaos:
        _print_chaos_report(trace["chaos"])


def _print_spec_decode_report(rec: dict, planner, args) -> None:
    """Draft/verify accounting + a parseable ``serve/spec_decode`` row
    the CI job greps."""
    drafted = rec["drafted"]
    rate = rec["accepted"] / drafted if drafted else 0.0
    model = planner.spec_decode_speedup(draft_len=args.draft_len,
                                        acceptance=args.acceptance,
                                        fence=args.fence)
    print(f"  speculative decode   : {rec['rounds']} rounds, "
          f"{rec['accepted']}/{drafted} drafts accepted "
          f"({rate:.2f}), {rec['wasted']} wasted, "
          f"{rec['substeps']} verify sub-steps")
    print(f"  draft-lane model     : {model['speedup']:.3f}x per-token vs "
          f"vanilla decode ({model['tokens_per_round']:.2f} tok/round)")
    print(f"serve/spec_decode,rounds={rec['rounds']},"
          f"drafted={drafted},accepted={rec['accepted']},"
          f"wasted={rec['wasted']},substeps={rec['substeps']}", flush=True)


def _print_chaos_report(rec: dict) -> None:
    """Human summary + a parseable ``serve/chaos`` row the CI job greps
    (the run reaching this line at all means zero unhandled
    exceptions)."""
    by_kind: dict[str, int] = {}
    for ev in rec["events"]:
        by_kind[ev["kind"]] = by_kind.get(ev["kind"], 0) + 1
    kinds = ", ".join(f"{k}:{n}" for k, n in sorted(by_kind.items()))
    tripped = ",".join(rec["breaker"]["open"]) or "none"
    print(f"  chaos (faults seed {rec['seed']}): {rec['injected']} "
          f"injected over {len(rec['timeline'])} timeline actions")
    print(f"  incident events      : {kinds or 'none'}")
    print(f"  breaker              : threshold "
          f"{rec['breaker']['threshold']}, tripped {tripped}")
    print(f"serve/chaos,injected={rec['injected']},"
          f"events={len(rec['events'])},"
          f"degrades={by_kind.get('degrade', 0)},"
          f"trips={by_kind.get('trip', 0)},"
          f"sheds={by_kind.get('shed', 0)},unhandled=0", flush=True)


def run_daemon_mode(args, full_cfg, cfg, params, mesh=None) -> None:
    """Serve the scenario through :class:`ServeDaemon` and print the
    operational report (parseable ``serve/daemon`` row, ``unhandled=0``
    on a clean run — same convention as the chaos smoke)."""
    from repro.serving.daemon import ServeDaemon, TraceWriter
    from repro.serving.scenarios import AutoscaleConfig

    planner = OffloadPlanner(full_cfg, PimSimulator())
    planner.plan(fence=args.fence)
    spec = make_scenario(args.scenario, seed=args.seed, slots=args.slots,
                         quick=args.quick)
    dcfg = _disagg_config(args)
    slo = (assign_slo(spec, frac_latency=args.slo)
           if args.slo is not None else None)
    auto = (AutoscaleConfig(min_slots=args.min_slots)
            if args.autoscale else None)
    writer = (TraceWriter(args.trace_out)
              if args.trace_out is not None else None)
    t0 = time.perf_counter()
    with lane_engine.lane_mesh_scope(mesh):
        daemon = ServeDaemon(
            cfg, params, planner, scenario=spec, policy=args.policy,
            fence=args.fence,
            disagg=(dcfg if isinstance(dcfg, DisaggConfig) else None),
            slo=slo, autoscale=auto, max_requests=args.max_requests,
            writer=writer)
        rep = daemon.run()
    dt = time.perf_counter() - t0
    acct = rep["accounting"]
    print(f"daemon scenario {args.scenario} (seed={args.seed}, "
          f"{len(spec.arrivals)} requests, {args.slots} slots): "
          f"{acct['completed']} completed / {acct['shed']} shed / "
          f"{acct['dropped']} dropped in {rep['ticks']} ticks "
          f"({dt:.2f}s host wall)")
    if auto is not None:
        asr = rep["autoscale"]
        lims = asr["limits"] or [0]
        print(f"  autoscale            : limit {min(lims)}..{max(lims)} "
              f"over {len(lims)} ticks ({asr['grows']} grows, "
              f"{asr['shrinks']} shrinks, "
              f"{asr['slot_ticks']} slot-ticks provisioned)")
    if writer is not None:
        print(f"  streamed trace       : {writer.records} records in "
              f"{writer.flushes} chunks -> {args.trace_out}")
    print(f"serve/daemon,ingested={acct['ingested']},"
          f"completed={acct['completed']},shed={acct['shed']},"
          f"dropped={acct['dropped']},in_flight={acct['in_flight']},"
          f"ticks={rep['ticks']},unhandled=0", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fence", action="store_true", default=True)
    ap.add_argument("--scenario", default=None,
                    help="drive a seeded workload scenario end to end "
                         "under an adaptive offload controller "
                         f"(one of {sorted(SCENARIOS)}; underscores ok)")
    ap.add_argument("--policy", default="per-step",
                    help="offload control policy for --scenario runs "
                         f"(one of {sorted(POLICIES)}; underscores ok)")
    ap.add_argument("--draft-len", type=int, default=4, metavar="L",
                    help="with --scenario spec-decode: speculative draft "
                         "length per round")
    ap.add_argument("--acceptance", type=float, default=0.7, metavar="P",
                    help="with --scenario spec-decode: per-token draft "
                         "acceptance probability (seeded model)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="smaller scenario (CI smoke)")
    ap.add_argument("--disagg", action="store_true",
                    help="serve through the disaggregated prefill/decode "
                         "cell pair (serving/cells.py) instead of the "
                         "monolithic engine")
    ap.add_argument("--slo", type=float, default=None, metavar="FRAC",
                    help="with --disagg: fraction of requests in the "
                         "latency SLO class (rest are throughput class)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    metavar="N", help="with --disagg: max prefills per "
                    "tick (default unbounded)")
    ap.add_argument("--handoff-bound", type=int, default=None,
                    metavar="N", help="with --disagg: KV-handoff queue "
                    "bound (default unbounded)")
    ap.add_argument("--starvation-age", type=int, default=8, metavar="N",
                    help="with --disagg: ticks after which a waiting "
                    "throughput-class request outranks latency traffic")
    ap.add_argument("--admission-capacity", type=int, default=None,
                    metavar="N", help="with --disagg: admission-queue "
                    "capacity; arrivals over it shed the lowest SLO "
                    "class first (default unbounded, never sheds)")
    ap.add_argument("--daemon", action="store_true",
                    help="serve --scenario through the long-running "
                         "ServeDaemon (serving/daemon.py): async "
                         "ingestion, drain accounting, autoscaling and "
                         "streamed traces; implies --disagg")
    ap.add_argument("--max-requests", type=int, default=None, metavar="N",
                    help="with --daemon: auto-drain after N completed "
                         "requests (default: serve the whole scenario)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="with --daemon: stream the trace to FILE as "
                         "tick-ordered JSONL chunks (bounded memory) "
                         "instead of holding it in RAM")
    ap.add_argument("--autoscale", action="store_true",
                    help="with --daemon: grow/shrink the decode cell's "
                         "admission limit against per-class SLO wait "
                         "telemetry (AutoscaleConfig rule)")
    ap.add_argument("--min-slots", type=int, default=1, metavar="N",
                    help="with --autoscale: the admission-limit floor "
                         "(ceiling is the scenario's slot capacity)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the scenario under a seeded fault "
                         "timeline (serving/chaos.py); implies "
                         "--scenario chaos unless one is given")
    ap.add_argument("--faults", type=int, default=0, metavar="SEED",
                    help="with --chaos: fault-timeline seed (same seed, "
                         "same faults at the same ticks)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="run the PIM lane resolution as one shard_map "
                         "program over an N-device 'lanes' mesh (needs N "
                         "visible devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N); "
                         "default: threaded multi-device dispatch")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent warm-start directory (XLA compile "
                         "cache + resolved-lane snapshot); also via "
                         "REPRO_CACHE_DIR")
    ap.add_argument("--lane-backend", default=None,
                    choices=["scan", "pallas", "auto"],
                    help="lane resolver backend (default: "
                         "REPRO_LANE_BACKEND env or scan); pallas/auto "
                         "fall back to scan when unsupported")
    args = ap.parse_args()
    if args.chaos and not args.scenario:
        args.scenario = "chaos"
    if args.daemon:
        if not args.scenario:
            ap.error("--daemon needs --scenario (the arrival process)")
        if args.chaos:
            ap.error("--daemon and --chaos are separate drivers; drive "
                     "chaos timelines through ServeDaemon's on_tick hook")
        args.disagg = True          # the daemon IS the cell pair
    for flag, name in ((args.max_requests, "--max-requests"),
                       (args.trace_out, "--trace-out")):
        if flag is not None and not args.daemon:
            ap.error(f"{name} requires --daemon")
    if args.autoscale and not args.daemon:
        ap.error("--autoscale requires --daemon")
    # Registry-backed validation instead of a frozen argparse ``choices``
    # list: underscore aliases resolve (``spec_decode`` works) and
    # unknown names fail with the full menu.
    try:
        if args.scenario:
            args.scenario = resolve_scenario(args.scenario)
        args.policy = resolve_policy(args.policy)
    except ValueError as e:
        ap.error(str(e))

    t_start = time.perf_counter()
    lane_engine.configure_lane_backend(args.lane_backend)
    warm = warmstart.enable_warm_start(args.cache_dir)
    if warm["cache_dir"]:
        print(f"warm start: cache-dir {warm['cache_dir']} "
              f"(compile cache {'on' if warm['compile_cache'] else 'off'}, "
              f"{warm['lanes']} lanes loaded)", flush=True)

    full_cfg = ARCHS[args.arch]
    cfg = smoke_config(full_cfg) if args.smoke else full_cfg
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{cfg.name} serves stub embeddings; "
                         "see launch/dryrun.py for its decode cells")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_lane_mesh
        mesh = make_lane_mesh(args.mesh)
        print(f"lane mesh: shard_map over {args.mesh} device(s)")

    if args.daemon:
        run_daemon_mode(args, full_cfg, cfg, params, mesh=mesh)
        _warm_epilogue(args)
        return

    if args.scenario:
        run_scenario_mode(args, full_cfg, cfg, params, mesh=mesh,
                          t_start=t_start)
        _warm_epilogue(args)
        return

    # Offload plan computed against the FULL architecture (the simulator
    # works on real matrix sizes regardless of the smoke model we run).
    lane_engine.configure_lane_mesh(mesh)
    planner = OffloadPlanner(full_cfg, PimSimulator())
    disagg = _disagg_config(args)
    if disagg:
        from repro.serving.cells import DisaggServingEngine
        from repro.serving.scenarios import SLO_LATENCY, SLO_THROUGHPUT
        eng = DisaggServingEngine(cfg, params, slots=args.slots,
                                  max_seq=128, disagg=disagg,
                                  planner=planner)
    else:
        eng = ServingEngine(cfg, params, slots=args.slots, max_seq=128,
                            planner=planner)
    rng = np.random.default_rng(0)
    frac = 1.0 if args.slo is None else args.slo
    for i in range(args.requests):
        req = Request(rid=i,
                      prompt=rng.integers(0, cfg.vocab, size=4 + i % 8),
                      max_new=args.max_new)
        if disagg:
            eng.submit(req, slo=(SLO_LATENCY if rng.random() < frac
                                 else SLO_THROUGHPUT))
        else:
            eng.submit(req)
    t0 = time.perf_counter()
    stats = eng.run(max_steps=2000)
    dt = time.perf_counter() - t0
    mode = "disagg cells" if disagg else "monolithic engine"
    print(f"served {args.requests} requests ({mode}): {stats['tokens']} "
          f"tokens in {stats['steps']} steps ({dt:.2f}s host wall)")
    if disagg:
        _print_disagg_report(stats["disagg"])
    tel = stats["pim_telemetry"]
    print(f"PIM offload telemetry (arch={full_cfg.name}, "
          f"batch={tel['batch']}):")
    print(f"  decode GEMV time host-only : {tel['host_ns']/1e3:10.1f} us")
    print(f"  with LP5X-PIM offload      : {tel['mixed_ns']/1e3:10.1f} us")
    print(f"  speedup {tel['speedup']:.2f}x; offloaded "
          f"{len(tel['offloaded'])}/{tel['n_sites']} GEMV sites")
    _warm_epilogue(args)


def _warm_epilogue(args) -> None:
    """Parseable lane-cache counters + snapshot save (no-op without a
    cache dir) — the cold-start smoke asserts against these rows."""
    info = lane_engine.lane_cache_info()
    print(f"serve/lane_cache,hits={info['hits']},misses={info['misses']},"
          f"size={info['size']}", flush=True)
    saved = warmstart.save_warm_start(args.cache_dir)
    if saved >= 0:
        print(f"warm start: saved {saved} lanes", flush=True)


if __name__ == "__main__":
    main()
