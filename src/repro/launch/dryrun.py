import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first initialization, and the production meshes need 512 host
placeholder devices (2 pods x 16 x 16).

Per cell this script:
  1. builds the step function (train_step / prefill / decode_step),
  2. lowers it under the production mesh with the sharding rules of
     `distribution.sharding` (ShapeDtypeStruct inputs — no allocation),
  3. compiles, records memory_analysis / cost_analysis / collective bytes,
  4. writes experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, shapes_for
from repro.distribution import sharding as SH
from repro.distribution.hlo_analysis import collective_bytes
from repro.distribution.roofline import model_flops
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.training.optimizer import AdamWState, adamw_init, adamw_update

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / \
    "dryrun"


def apply_variant(name: str):
    """§Perf hillclimb variants: flip one knob, re-lower, re-analyse."""
    from repro.distribution import roofline as RLmod
    from repro.distribution import sharding as SHmod
    from repro.models import layers as LAY
    from repro.models import moe as MOEmod
    from repro.launch import mesh as MESHmod
    SHmod.SERVE_TP_ONLY = False
    M.REMAT_POLICY = "full"
    M.CE_CHUNKS = 0
    M.QUANT_BITS = 0
    M.KV_QUANT = False
    MESHmod.MESH_OVERRIDE = None
    MOEmod.DISPATCH_SPEC = None
    LAY.FLASH_SKIP_BLOCKS = False
    RLmod.FLASH_SKIP_BLOCKS = False
    if name == "baseline":
        return
    if name == "serve-tp":
        SHmod.SERVE_TP_ONLY = True
    elif name == "serve-tp-w8":
        SHmod.SERVE_TP_ONLY = True
        M.QUANT_BITS = 8
    elif name == "serve-tp-w4":
        SHmod.SERVE_TP_ONLY = True
        M.QUANT_BITS = 4
    elif name == "serve-tp-w4-kv8":
        SHmod.SERVE_TP_ONLY = True
        M.QUANT_BITS = 4
        M.KV_QUANT = True
    elif name == "remat-dots":
        M.REMAT_POLICY = "dots"
    elif name == "remat-none":
        M.REMAT_POLICY = "none"
    elif name == "chunked-ce":
        M.CE_CHUNKS = 8
    elif name == "chunked-ce+dots":
        M.CE_CHUNKS = 8
        M.REMAT_POLICY = "dots"
    elif name == "moe-shard":
        from repro.models import moe as MOEmod
        MOEmod.DISPATCH_SPEC = ("data", None)
    elif name == "tp-save":
        M.REMAT_POLICY = "tp-save"
    elif name == "mesh-64x4":
        from repro.launch import mesh as MESHmod
        MESHmod.MESH_OVERRIDE = (64, 4)
    elif name == "moe-shard+save":
        from repro.models import moe as MOEmod
        MOEmod.DISPATCH_SPEC = ("data", None)
        M.REMAT_POLICY = "moe-save"
    elif name == "flash-skip":
        from repro.distribution import roofline as RLmod
        LAY.FLASH_SKIP_BLOCKS = True
        RLmod.FLASH_SKIP_BLOCKS = True
    elif name == "flash-skip+ce":
        from repro.distribution import roofline as RLmod
        LAY.FLASH_SKIP_BLOCKS = True
        RLmod.FLASH_SKIP_BLOCKS = True
        M.CE_CHUNKS = 8
    else:
        raise ValueError(f"unknown variant {name}")


def build_step(cfg, shape):
    """Returns (fn, arg_specs, arg_shardings) for the cell."""
    if shape.kind == "train":
        def train_step(params, opt, batch):
            def lf(p):
                return M.loss_fn(cfg, p, batch)[0]
            loss, grads = jax.value_and_grad(lf)(params)
            params, opt = adamw_update(params, grads, opt, lr=3e-4)
            return params, opt, loss
        return train_step, "train"
    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return M.prefill(cfg, params, batch, cache)
        return prefill_step, "prefill"

    def decode(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)
    return decode, "decode"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override=None):
    cfg = cfg_override or ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    pdtype = jnp.bfloat16
    pspecs = M.param_specs(cfg, pdtype)
    pshard = SH.param_shardings(cfg, mesh, kind=shape.kind)
    ispecs = M.input_specs(cfg, shape, pdtype)
    ishard = SH.input_shardings(cfg, mesh, shape)
    fn, kind = build_step(cfg, shape)

    with mesh:
        if kind == "train":
            opt_specs = jax.eval_shape(adamw_init, pspecs)
            opt_shard = AdamWState(
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                m=pshard, v=pshard)
            jfn = jax.jit(fn, in_shardings=(pshard, opt_shard,
                                            ishard["batch"]),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(pspecs, opt_specs, ispecs["batch"])
        elif kind == "prefill":
            jfn = jax.jit(fn, in_shardings=(pshard, ishard["batch"],
                                            ishard["cache"]),
                          donate_argnums=(2,))
            lowered = jfn.lower(pspecs, ispecs["batch"], ispecs["cache"])
        else:
            jfn = jax.jit(fn, in_shardings=(pshard, ishard["cache"],
                                            ishard["token"], ishard["pos"]),
                          donate_argnums=(1,))
            lowered = jfn.lower(pspecs, ispecs["cache"], ispecs["token"],
                                ispecs["pos"])
    return lowered, mesh, cfg, shape


def cost_extrapolate(arch: str, shape_name: str, multi_pod: bool) -> dict:
    """Exact per-device FLOPs/bytes from compiled *unrolled* small-L
    variants: total(L) = base + L x per_layer.

    XLA's cost_analysis counts a while body once regardless of trip
    count, so the full-L scanned compile cannot report total cost; two
    fully-unrolled variants (nl, 2nl layers; nl a multiple of the
    local:global period) recover base and per-layer exactly.
    """
    cfg = ARCHS[arch]
    # Layer kinds (local/global) only affect attention, which is added
    # analytically — 2/4-layer probes capture the matmul terms exactly.
    nl_a = 2
    nl_b = 4
    vals = {}
    M.UNROLL_SCAN = True
    try:
        for nl in (nl_a, nl_b):
            cfg2 = dataclasses.replace(cfg, n_layers=nl)
            lowered, mesh, _, _ = lower_cell(arch, shape_name, multi_pod,
                                             cfg_override=cfg2)
            cost = lowered.compile().cost_analysis()
            vals[nl] = (float(cost.get("flops", 0.0)),
                        float(cost.get("bytes accessed", 0.0)))
    finally:
        M.UNROLL_SCAN = False
    fa, ba = vals[nl_a]
    fb, bb = vals[nl_b]
    per_layer_f = (fb - fa) / (nl_b - nl_a)
    per_layer_b = (bb - ba) / (nl_b - nl_a)
    flops_dev = fa - nl_a * per_layer_f + cfg.n_layers * per_layer_f
    bytes_dev = ba - nl_a * per_layer_b + cfg.n_layers * per_layer_b
    # Blockwise-attention inner scans are counted once by cost_analysis;
    # add the white-box executed-block account (see roofline module —
    # this also makes block-skipping optimizations measurable).
    from repro.distribution.roofline import attention_hlo_flops
    shape = SHAPES[shape_name]
    mesh_chips = 512 if multi_pod else 256
    attn = attention_hlo_flops(cfg, shape)
    return dict(
        flops_dev=flops_dev + attn["added_global"] / mesh_chips,
        bytes_dev=bytes_dev,
        matmul_flops_dev=flops_dev,
        attn_flops_global=attn["total_global"],
        attn_counted_once_global=attn["counted_once_global"],
        probe_layers=[nl_a, nl_b],
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, extrapolate: bool = True,
             variant: str = "baseline") -> dict:
    mesh_name = "pod2" if multi_pod else "pod1"
    apply_variant(variant)
    t0 = time.perf_counter()
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_name,
               variant=variant, status="ok")
    try:
        lowered, mesh, cfg, shape = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo, scan_trip_count=cfg.n_layers)
        chips = mesh.size
        rec.update(
            chips=chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collective=coll,
            model_flops=model_flops(cfg, shape),
            n_layers=cfg.n_layers,
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
                output_bytes=getattr(mem, "output_size_in_bytes", 0),
                temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
                peak_bytes=getattr(mem, "peak_memory_in_bytes", 0),
            ),
            hlo_collective_ops={
                k: v for k, v in coll.items() if k != "total"},
        )
        # per-device view (the dry-run proves it fits)
        rec["per_device_arg_gib"] = rec["memory"]["argument_bytes"] / \
            chips / 2**30
        if extrapolate:
            rec["extrap"] = cost_extrapolate(arch, shape_name, multi_pod)
            rec["extrap"]["variant"] = variant
    except Exception as e:  # noqa: BLE001 - record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.perf_counter() - t0, 1)
    apply_variant("baseline")
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        path = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        path.write_text(json.dumps(rec, indent=1))
    return rec


def pim_offload_report(arch: str, batches=(1, 2, 4, 8, 16),
                       scenario: str | None = None,
                       policy: str = "per-step",
                       disagg: bool = False) -> dict:
    """Decode-phase PIM offload telemetry across a hardware-variant grid.

    One ``OffloadPlanner.plan_grid`` call — i.e. a single batched engine
    dispatch — covers every (spec variant x GEMV site) point of this
    model; per variant we record the plan and the end-to-end decode-step
    speedup curve over batch sizes.  With ``scenario`` the report also
    runs the adaptive offload controller closed-loop over that
    scenario's simulated occupancy trace (no model involved) and records
    realized-vs-oracle policy telemetry; the ``spec-decode`` scenario
    instead drives the loop from ``simulate_spec_decode``'s occupancy
    (acceptance-dependent slot dynamics) and records the draft/verify
    accounting.  With ``disagg`` the closed
    loop instead runs over the disaggregated cell pair's decode
    occupancy (``simulate_disagg`` — bounded prefill/handoff, SLO-mixed
    admission, still model-free) and the record gains the handoff/SLO
    scheduling telemetry.  The report always closes with the
    heterogeneous spec-family sweep (``configs/specfam.py``): one
    ``plan_grid`` dispatch over the whole population, then each
    family's offload frontier and speculative-decode economics.
    Writes experiments/dryrun/pim/<arch>.json.
    """
    import dataclasses as _dc

    from repro.configs.specfam import SPEC_FAMILIES
    from repro.core.timing import DEFAULT_SYSTEM, LpddrTimings, PimSpec, \
        SystemSpec
    from repro.serving.offload import OffloadPlanner
    from repro.serving.scenarios import DisaggConfig, SpecDecodeConfig, \
        assign_slo, make_scenario, occupancy_trace, resolve_scenario, \
        run_policy_over_trace, simulate_disagg, simulate_spec_decode

    variants = {
        "lp5x-9600": DEFAULT_SYSTEM,
        "fast-core": SystemSpec(timings=LpddrTimings(tRCD=15.0, tRP=15.0)),
        "mac2": SystemSpec(pim=PimSpec(mac_interval_ck=2)),
        "srf1k": SystemSpec(pim=PimSpec(srf_bytes=1024)),
    }
    planner = OffloadPlanner(ARCHS[arch])
    grid = planner.plan_grid(list(variants.values()))
    rec: dict = dict(arch=arch, variants={})
    for (name, spec), decisions in zip(variants.items(), grid):
        rec["variants"][name] = dict(
            sites=[{**_dc.asdict(d.site), "pim_ns": d.pim_ns,
                    "host_ns": d.host_ns, "reshape": d.reshape,
                    "offload_below_batch": d.offload_below_batch}
                   for d in decisions],
            # str keys: the in-memory record matches its JSON round-trip
            decode_speedup={str(b): planner.decode_speedup(batch=b,
                                                           spec=spec)
                            for b in batches},
        )
    if scenario:
        scenario = resolve_scenario(scenario)
        sc = make_scenario(scenario, seed=0, quick=True)
        if scenario == "spec-decode":
            sd = SpecDecodeConfig()
            sim = simulate_spec_decode(sc, sd)
            occ = [b for b in sim["per_tick_batch"] if b > 0]
            drafted = sum(sim["drafted"].values())
            accepted = sum(sim["accepted"].values())
            rec["spec_decode"] = dict(
                config=sd.to_record(), drafted=drafted, accepted=accepted,
                wasted=drafted - accepted,
                rounds=sum(sim["rounds"].values()),
                model=planner.spec_decode_speedup(
                    draft_len=sd.draft_len, acceptance=sd.acceptance))
        else:
            occ = occupancy_trace(sc)
        controller = run_policy_over_trace(planner, policy, occ)
        rec["serving_policy"] = dict(scenario=scenario, policy=policy,
                                     report=controller.report())
        if disagg:
            # The cell pair's decode occupancy under bounded prefill,
            # a bounded KV-handoff queue and a mixed SLO population —
            # the policy sees what the disagg decode cell would show it.
            dcfg = DisaggConfig(prefill_budget=2, handoff_bound=3,
                                starvation_age=4)
            slo = assign_slo(sc, frac_latency=0.5)
            sim = simulate_disagg(sc, dcfg, slo)
            dec = [b for b in sim["per_tick_batch"] if b > 0]
            dctl = run_policy_over_trace(planner, policy, dec)
            rec["disagg"] = dict(
                scenario=scenario, policy=policy,
                config=dcfg.to_record(),
                slo={str(r): s for r, s in sorted(slo.items())},
                max_handoff_depth=sim["max_handoff_depth"],
                decode_steps=len(dec),
                report=dctl.report())
    # Heterogeneous spec-family sweep: the whole population's decisions
    # come from ONE batched grid dispatch; frontiers and spec-decode
    # economics per family are then cache lookups + arithmetic.
    planner.plan_grid(list(SPEC_FAMILIES.values()))
    rec["spec_families"] = {
        name: dict(frontier=planner.frontier(spec=s),
                   spec_decode=planner.spec_decode_speedup(spec=s))
        for name, s in SPEC_FAMILIES.items()}
    out_dir = OUT_DIR / "pim"
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}.json").write_text(json.dumps(rec, indent=1))
    return rec


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch, cfg in ARCHS.items():
        for shape_name in shapes_for(cfg):
            cells.append((arch, shape_name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1",
                    help="pod1|pod2|both selects the production mesh for "
                         "cell lowering; with --pim an integer N instead "
                         "runs the offload grid's lane resolution as one "
                         "shard_map program over an N-device 'lanes' mesh")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--pim", action="store_true",
                    help="emit decode-phase PIM offload telemetry per arch "
                         "(multi-spec grid, one batched engine query) "
                         "instead of lowering/compiling cells")
    from repro.serving.policy import POLICIES
    from repro.serving.scenarios import SCENARIOS
    ap.add_argument("--scenario", default=None,
                    help="with --pim: also run the adaptive offload "
                         "controller closed-loop over this scenario's "
                         "simulated occupancy trace "
                         f"(one of {sorted(SCENARIOS)}; underscores ok)")
    ap.add_argument("--policy", default="per-step",
                    help="with --pim --scenario: offload control policy "
                         f"(one of {sorted(POLICIES)}; underscores ok)")
    ap.add_argument("--disagg", action="store_true",
                    help="with --pim: run the closed loop over the "
                         "disaggregated cell pair's decode occupancy "
                         "(bounded prefill/handoff, SLO-mixed admission; "
                         "defaults --scenario to bursty)")
    ap.add_argument("--extrap-only", action="store_true",
                    help="recompute the probe extrapolation of existing "
                         "cells (methodology changes) without the full "
                         "compile")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent warm-start directory (XLA compile "
                         "cache + resolved-lane snapshot); also via "
                         "REPRO_CACHE_DIR")
    args = ap.parse_args()
    # Registry-backed validation (underscore aliases resolve; unknown
    # names fail with the full menu) instead of frozen argparse choices.
    from repro.serving.policy import resolve_policy
    from repro.serving.scenarios import resolve_scenario
    try:
        if args.scenario:
            args.scenario = resolve_scenario(args.scenario)
        args.policy = resolve_policy(args.policy)
    except ValueError as e:
        ap.error(str(e))

    from repro.core import warmstart
    warm = warmstart.enable_warm_start(args.cache_dir)
    if warm["cache_dir"]:
        print(f"warm start: cache-dir {warm['cache_dir']} "
              f"(compile cache {'on' if warm['compile_cache'] else 'off'}, "
              f"{warm['lanes']} lanes loaded)", flush=True)

    if args.pim:
        if not args.all and args.arch not in ARCHS:
            ap.error(f"--pim needs --all or --arch from {list(ARCHS)}")
        if args.disagg and args.scenario is None:
            args.scenario = "bursty"
        if args.mesh.isdigit():
            from repro.core import engine as lane_engine
            from repro.launch.mesh import make_lane_mesh
            lane_engine.configure_lane_mesh(make_lane_mesh(int(args.mesh)))
            print(f"[pim] lane mesh: shard_map over {args.mesh} device(s)",
                  flush=True)
        elif args.mesh != "pod1":
            ap.error("--pim takes an integer --mesh N (shard_map lane "
                     "mesh); pod meshes apply to cell lowering only")
        archs = list(ARCHS) if args.all else [args.arch]
        for arch in archs:
            rec = pim_offload_report(arch, scenario=args.scenario,
                                     policy=args.policy,
                                     disagg=args.disagg)
            base = rec["variants"]["lp5x-9600"]["decode_speedup"]["1"]
            print(f"[pim] {arch}: decode b=1 speedup "
                  f"{base['speedup']:.2f}x, "
                  f"{len(base['offloaded'])}/{base['n_sites']} sites",
                  flush=True)
            if "serving_policy" in rec:
                rep = rec["serving_policy"]["report"]
                print(f"[pim] {arch}: {args.scenario} x {args.policy}: "
                      f"realized {rep['realized_speedup']:.2f}x / oracle "
                      f"{rep['oracle_speedup']:.2f}x (eff "
                      f"{rep['efficiency']:.3f}), "
                      f"{rep['planner_queries']} queries over "
                      f"{rep['steps']} steps", flush=True)
            if "spec_decode" in rec:
                sdr = rec["spec_decode"]
                print(f"[pim] {arch}: spec-decode "
                      f"{sdr['accepted']}/{sdr['drafted']} drafts "
                      f"accepted, model "
                      f"{sdr['model']['speedup']:.2f}x/token", flush=True)
            for fam, frec in rec["spec_families"].items():
                n_pim = sum(1 for b in frec["frontier"].values() if b > 1)
                print(f"[pim] {arch}: family {fam}: {n_pim}/"
                      f"{len(frec['frontier'])} sites PIM-favored, "
                      f"spec-decode "
                      f"{frec['spec_decode']['speedup']:.2f}x/token",
                      flush=True)
            if "disagg" in rec:
                drep = rec["disagg"]["report"]
                print(f"[pim] {arch}: disagg cells x {args.policy}: eff "
                      f"{drep['efficiency']:.3f} over "
                      f"{drep['steps']} decode steps, peak handoff "
                      f"depth {rec['disagg']['max_handoff_depth']}",
                      flush=True)
        warmstart.save_warm_start(args.cache_dir)
        sys.exit(0)

    if args.disagg:
        ap.error("--disagg applies to --pim runs only")
    if args.mesh not in ("pod1", "pod2", "both"):
        ap.error("--mesh must be pod1|pod2|both for cell lowering "
                 "(integer lane-mesh sizes apply to --pim only)")
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "pod2" if mp else "pod1"
            out = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("status") == "ok":
                    print(f"[skip] {arch} {shape_name} {mesh_name}")
                    continue
            if args.extrap_only:
                if not out.exists():
                    continue
                rec = json.loads(out.read_text())
                if rec.get("status") != "ok":
                    continue
                apply_variant(args.variant)
                try:
                    rec["extrap"] = cost_extrapolate(arch, shape_name, mp)
                    rec["model_flops"] = model_flops(
                        ARCHS[arch], SHAPES[shape_name])
                    out.write_text(json.dumps(rec, indent=1))
                    print(f"[extrap] {arch} {shape_name} {mesh_name}: "
                          f"{rec['extrap']['flops_dev']:.3e} flops/dev",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"[error] extrap {arch} {shape_name} "
                          f"{mesh_name}: {e}", flush=True)
                apply_variant("baseline")
                continue
            rec = run_cell(arch, shape_name, mp, variant=args.variant)
            ok = rec["status"] == "ok"
            failures += (not ok)
            msg = (f"{rec['flops']:.3e} flops, "
                   f"coll {rec['collective']['total']:.3e} B, "
                   f"compile {rec['compile_s']}s" if ok
                   else rec.get("error", "?"))
            print(f"[{rec['status']}] {arch} {shape_name} {mesh_name}: "
                  f"{msg}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
