"""Logical-axis sharding rules (per arch x shape-kind x mesh).

Parameters carry *logical* axis names (``models.model.param_logical``);
this module maps them to mesh ``PartitionSpec``s with divisibility-checked
greedy assignment (a mesh axis is used at most once per leaf; dims whose
size does not divide the axis fall back to replication).

Policy (DESIGN.md §4):
  * tensor-parallel axes (vocab / heads / kv_heads / mlp / experts) -> "model"
  * FSDP: "embed" -> "data" for archs >= `fsdp_threshold` params, so the
    72B/132B train states fit; small archs replicate over data.
  * batch -> ("pod", "data"); pods are pure DP (only grad all-reduce
    crosses pod links).
  * decode caches: batch -> data when divisible, else sequence -> (data,
    model) (sequence parallelism for long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import data_axes
from repro.models import model as M

FSDP_THRESHOLD = 5_000_000_000

# §Perf hillclimb knob: when True, decode/prefill cells shard params
# TP-only (no FSDP over "data") — weight-stationary serving kills the
# per-step parameter all-gathers at the cost of 16x param memory/chip.
SERVE_TP_ONLY = False


def tp_rules(cfg: ArchConfig, mesh, kind: str = "train") -> dict:
    """logical axis -> mesh axis (or None)."""
    msize = mesh.shape["model"]
    fsdp = cfg.param_count() >= FSDP_THRESHOLD
    if SERVE_TP_ONLY and kind in ("decode", "prefill"):
        fsdp = False
    rules = {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": None,
        "embed": "data" if fsdp else None,
        "embed2": None,
        "ssm_inner": None,
        "ssm_heads": None,
        "layers": None,
    }
    if cfg.moe and cfg.moe.n_experts % msize == 0:
        rules["experts"] = "model"
        rules["mlp"] = None          # expert dim claims the model axis
    return rules


def _leaf_pspec(logical: tuple, shape: tuple, rules: dict, mesh) -> P:
    spec = []
    used = set()
    for name, dim in zip(logical, shape):
        axis = rules.get(name)
        if axis is not None and axis not in used and \
                dim % mesh.shape[axis] == 0:
            spec.append(axis)
            used.add(axis)
        else:
            spec.append(None)
    return P(*spec)


def param_shardings(cfg: ArchConfig, mesh, kind: str = "train"):
    """NamedSharding tree matching ``model.param_specs(cfg)``."""
    from repro.models.quant import quantize_logical
    rules = tp_rules(cfg, mesh, kind)
    logical = M.param_logical(cfg)
    if M.QUANT_BITS:
        logical = quantize_logical(logical)
    specs = M.param_specs(cfg)

    def mk(log, spec):
        return NamedSharding(mesh,
                             _leaf_pspec(tuple(log), spec.shape, rules,
                                         mesh))

    return jax.tree.map(mk, logical, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(s, (str, type(None))) for s in x))


def _batch_dim_axes(mesh, n: int):
    """Sharding for a global-batch dim of size n (prefers pod+data)."""
    dax = data_axes(mesh)
    total = 1
    for a in dax:
        total *= mesh.shape[a]
    if n % total == 0:
        return dax if len(dax) > 1 else dax[0]
    if n % mesh.shape["data"] == 0:
        return "data"
    return None


def cache_shardings(cfg: ArchConfig, mesh, shape: ShapeConfig):
    """Sharding tree for the KV/SSM cache of a decode/prefill cell."""
    b = shape.global_batch
    batch_ax = _batch_dim_axes(mesh, b)

    def kv_spec(leaf_shape):
        # (L, B, S, kv, hd)
        _, _, s, kv, hd = leaf_shape
        used = {a for a in (batch_ax if isinstance(batch_ax, tuple)
                            else (batch_ax,)) if a}
        seq_ax = None
        if batch_ax is None:
            cand = tuple(a for a in ("data", "model"))
            tot = mesh.shape["data"] * mesh.shape["model"]
            if s % tot == 0:
                seq_ax = cand
        elif "model" not in used and s % mesh.shape["model"] == 0:
            seq_ax = "model"
        return P(None, batch_ax, seq_ax, None, None)

    def ssm_spec(leaf_shape):
        # (L, B, nh, p, n)
        _, _, nh, p, n = leaf_shape
        head_ax = "model" if nh % mesh.shape["model"] == 0 else (
            "model" if p % mesh.shape["model"] == 0 else None)
        if nh % mesh.shape["model"] == 0:
            return P(None, batch_ax, "model", None, None)
        if p % mesh.shape["model"] == 0:
            return P(None, batch_ax, None, "model", None)
        return P(None, batch_ax, None, None, None)

    def conv_spec(leaf_shape):
        return P(None, batch_ax, None, None)

    cache_spec = jax.eval_shape(
        lambda: M.init_cache(cfg, b, shape.seq_len, jnp.bfloat16))
    out = {}
    if "kv" in cache_spec:
        out["kv"] = tuple(NamedSharding(mesh, kv_spec(l.shape))
                          for l in cache_spec["kv"])
        if "kv_scale" in cache_spec:
            out["kv_scale"] = tuple(
                NamedSharding(mesh, P(None, batch_ax, None, None, None))
                for _ in cache_spec["kv_scale"])
    if "ssm" in cache_spec:
        out["ssm"] = NamedSharding(mesh, ssm_spec(cache_spec["ssm"].shape))
        out["conv"] = NamedSharding(mesh,
                                    conv_spec(cache_spec["conv"].shape))
    return out


class MeshShape:
    """Axis-size view of a mesh (rule math without device state)."""

    def __init__(self, shape: dict):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


def _shard_bytes(shape, pspec, mesh) -> int:
    n = 1
    for d in shape:
        n *= d
    denom = 1
    for ax in tuple(pspec):
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            denom *= mesh.shape[a]
    return n // denom


def state_bytes_per_device(cfg: ArchConfig, shape: ShapeConfig,
                           mesh=None, with_opt: bool | None = None
                           ) -> dict:
    """Exact per-device byte footprint of params / opt / cache under the
    sharding rules (drives the memory roofline term and fit checks)."""
    import jax.numpy as jnp
    from repro.models import model as M

    from repro.models.quant import quantize_logical
    mesh = mesh or MeshShape({"data": 16, "model": 16})
    rules = tp_rules(cfg, mesh, shape.kind)
    logical = M.param_logical(cfg)
    if M.QUANT_BITS:
        logical = quantize_logical(logical)
    specs = M.param_specs(cfg, jnp.bfloat16)
    is_leaf = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(s, (str, type(None))) for s in x)
    flat_l = jax.tree.leaves(logical, is_leaf=is_leaf)
    flat_s = jax.tree.leaves(specs)
    params = 0
    for log, spec in zip(flat_l, flat_s):
        ps = _leaf_pspec(tuple(log), spec.shape, rules, mesh)
        params += _shard_bytes(spec.shape, ps, mesh) * spec.dtype.itemsize
    out = dict(params=params)
    if with_opt if with_opt is not None else shape.kind == "train":
        out["opt"] = params * 4          # m, v in f32
        out["grads"] = params
    if shape.kind != "train":
        b = shape.global_batch
        cache_specs = jax.eval_shape(
            lambda: M.init_cache(cfg, b, shape.seq_len, jnp.bfloat16))
        batch_ax = _batch_dim_axes(mesh, b)
        cache = 0
        if "kv" in cache_specs:
            for leaf in cache_specs["kv"]:
                denom = 1
                used = {a for a in ((batch_ax,) if not isinstance(
                    batch_ax, tuple) else batch_ax) if a}
                if batch_ax is not None:
                    for a in used:
                        denom *= mesh.shape[a]
                s = leaf.shape[2]
                if batch_ax is None and s % (mesh.shape["data"]
                                             * mesh.shape["model"]) == 0:
                    denom *= mesh.shape["data"] * mesh.shape["model"]
                elif "model" not in used and s % mesh.shape["model"] == 0:
                    denom *= mesh.shape["model"]
                n = 1
                for d in leaf.shape:
                    n *= d
                cache += n * leaf.dtype.itemsize // denom
        for key in ("ssm", "conv"):
            if key in cache_specs:
                leaf = cache_specs[key]
                n = 1
                for d in leaf.shape:
                    n *= d
                denom = mesh.shape["data"] if b % mesh.shape["data"] == 0 \
                    else 1
                itemsize = 4 if key == "ssm" else 2
                cache += n * itemsize // denom
        out["cache"] = cache
    out["total"] = sum(out.values())
    return out


def input_shardings(cfg: ArchConfig, mesh, shape: ShapeConfig) -> dict:
    """Shardings matching ``model.input_specs(cfg, shape)``."""
    b = shape.global_batch
    batch_ax = _batch_dim_axes(mesh, b)
    bspec2 = NamedSharding(mesh, P(batch_ax, None))
    bspec3 = NamedSharding(mesh, P(batch_ax, None, None))
    out: dict = {}
    if shape.kind == "train":
        batch = {}
        if cfg.input_mode == "embeddings":
            batch = {"embeds": bspec3, "labels": bspec2}
        else:
            batch = {"tokens": bspec2, "labels": bspec2}
            if cfg.prefix_patches:
                batch["patches"] = bspec3
        out["batch"] = batch
    elif shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            out["batch"] = {"embeds": bspec3}
        else:
            out["batch"] = {"tokens": bspec2}
            if cfg.prefix_patches:
                out["batch"]["patches"] = bspec3
        out["cache"] = cache_shardings(cfg, mesh, shape)
    else:
        out["token"] = bspec3 if cfg.input_mode == "embeddings" else bspec2
        out["pos"] = NamedSharding(mesh, P())
        out["cache"] = cache_shardings(cfg, mesh, shape)
    return out
