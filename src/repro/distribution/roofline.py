"""Roofline math: TPU v5e hardware model + analytic MODEL_FLOPS.

Terms (per device, seconds):
    compute    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak]
    memory     = HLO_bytes / (chips * 819e9)           [HBM bandwidth]
    collective = collective_bytes / (chips * 50e9)     [ICI per link]

MODEL_FLOPS is the *useful* work: 6·N_active·tokens for training,
2·N_active·tokens (+ attention and SSD terms) for inference — the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


def attention_context(cfg: ArchConfig, s: int) -> float:
    """Mean effective context per layer (sliding windows clamp it)."""
    if cfg.attention_free:
        return 0.0
    import numpy as _np
    kinds = _np.arange(cfg.n_layers)
    if cfg.sliding_window is None or cfg.global_every == 0:
        return float(s) * cfg.n_layers
    is_global = (kinds % cfg.global_every) == cfg.global_every - 1
    ctx = _np.where(is_global, s, min(s, cfg.sliding_window))
    return float(ctx.sum())


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    b, s = shape.global_batch, shape.seq_len
    n_act = cfg.active_param_count()
    hq, hd = cfg.n_heads, cfg.d_head
    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n_act * tokens
        # causal attention fwd+bwd: 12 * B * S * ctx/2 * H * hd
        flops += 12.0 * b * s * attention_context(cfg, s) / 2 * hq * hd
        if cfg.ssm is not None:
            flops += 30.0 * b * s * cfg.n_layers * cfg.n_ssm_heads * \
                cfg.ssm.head_dim * cfg.ssm.state_dim
        return flops
    if shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_act * tokens
        flops += 4.0 * b * s * attention_context(cfg, s) / 2 * hq * hd
        if cfg.ssm is not None:
            flops += 10.0 * b * s * cfg.n_layers * cfg.n_ssm_heads * \
                cfg.ssm.head_dim * cfg.ssm.state_dim
        return flops
    # decode: one token over a seq_len cache
    flops = 2.0 * n_act * b
    flops += 4.0 * b * attention_context(cfg, s) * hq * hd
    if cfg.ssm is not None:
        flops += 10.0 * b * cfg.n_layers * cfg.n_ssm_heads * \
            cfg.ssm.head_dim * cfg.ssm.state_dim
    return flops


FLASH_BLOCK = 512          # layers.flash_attention default block size
FLASH_SKIP_BLOCKS = False  # §Perf knob: causal/window block skipping


def attention_hlo_flops(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """White-box account of the blockwise-attention FLOPs.

    ``cost_analysis`` counts the flash inner scans once; the *executed*
    work is ``n_executed_blocks x per-block``.  Without block skipping
    the implementation computes every (q-block, k-block) pair (masking
    only); with FLASH_SKIP_BLOCKS the causal upper triangle and
    out-of-window blocks are skipped — this function is the measurement
    hook that makes that optimization visible in the roofline.

    Returns global-FLOP figures: total, counted-once (already inside the
    probe numbers), and the delta to add.
    """
    b, s = shape.global_batch, shape.seq_len
    if cfg.attention_free or shape.kind == "decode" or \
            s <= 2048:  # dense path: probes count it exactly
        return dict(total_global=0.0, counted_once_global=0.0,
                    added_global=0.0)
    bq = bk = FLASH_BLOCK
    nq = -(-s // bq)
    nk = -(-s // bk)
    per_block = 4.0 * b * bq * bk * cfg.n_heads * cfg.d_head
    mult = 4.0 if shape.kind == "train" else 1.0   # remat + bwd
    total = 0.0
    import numpy as _np
    kinds = _np.arange(cfg.n_layers)
    if cfg.sliding_window is not None and cfg.global_every:
        is_global = (kinds % cfg.global_every) == cfg.global_every - 1
    else:
        is_global = _np.ones(cfg.n_layers, dtype=bool)
    for g in is_global:
        if not FLASH_SKIP_BLOCKS:
            nblk = nq * nk
        else:
            nblk = nq * (nq + 1) // 2              # causal triangle
        total += nblk * per_block * mult
    # cost_analysis counts each lax.scan body once: the rolled variant
    # has ONE inner scan; the static-q skip variant has nq of them.
    bodies = nq if FLASH_SKIP_BLOCKS else 1
    counted_once = cfg.n_layers * bodies * per_block * mult
    return dict(total_global=total, counted_once_global=counted_once,
                added_global=total - counted_once)


def min_traffic_bytes(cfg: ArchConfig, shape: ShapeConfig,
                      data_axis: int = 16, remat: str = "full") -> float:
    """Per-device lower-bound HBM traffic of one step (bytes).

    State footprints come from the *actual sharding rules*
    (``sharding.state_bytes_per_device``): each device reads its param /
    opt / cache shard (replicated state is read per device — small archs
    without FSDP pay it) and writes the updated state; saved layer inputs
    under the remat policy add write+read traffic.  This is the
    fusion-independent floor the memory roofline term uses (XLA's 'bytes
    accessed' is a no-fusion upper bound, reported separately).
    """
    from repro.distribution.sharding import (MeshShape,
                                             state_bytes_per_device,
                                             tp_rules)
    st = state_bytes_per_device(cfg, shape)
    mesh = MeshShape({"data": 16, "model": 16})
    rules = tp_rules(cfg, mesh, shape.kind)
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    # FSDP gathers materialize model-sharded full weights in HBM each
    # step: write + read of params_total / model_axis per device.
    gathered = 0.0
    if rules.get("embed") == "data":
        gathered = cfg.param_count() * 2 / mesh.shape["model"]
    if shape.kind == "train":
        total = 2 * (st["params"] + st["opt"]) + st["grads"]
        total += 3 * gathered        # fwd + remat recompute + bwd use
        # saved activations: layer inputs (remat full) or all residuals
        mult = 2 if remat != "none" else 8
        total += mult * cfg.n_layers * b * s * d * 2 / data_axis
        total += 2 * b * s * 4 / data_axis
    elif shape.kind == "prefill":
        total = st["params"] + 2 * gathered + 2 * st["cache"] \
            + b * s * d * 2 / data_axis
    else:  # decode: read params + cache once, write one cache slot
        total = st["params"] + 2 * gathered + st["cache"] + b * d * 2
    return float(total)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float          # XLA bytes-accessed (no-fusion UPPER bound)
    coll_bytes: float
    model_flops: float
    traffic_dev: float = 0.0  # per-device min-traffic floor (memory term)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        if self.traffic_dev > 0:
            return self.traffic_dev / HBM_BW
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput / peak, if bound by the dominant term."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / max(t, 1e-30)

    def row(self) -> dict:
        return dict(arch=self.arch, shape=self.shape, mesh=self.mesh,
                    chips=self.chips,
                    t_compute_s=self.t_compute, t_memory_s=self.t_memory,
                    t_collective_s=self.t_collective,
                    bottleneck=self.bottleneck,
                    model_flops=self.model_flops, hlo_flops=self.hlo_flops,
                    hlo_bytes=self.hlo_bytes, coll_bytes=self.coll_bytes,
                    traffic_dev=self.traffic_dev,
                    useful_ratio=self.useful_ratio,
                    roofline_fraction=self.roofline_fraction)
