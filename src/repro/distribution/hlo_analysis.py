"""HLO-text analysis: collective-traffic extraction for the roofline.

``compiled.cost_analysis()`` gives FLOPs/bytes but not collective traffic,
so we parse the (optimized) HLO text: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute contributes its output
byte size.  Ops inside ``while`` bodies (the layer scan) execute
``trip_count`` times — the caller passes the scan length and any
computation reachable from a while body is scaled by it.
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^\s*%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'bf16[8,128]' or a tuple."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-computation *transferred-byte* estimates per collective kind.

    Per-device transfer conventions (ring algorithms, n large):
      all-gather          ~ output bytes
      reduce-scatter      ~ operand bytes (= output x n)
      all-reduce          ~ 2 x output bytes (reduce-scatter + all-gather)
      all-to-all          ~ output bytes
      collective-permute  ~ output bytes

    Returns {computation_name: {op_kind: bytes}}.
    """
    per_comp: dict = defaultdict(lambda: defaultdict(int))
    comp = "main"
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("%") and "{" in stripped and "->" in stripped:
            m = re.match(r"%([\w\.\-]+)", stripped)
            if m:
                comp = m.group(1)
            continue
        if stripped.startswith("ENTRY"):
            comp = "main"
            continue
        for kind in COLLECTIVES:
            token = None
            for suffix in ("(", "-start("):
                if f" {kind}{suffix}" in stripped:
                    token = f" {kind}{suffix}"
                    break
            if token is None:
                continue
            eq = stripped.split("=", 1)
            if len(eq) != 2:
                continue
            out_part, _, rest = eq[1].partition(token)
            operand_part = rest.split("),", 1)[0]
            out_b = _shape_bytes(out_part)
            in_b = _shape_bytes(operand_part)
            if kind == "reduce-scatter":
                nbytes = in_b or out_b
            elif kind == "all-reduce":
                nbytes = 2 * out_b
            else:
                nbytes = out_b
            per_comp[comp][kind] += nbytes
            break
    return {k: dict(v) for k, v in per_comp.items()}


def collective_bytes(hlo_text: str, scan_trip_count: int = 1) -> dict:
    """Aggregate collective bytes; while-body computations x trip count.

    Heuristic: computations whose name contains 'while' or 'body' or
    'scan' belong to the layer scan.  Returns per-kind and total bytes.
    """
    per_comp = parse_collectives(hlo_text)
    total = defaultdict(int)
    for comp, kinds in per_comp.items():
        mult = scan_trip_count if re.search(
            r"while|body|scan|cond", comp) else 1
        for kind, nbytes in kinds.items():
            total[kind] += nbytes * mult
    out = dict(total)
    out["total"] = sum(total.values())
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return hlo_text.count(f" {opname}(")
