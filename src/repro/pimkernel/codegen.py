"""PIM Device Code Gen (paper §2.2, PIM Executor sub-component 1).

"Dynamically synthesizes optimized PIM instructions (IRF code) and hardware
configuration code based on matrix shapes and data types."

The IRF program of a GEMV kernel is the per-tile MAC traversal: for the
k-th 32 B weight burst of a tile it names the destination accumulator and
the SRF operand window.  The hardware executes it as a loop nest
(ACC-outer, SRF-inner); we synthesize both the loop-nest form (what would
be written to the IRF — bounded by ``PimSpec.irf_entries``) and the
flattened per-burst arrays the functional device model consumes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.timing import PimSpec
from .tileconfig import PimDType, TileConfig

BURST = 32


@dataclasses.dataclass(frozen=True)
class IrfInsn:
    op: str                  # LOOP / MAC / FLUSH / CFG
    args: tuple


@dataclasses.dataclass
class PimProgram:
    """IRF code + flattened burst->operand mapping for one tile shape."""

    dtype: PimDType
    insns: list               # loop-nest IRF form
    acc_idx: np.ndarray       # (macs_per_tile,) destination accumulator
    srf_off: np.ndarray       # (macs_per_tile,) first SRF element index
    n_elems: int              # weight elements per 32 B burst
    setup_cmds: int           # WR_IRF commands to load the program
    chunk_cfg_cmds: int       # WR_IRF commands per chunk re-config

    def __len__(self) -> int:
        return len(self.insns)


def synthesize(tc: TileConfig, pim: PimSpec) -> PimProgram:
    """Generate the GEMV IRF program for one tile geometry."""
    row_bytes = tc.t_w * tc.dtype.w_bits // 8
    bursts_per_row = -(-row_bytes // BURST)
    n_elems = BURST * 8 // tc.dtype.w_bits

    # Loop-nest (IRF) form: outer loop over accumulators (tile rows),
    # inner loop over the row's weight bursts.  This is what bounds the
    # program to a handful of IRF entries regardless of tile size.
    insns = [
        IrfInsn("CFG", ("dtype", tc.dtype.name)),
        IrfInsn("LOOP", ("acc", tc.t_h)),
        IrfInsn("LOOP", ("burst", bursts_per_row)),
        IrfInsn("MAC", ("acc=acc", "srf=burst*%d" % n_elems)),
        IrfInsn("ENDL", ("burst",)),
        IrfInsn("ENDL", ("acc",)),
        IrfInsn("FLUSH", ()),
    ]
    assert len(insns) <= pim.irf_entries, "IRF overflow"

    k = np.arange(tc.macs_per_tile, dtype=np.int64)
    byte_in_tile = k * BURST
    acc = byte_in_tile // row_bytes
    elem = (byte_in_tile % row_bytes) * 8 // tc.dtype.w_bits
    return PimProgram(
        dtype=tc.dtype,
        insns=insns,
        acc_idx=acc.astype(np.int32),
        srf_off=elem.astype(np.int32),
        n_elems=n_elems,
        setup_cmds=pim.irf_setup_cmds,
        chunk_cfg_cmds=pim.irf_chunk_cmds,
    )


def decode_srf(raw: np.ndarray, dtype: PimDType) -> np.ndarray:
    """Decode SRF bytes into activation values (int paths / fp via codes)."""
    if dtype.is_fp:
        if dtype.a_bits == 8:
            return _fp8_decode(raw)
        return raw.view(np.float16).astype(np.float32)
    if dtype.a_bits == 8:
        return raw.view(np.int8).astype(np.int32)
    if dtype.a_bits == 16:
        return raw.view("<i2").astype(np.int32)
    if dtype.a_bits == 4:
        lo = (raw & 0xF).astype(np.int8)
        hi = ((raw >> 4) & 0xF).astype(np.int8)
        lo = np.where(lo >= 8, lo - 16, lo).astype(np.int32)
        hi = np.where(hi >= 8, hi - 16, hi).astype(np.int32)
        out = np.empty(raw.size * 2, dtype=np.int32)
        out[0::2] = lo
        out[1::2] = hi
        return out
    raise ValueError(dtype)


def encode_acts(x: np.ndarray, dtype: PimDType) -> np.ndarray:
    """Encode activation values into SRF byte layout."""
    if dtype.is_fp:
        if dtype.a_bits == 8:
            return _fp8_encode(x)
        return x.astype(np.float16).view(np.uint8)
    if dtype.a_bits == 8:
        return x.astype(np.int8).view(np.uint8)
    if dtype.a_bits == 16:
        return x.astype("<i2").view(np.uint8)
    if dtype.a_bits == 4:
        m = x.astype(np.int8)
        lo = (m[0::2] & 0xF).astype(np.uint8)
        hi = (m[1::2] & 0xF).astype(np.uint8)
        return lo | (hi << 4)
    raise ValueError(dtype)


# --- fp8 (e4m3, no inf, saturating) helpers used by the FP dtypes --------
_FP8_TABLE = None


def _fp8_table() -> np.ndarray:
    global _FP8_TABLE
    if _FP8_TABLE is None:
        codes = np.arange(256, dtype=np.uint32)
        sign = np.where(codes >> 7, -1.0, 1.0)
        exp = ((codes >> 3) & 0xF).astype(np.int32)
        man = (codes & 0x7).astype(np.float64)
        normal = sign * (1.0 + man / 8.0) * np.exp2(exp - 7.0)
        subnorm = sign * (man / 8.0) * np.exp2(-6.0)
        vals = np.where(exp == 0, subnorm, normal)
        # e4m3fn: exp==15, man==7 is NaN; keep finite (saturate) for sim.
        _FP8_TABLE = vals.astype(np.float32)
    return _FP8_TABLE


def _fp8_decode(raw: np.ndarray) -> np.ndarray:
    return _fp8_table()[raw]


def _fp8_encode(x: np.ndarray) -> np.ndarray:
    """Nearest-value quantization to e4m3 codes (simulation-grade)."""
    table = _fp8_table()
    order = np.argsort(table, kind="stable")
    svals = table[order]
    idx = np.searchsorted(svals, x.astype(np.float32))
    idx = np.clip(idx, 1, 255)
    left = svals[idx - 1]
    right = svals[np.minimum(idx, 255)]
    pick = np.where(np.abs(x - left) <= np.abs(right - x), idx - 1, idx)
    return order[pick].astype(np.uint8)


def decode_w_burst(raw: np.ndarray, dtype: PimDType) -> np.ndarray:
    """Decode one 32 B weight burst into values (int32 or float32)."""
    if dtype.is_fp:
        return _fp8_decode(raw)
    if dtype.w_bits == 8:
        return raw.view(np.int8).astype(np.int32)
    if dtype.w_bits == 4:
        lo = (raw & 0xF).astype(np.int8)
        hi = ((raw >> 4) & 0xF).astype(np.int8)
        lo = np.where(lo >= 8, lo - 16, lo).astype(np.int32)
        hi = np.where(hi >= 8, hi - 16, hi).astype(np.int32)
        out = np.empty(raw.size * 2, dtype=np.int32)
        out[0::2] = lo
        out[1::2] = hi
        return out
    raise ValueError(dtype)
