"""2D address mapping (paper §2.3, Fig. 3).

* **Vertical mapping** — tile rows (the H direction) are interleaved across
  the DRAM hierarchy in Channel -> Rank -> BankGroup -> Bank order so that
  consecutive h-tiles land on distinct PIM blocks: this maximizes bank-level
  parallelism for PIM execution and external bandwidth for the preload.
* **Horizontal mapping** — tiles adjacent in the W direction are placed at
  consecutive byte offsets of the *same* bank, so the per-tile MAC sweeps
  hit the open row (row-buffer locality).

``block_of`` / ``bank_layout_offset`` define the bijection
``(h_tile, w_tile) <-> (channel, rank, bank, byte_offset)`` used by both the
Data Mapper (placement) and the GEMV kernel (command synthesis); a
hypothesis test asserts bijectivity over random geometries.
"""
from __future__ import annotations

import dataclasses

from repro.core.timing import SystemSpec


@dataclasses.dataclass(frozen=True)
class BlockAddr:
    channel: int
    rank: int
    bank: int           # 0..15 : bank id = bg * banks_per_group + idx
    byte_offset: int    # linear offset inside the bank's PIM region

    def row_col(self, page_bytes: int, burst_bytes: int) -> tuple[int, int]:
        return (self.byte_offset // page_bytes,
                (self.byte_offset % page_bytes) // burst_bytes)


def num_blocks(spec: SystemSpec) -> int:
    return spec.num_channels * spec.num_ranks * spec.timings.num_banks


def block_of(block_id: int, spec: SystemSpec) -> tuple[int, int, int]:
    """block_id -> (channel, rank, bank): channel-first interleaving.

    Bank order enumerates bank groups first (bg = fastest-varying within a
    channel/rank after channels), i.e. block ids walk Ch -> Rank -> BG ->
    Bank-in-group, matching the paper's vertical-mapping order.
    """
    t = spec.timings
    ch = block_id % spec.num_channels
    rest = block_id // spec.num_channels
    rank = rest % spec.num_ranks
    rest //= spec.num_ranks
    bg = rest % t.num_bankgroups
    idx = rest // t.num_bankgroups
    bank = bg * t.banks_per_group + idx
    return ch, rank, bank


def block_id_of(ch: int, rank: int, bank: int, spec: SystemSpec) -> int:
    t = spec.timings
    bg, idx = divmod(bank, t.banks_per_group)
    rest = idx * t.num_bankgroups + bg
    rest = rest * spec.num_ranks + rank
    return rest * spec.num_channels + ch

def tile_address(h_tile: int, w_tile: int, n_wtiles: int, tile_bytes: int,
                 spec: SystemSpec, split: int = 1,
                 base_offset: int = 0) -> BlockAddr:
    """Map tile (h_tile, w_tile) of a matrix to its physical location.

    ``split`` is the reshape column-split factor: with split > 1 the
    w-tiles of one h-tile are divided into ``split`` groups assigned to
    *different* blocks (paper §2.3 "Reshape Optimization"); within a group
    the horizontal mapping (same bank, consecutive offsets) is preserved.
    """
    nblk = num_blocks(spec)
    group_w = -(-n_wtiles // split)          # w-tiles per split group
    g, w_in = divmod(w_tile, group_w)
    logical = h_tile * split + g             # logical block index
    blk = logical % nblk
    step = logical // nblk                   # serialized rounds
    ch, rank, bank = block_of(blk, spec)
    # Horizontal mapping: consecutive w-tiles (within the group) adjacent;
    # successive rounds stacked after them.
    offset = base_offset + (step * group_w + w_in) * tile_bytes
    return BlockAddr(ch, rank, bank, offset)
