"""Data Mapper (paper §2.2, offline stage).

Receives the weight matrix + data type, structures it into PIM tiles
(`tileconfig`), generates the memory layout (`addrmap` — vertical +
horizontal mapping, optional reshape column-split) and *preloads* it into
the per-bank DRAM images.  Everything the runtime needs (tile->block
assignment, per-chunk byte ranges, SRF chunk ranges) is derived from the
resulting :class:`PimLayout`, so placement decisions live in exactly one
place — as in the paper's architecture (Fig. 2, both components refer to
the PIM tiling configuration).

The packing is *byte-exact*: ``pack`` produces per-(channel, rank, bank)
uint8 DRAM images and ``unpack`` inverts them (hypothesis tests assert the
round trip).  The device-level interpreter (`core/device.py`) executes the
generated command streams against these images, which is what makes the
behavioral-fidelity tests end-to-end.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.timing import SystemSpec
from . import addrmap
from .tileconfig import PimDType, TileConfig

BURST = 32  # bytes per BL16 access


def _encode_w(mat: np.ndarray, dtype: PimDType) -> np.ndarray:
    """Encode an integer (or fp8-code) matrix into its byte layout rows."""
    if dtype.is_fp:
        return mat.astype(np.uint8)  # fp8 codes stored verbatim
    if dtype.w_bits == 8:
        return mat.astype(np.int8).view(np.uint8)
    if dtype.w_bits == 4:
        m = mat.astype(np.int8)
        assert m.shape[1] % 2 == 0
        lo = (m[:, 0::2] & 0xF).astype(np.uint8)
        hi = (m[:, 1::2] & 0xF).astype(np.uint8)
        return lo | (hi << 4)
    raise ValueError(dtype)


def _decode_w(raw: np.ndarray, dtype: PimDType, n_elems: int) -> np.ndarray:
    """Decode bytes back into signed weight values (int paths) or codes."""
    if dtype.is_fp:
        return raw[:n_elems].astype(np.int32)  # fp8 codes
    if dtype.w_bits == 8:
        return raw.view(np.int8)[:n_elems].astype(np.int32)
    if dtype.w_bits == 4:
        lo = (raw & 0xF).astype(np.int8)
        hi = ((raw >> 4) & 0xF).astype(np.int8)
        lo = np.where(lo >= 8, lo - 16, lo)
        hi = np.where(hi >= 8, hi - 16, hi)
        out = np.empty(raw.size * 2, dtype=np.int32)
        out[0::2] = lo
        out[1::2] = hi
        return out[:n_elems]
    raise ValueError(dtype)


@dataclasses.dataclass
class PimLayout:
    """Placement + schedule geometry for one GEMV weight matrix."""

    spec: SystemSpec
    tc: TileConfig
    H: int
    W: int
    split: int                   # reshape column-split factor (1 = off)
    n_htiles: int
    n_wtiles: int
    group_w: int                 # w-tiles per split group
    n_logical: int               # h-tiles * split
    rounds: int                  # ceil(n_logical / num_blocks)

    # ---- geometry helpers -------------------------------------------------
    @property
    def nblocks(self) -> int:
        return addrmap.num_blocks(self.spec)

    @property
    def padded_h(self) -> int:
        return self.n_htiles * self.tc.t_h

    @property
    def padded_w(self) -> int:
        return self.n_wtiles * self.tc.t_w

    def logical_of(self, h_tile: int, g: int) -> int:
        return h_tile * self.split + g

    def place(self, logical: int) -> tuple[int, tuple[int, int, int]]:
        """logical block index -> (round, (channel, rank, bank))."""
        blk = logical % self.nblocks
        rnd = logical // self.nblocks
        return rnd, addrmap.block_of(blk, self.spec)

    def w_tile_at(self, g: int, chunk: int) -> int | None:
        w = g * self.group_w + chunk
        if chunk >= self.group_w or w >= min((g + 1) * self.group_w,
                                             self.n_wtiles):
            return None
        return w

    def chunk_offset(self, rnd: int, chunk: int) -> int:
        """Byte offset of (round, chunk)'s tile inside its bank."""
        return (rnd * self.group_w + chunk) * self.tc.tile_w_bytes

    def active_logicals(self, rnd: int) -> range:
        return range(rnd * self.nblocks,
                     min((rnd + 1) * self.nblocks, self.n_logical))

    def active_banks(self, rnd: int, channel: int) -> list[tuple[int, int]]:
        """(rank, bank) of this channel's active blocks in round `rnd`."""
        out = []
        for logical in self.active_logicals(rnd):
            ch, rank, bank = addrmap.block_of(logical % self.nblocks,
                                              self.spec)
            if ch == channel:
                out.append((rank, bank))
        return out

    def tile_eff(self, h_tile: int, w_tile: int) -> tuple[int, int]:
        th = self.tc.t_h if h_tile < self.n_htiles - 1 else \
            self.H - h_tile * self.tc.t_h
        tw = self.tc.t_w if w_tile < self.n_wtiles - 1 else \
            self.W - w_tile * self.tc.t_w
        return th, tw

    def max_bursts(self, rnd: int, chunk: int) -> int:
        """Lock-step MAC count at (round, chunk): worst active bank.

        Storage is row-padded to the full ``t_w`` stride (all banks must
        share one IRF program in broadcast mode), so the W direction always
        sweeps the full row; only a uniformly-short edge h-tile lets the
        sweep stop early (trailing tile rows are a sequential suffix).
        """
        if not self.active_groups(rnd, chunk):
            return 0
        h_tiles = {l // self.split for l in self.active_logicals(rnd)}
        th = self.tc.t_h if any(h < self.n_htiles - 1 for h in h_tiles) \
            else (self.H - (self.n_htiles - 1) * self.tc.t_h)
        row_bytes = self.tc.t_w * self.tc.dtype.w_bits // 8
        return int(math.ceil(th * row_bytes / BURST))

    def active_groups(self, rnd: int, chunk: int) -> list[int]:
        groups = sorted({l % self.split for l in self.active_logicals(rnd)})
        return [g for g in groups if self.w_tile_at(g, chunk) is not None]

    @property
    def utilization(self) -> float:
        return self.n_logical / (self.rounds * self.nblocks)

    @property
    def flops(self) -> int:
        return 2 * self.H * self.W

    @property
    def weight_bytes(self) -> int:
        return self.H * self.W * self.tc.dtype.w_bits // 8


class DataMapper:
    """Offline placement: matrix -> PimLayout (+ optional DRAM preload)."""

    def __init__(self, spec: SystemSpec):
        self.spec = spec

    def layout(self, H: int, W: int, dtype: PimDType,
               reshape: bool = False) -> PimLayout:
        tc = TileConfig.make(dtype, self.spec.pim,
                             self.spec.timings.burst_bytes)
        n_h, n_w = tc.tiles_for(H, W)
        nblk = addrmap.num_blocks(self.spec)
        split = 1
        if reshape and n_h < nblk and n_w > 1:
            # Paper §2.3: column-based partitioning activates idle blocks.
            split = min(self.spec.pim.max_reshape_split, n_w,
                        max(1, nblk // n_h))
        group_w = -(-n_w // split)
        n_logical = n_h * split
        rounds = -(-n_logical // nblk)
        return PimLayout(spec=self.spec, tc=tc, H=H, W=W, split=split,
                         n_htiles=n_h, n_wtiles=n_w, group_w=group_w,
                         n_logical=n_logical, rounds=rounds)

    # ------------------------------------------------------------------
    def pack(self, layout: PimLayout,
             weights: np.ndarray) -> dict[tuple[int, int, int], np.ndarray]:
        """Preload weights into per-(ch, rank, bank) uint8 DRAM images.

        ``weights`` is an integer matrix (int dtypes: int8 values; W4 in
        [-8, 7]) or uint8 fp8 codes of shape (H, W).  Edge tiles are stored
        zero-padded to the full tile footprint so every (round, chunk) has
        a uniform byte offset across banks (lock-step broadcast invariant).
        """
        tc, spec = layout.tc, layout.spec
        H, W = weights.shape
        assert (H, W) == (layout.H, layout.W)
        padded = np.zeros((layout.padded_h, layout.padded_w),
                          dtype=weights.dtype)
        padded[:H, :W] = weights
        bank_bytes = layout.rounds * layout.group_w * tc.tile_w_bytes
        dram = {}
        for ch in range(spec.num_channels):
            for rank in range(spec.num_ranks):
                for bank in range(spec.timings.num_banks):
                    dram[(ch, rank, bank)] = np.zeros(bank_bytes,
                                                      dtype=np.uint8)
        for h in range(layout.n_htiles):
            for g in range(layout.split):
                logical = layout.logical_of(h, g)
                rnd, (ch, rank, bank) = layout.place(logical)
                img = dram[(ch, rank, bank)]
                for chunk in range(layout.group_w):
                    w = layout.w_tile_at(g, chunk)
                    if w is None:
                        continue
                    tile = padded[h * tc.t_h:(h + 1) * tc.t_h,
                                  w * tc.t_w:(w + 1) * tc.t_w]
                    raw = _encode_w(tile, tc.dtype).reshape(-1)
                    off = layout.chunk_offset(rnd, chunk)
                    img[off:off + raw.size] = raw
        return dram

    def unpack(self, layout: PimLayout,
               dram: dict[tuple[int, int, int], np.ndarray]) -> np.ndarray:
        """Inverse of :meth:`pack` (returns the padded matrix)."""
        tc = layout.tc
        row_bytes = tc.t_w * tc.dtype.w_bits // 8
        out = np.zeros((layout.padded_h, layout.padded_w), dtype=np.int32)
        for h in range(layout.n_htiles):
            for g in range(layout.split):
                logical = layout.logical_of(h, g)
                rnd, (ch, rank, bank) = layout.place(logical)
                img = dram[(ch, rank, bank)]
                for chunk in range(layout.group_w):
                    w = layout.w_tile_at(g, chunk)
                    if w is None:
                        continue
                    off = layout.chunk_offset(rnd, chunk)
                    raw = img[off:off + tc.tile_w_bytes]
                    rows = raw.reshape(tc.t_h, row_bytes)
                    vals = np.stack([
                        _decode_w(rows[r], tc.dtype, tc.t_w)
                        for r in range(tc.t_h)])
                    out[h * tc.t_h:(h + 1) * tc.t_h,
                        w * tc.t_w:(w + 1) * tc.t_w] = vals
        return out
