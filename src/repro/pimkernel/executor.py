"""PIM Executor (paper §2.2): runtime orchestration.

Glues Code Gen + PIM Control + GEMV Kernel over a Data-Mapper layout and
runs the result through the cycle engine (timing view) and optionally the
functional device model (behavioral view).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commands as C
from repro.core import controller, device, engine
from repro.core.energy import EnergyParams, gemv_energy_summary
from repro.core.timing import SystemSpec
from . import codegen
from .datamapper import DataMapper, PimLayout
from .gemv import GemvKernel, GemvStreams
from .tileconfig import PimDType, TileConfig


@dataclasses.dataclass
class PimResult:
    cycles: int                 # max over channels
    ns: float
    flops: int
    weight_bytes: int
    utilization: float
    split: int
    energy: dict
    counts: np.ndarray          # aggregated opcode histogram
    meta: dict

    @property
    def gflops(self) -> float:
        return self.flops / max(self.ns, 1e-9)


class PimExecutor:
    """Runtime control for GEMV offload on LP5X-PIM."""

    def __init__(self, spec: SystemSpec,
                 energy_params: EnergyParams | None = None):
        self.spec = spec
        self.cyc = spec.derive_cycles()
        self.mapper = DataMapper(spec)
        self.kernel = GemvKernel(spec)
        self.energy_params = energy_params or EnergyParams()

    # -- paper pipeline -------------------------------------------------
    def plan(self, H: int, W: int, dtype: PimDType,
             reshape: bool = False) -> tuple[PimLayout, codegen.PimProgram]:
        layout = self.mapper.layout(H, W, dtype, reshape=reshape)
        program = codegen.synthesize(layout.tc, self.spec.pim)
        return layout, program

    def build_streams(self, layout: PimLayout, program: codegen.PimProgram,
                      x: np.ndarray | None = None,
                      fence: bool = False,
                      flush: str = "bus") -> GemvStreams:
        return self.kernel.build(layout, program, x=x, fence=fence,
                                 flush=flush)

    def time_streams(self, gs: GemvStreams) -> PimResult:
        issue, totals = engine.run_streams(self.cyc, gs.streams)
        cycles = int(totals.max()) if totals.size else 0
        counts = sum((C.op_counts(s) for s in gs.streams),
                     np.zeros(C.NUM_OPCODES, dtype=np.int64))
        active = max(1, int(round(16 * gs.layout.utilization)))
        energy = gemv_energy_summary(gs.streams, totals, self.spec,
                                     gs.meta["flops"], self.energy_params,
                                     active_banks=active)
        return PimResult(
            cycles=cycles,
            ns=cycles * self.cyc.tck_ns,
            flops=gs.meta["flops"],
            weight_bytes=gs.meta["weight_bytes"],
            utilization=gs.meta["utilization"],
            split=gs.meta["split"],
            energy=energy,
            counts=counts,
            meta=gs.meta,
        )

    def run_gemv(self, H: int, W: int, dtype: PimDType,
                 fence: bool = False, reshape: bool = False,
                 flush: str = "bus") -> PimResult:
        """Timing-only GEMV simulation (the Fig. 4 path)."""
        layout, program = self.plan(H, W, dtype, reshape=reshape)
        gs = self.build_streams(layout, program, fence=fence, flush=flush)
        return self.time_streams(gs)

    def run_gemv_functional(self, weights: np.ndarray, x: np.ndarray,
                            dtype: PimDType, fence: bool = False,
                            reshape: bool = False
                            ) -> tuple[np.ndarray, PimResult]:
        """Full HW/SW co-simulation: returns (y, timing result)."""
        H, W = weights.shape
        layout, program = self.plan(H, W, dtype, reshape=reshape)
        dram = self.mapper.pack(layout, weights)
        gs = self.build_streams(layout, program, x=x, fence=fence)
        y = device.execute_gemv(layout, program, dram, gs.streams,
                                gs.payloads)
        return y, self.time_streams(gs)

    # -- non-PIM baseline (Fig. 4 normalization) --------------------------
    def run_baseline(self, H: int, W: int, dtype: PimDType) -> PimResult:
        """Sequential weight read on a non-PIM system (4 channels)."""
        total_bytes = H * W * dtype.w_bits // 8
        per_ch = -(-total_bytes // self.spec.num_channels)
        stream = controller.sequential_read_stream(per_ch, self.spec)
        streams = [stream] * self.spec.num_channels
        issue, totals = engine.run_streams(self.cyc, [stream])
        cycles = int(totals.max())
        counts = C.op_counts(stream) * self.spec.num_channels
        energy = gemv_energy_summary(streams, [cycles] * len(streams),
                                     self.spec, 2 * H * W,
                                     self.energy_params)
        return PimResult(cycles=cycles, ns=cycles * self.cyc.tck_ns,
                         flops=2 * H * W,
                         weight_bytes=total_bytes,
                         utilization=1.0, split=1, energy=energy,
                         counts=counts, meta=dict(kind="baseline"))
