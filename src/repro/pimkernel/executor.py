"""PIM Executor (paper §2.2): runtime orchestration.

Glues Code Gen + PIM Control + GEMV Kernel over a Data-Mapper layout and
runs the result through the cycle engine (timing view) and optionally the
functional device model (behavioral view).

The executor speaks the *fleet request* API and is a stateless planner:
a :class:`GemvRequest` names one unit of simulator work (a PIM GEMV or
the non-PIM baseline) **including the ``SystemSpec`` it runs under**, and
:meth:`PimExecutor.run_many` plans every request eagerly, dedupes
repeats, pads all per-channel command streams into one flat fleet batch
and resolves them with a single ``engine.resolve_fleet`` call — points
with *different* specs ride the same batch, because the engine traces the
timing configuration as fleet data.  Per-spec machinery (``DataMapper``,
``GemvKernel`` geometry, ``derive_cycles``) is built once per spec in a
shared context cache, not per executor instance, so a heterogeneous
design-space grid costs no more setup than a single-spec sweep.

``run_gemv`` / ``run_baseline`` are the one-request conveniences on top;
``run_functional_many`` is the batched HW/SW co-simulation path (one
engine dispatch for all timing lanes, then the per-channel device
interpreters).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence

import numpy as np

from repro.core import commands as C
from repro.core import controller, device, engine
from repro.core.energy import EnergyParams, gemv_energy_summary
from repro.core.timing import DEFAULT_SYSTEM, SystemSpec, TimingCycles
from . import codegen
from .datamapper import DataMapper, PimLayout
from .gemv import GemvKernel, GemvStreams
from .tileconfig import PimDType


@dataclasses.dataclass(frozen=True)
class SpecContext:
    """Everything derived from one ``SystemSpec``, built once and shared."""

    spec: SystemSpec
    cyc: TimingCycles
    mapper: DataMapper
    kernel: GemvKernel


@functools.lru_cache(maxsize=512)
def spec_context(spec: SystemSpec) -> SpecContext:
    """Per-spec planning context (cached process-wide: specs are frozen).

    Bounded so design-space searches that mint fresh specs per step
    don't grow memory monotonically; 512 comfortably covers any grid
    resolved in one fleet call.
    """
    return SpecContext(spec=spec, cyc=spec.derive_cycles(),
                       mapper=DataMapper(spec), kernel=GemvKernel())


@dataclasses.dataclass(frozen=True)
class GemvRequest:
    """One unit of fleet work: a PIM GEMV point or its host baseline.

    ``spec`` names the memory system the request runs under; ``None``
    means "the caller's default", resolved by :meth:`resolved` before any
    planning or caching happens, so every planned/keyed request is
    spec-explicit.  ``key`` is the canonical dedupe/cache key — baseline
    timing depends only on (spec, H, W, dtype), so the PIM-only knobs are
    excluded there.
    """

    H: int
    W: int
    dtype: PimDType
    fence: bool = False
    reshape: bool = False
    flush: str = "bus"
    kind: str = "pim"            # "pim" | "baseline"
    spec: SystemSpec | None = None

    @staticmethod
    def pim(H: int, W: int, dtype: PimDType | str, *, fence: bool = False,
            reshape: bool = False, flush: str = "bus",
            spec: SystemSpec | None = None) -> "GemvRequest":
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        return GemvRequest(H, W, dtype, fence, reshape, flush, "pim", spec)

    @staticmethod
    def baseline(H: int, W: int, dtype: PimDType | str,
                 spec: SystemSpec | None = None) -> "GemvRequest":
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        return GemvRequest(H, W, dtype, kind="baseline", spec=spec)

    def resolved(self, default: SystemSpec) -> "GemvRequest":
        """This request with its spec filled in (no-op when explicit)."""
        if self.spec is not None:
            return self
        return dataclasses.replace(self, spec=default)

    @property
    def key(self) -> tuple:
        if self.kind == "baseline":
            # Baseline streams/timing/energy depend only on the memory
            # system (timings, channel/rank counts), never the PIM
            # knobs — PIM-variant grids share one baseline lane.
            mem = None if self.spec is None else (
                self.spec.timings, self.spec.num_channels,
                self.spec.num_ranks)
            return ("base", mem, self.H, self.W, self.dtype)
        return ("pim", self.spec, self.H, self.W, self.dtype, self.fence,
                self.reshape, self.flush)


@dataclasses.dataclass
class PlannedGemv:
    """A request with its layouts/programs/streams built, ready to time.

    ``stream_keys`` carries one structural identity per channel stream
    (see ``GemvStreams.stream_keys``): the engine dedupes and LRU-caches
    lanes by planner-provided key instead of hashing stream bytes.
    """

    req: GemvRequest
    ctx: SpecContext
    streams: list[np.ndarray]
    stream_keys: list | None = None
    gs: GemvStreams | None = None      # pim requests only
    weight_bytes: int = 0              # baseline requests only


@dataclasses.dataclass
class PimResult:
    cycles: int                 # max over channels
    ns: float
    flops: int
    weight_bytes: int
    utilization: float
    split: int
    energy: dict
    counts: np.ndarray          # aggregated opcode histogram
    meta: dict

    @property
    def gflops(self) -> float:
        return self.flops / max(self.ns, 1e-9)


@dataclasses.dataclass
class FunctionalGemv:
    """One HW/SW co-simulation unit: weights + activations + knobs.

    Unlike :class:`GemvRequest` this carries the actual operand arrays,
    so it is never deduped/cached — but its *timing* lane joins the same
    fleet batch as everything else in the call.
    """

    weights: np.ndarray
    x: np.ndarray
    dtype: PimDType
    fence: bool = False
    reshape: bool = False
    spec: SystemSpec | None = None


class PimExecutor:
    """Stateless planner for GEMV offload on LP5X-PIM.

    ``default_spec`` only fills in requests that do not name a spec of
    their own; all per-spec state lives in the shared ``spec_context``
    cache, keyed by the request's spec.
    """

    def __init__(self, default_spec: SystemSpec | None = None,
                 energy_params: EnergyParams | None = None):
        self.default_spec = default_spec or DEFAULT_SYSTEM
        self.energy_params = energy_params or EnergyParams()

    # -- paper pipeline -------------------------------------------------
    def plan(self, H: int, W: int, dtype: PimDType,
             reshape: bool = False, spec: SystemSpec | None = None
             ) -> tuple[PimLayout, codegen.PimProgram]:
        ctx = spec_context(spec or self.default_spec)
        layout = ctx.mapper.layout(H, W, dtype, reshape=reshape)
        program = codegen.synthesize(layout.tc, ctx.spec.pim)
        return layout, program

    def build_streams(self, layout: PimLayout, program: codegen.PimProgram,
                      x: np.ndarray | None = None,
                      fence: bool = False,
                      flush: str = "bus") -> GemvStreams:
        kernel = spec_context(layout.spec).kernel
        return kernel.build(layout, program, x=x, fence=fence, flush=flush)

    def time_streams(self, gs: GemvStreams) -> PimResult:
        ctx = spec_context(gs.layout.spec)
        _, totals = engine.run_streams(ctx.cyc, gs.streams)
        return self._pim_result(ctx, gs, totals)

    def run_gemv(self, H: int, W: int, dtype: PimDType,
                 fence: bool = False, reshape: bool = False,
                 flush: str = "bus",
                 spec: SystemSpec | None = None) -> PimResult:
        """Timing-only GEMV simulation (the Fig. 4 path)."""
        layout, program = self.plan(H, W, dtype, reshape=reshape, spec=spec)
        gs = self.build_streams(layout, program, fence=fence, flush=flush)
        return self.time_streams(gs)

    def run_gemv_functional(self, weights: np.ndarray, x: np.ndarray,
                            dtype: PimDType, fence: bool = False,
                            reshape: bool = False,
                            spec: SystemSpec | None = None
                            ) -> tuple[np.ndarray, PimResult]:
        """Full HW/SW co-simulation: returns (y, timing result)."""
        return self.run_functional_many([
            FunctionalGemv(weights, x, dtype, fence=fence, reshape=reshape,
                           spec=spec)])[0]

    # -- fleet API -------------------------------------------------------
    def plan_many(self, reqs: Iterable[GemvRequest]) -> list[PlannedGemv]:
        """Build every layout/program/stream eagerly (no timing yet)."""
        out = []
        for r in reqs:
            r = r.resolved(self.default_spec)
            ctx = spec_context(r.spec)
            if r.kind == "baseline":
                total_bytes = r.H * r.W * r.dtype.w_bits // 8
                per_ch = -(-total_bytes // ctx.spec.num_channels)
                stream = controller.sequential_read_stream(per_ch, ctx.spec)
                # the stream is fully determined by (memory system, H, W,
                # dtype) == r.key, identical across channels -> one lane
                out.append(PlannedGemv(
                    req=r, ctx=ctx,
                    streams=[stream] * ctx.spec.num_channels,
                    stream_keys=[r.key] * ctx.spec.num_channels,
                    weight_bytes=total_bytes))
            else:
                layout, program = self.plan(r.H, r.W, r.dtype,
                                            reshape=r.reshape, spec=r.spec)
                gs = self.build_streams(layout, program, fence=r.fence,
                                        flush=r.flush)
                out.append(PlannedGemv(req=r, ctx=ctx, streams=gs.streams,
                                       stream_keys=gs.stream_keys, gs=gs))
        return out

    def touch_many(self, reqs: Sequence[GemvRequest]) -> int:
        """Pin the requests' resolved lanes at the MRU end of the lane
        LRU (``engine.lane_cache_touch``); returns lanes found warm.

        Planning is cheap numpy stream synthesis (and the layouts /
        programs sit in the shared ``spec_context`` LRU), so this never
        dispatches the engine: absent lanes stay absent until something
        actually resolves them.  The speculative-decode serve loop uses
        it every tick to shield its hot small-shape draft lanes from
        eviction by large heterogeneous grid resolves.
        """
        reqs = [r.resolved(self.default_spec) for r in reqs]
        uniq: dict[tuple, GemvRequest] = {}
        for r in reqs:
            uniq.setdefault(r.key, r)
        pairs = []
        for p in self.plan_many(uniq.values()):
            pairs.extend((p.ctx.cyc, k) for k in p.stream_keys
                         if k is not None)
        return engine.lane_cache_touch(pairs)

    def run_many(self, reqs: Sequence[GemvRequest]) -> list[PimResult]:
        """Resolve many requests through ONE batched engine call.

        Requests may name arbitrary (heterogeneous) ``SystemSpec``s — the
        whole (spec x shape) grid still resolves as one fleet.  Duplicate
        requests (by ``key``, which includes the spec) are planned and
        timed once; the returned list matches the input order.  Results
        are bit-identical to the per-call ``run_gemv`` / ``run_baseline``
        paths under each request's spec.
        """
        reqs = [r.resolved(self.default_spec) for r in reqs]
        uniq: dict[tuple, GemvRequest] = {}
        for r in reqs:
            uniq.setdefault(r.key, r)
        planned = self.plan_many(uniq.values())
        fleet = engine.resolve_fleet(
            [(p.ctx.cyc, p.streams) for p in planned],
            keys=[p.stream_keys for p in planned],
            need_issue=False)
        by_key = {p.req.key: self._finish(p, fr.totals)
                  for p, fr in zip(planned, fleet)}
        return [by_key[r.key] for r in reqs]

    def run_functional_many(self, items: Sequence[FunctionalGemv]
                            ) -> list[tuple[np.ndarray, PimResult]]:
        """Batched HW/SW co-simulation.

        Plans every item (layout, codegen, DRAM preload, streams with
        WR_SRF payloads), resolves ALL timing lanes — across specs — in
        one ``resolve_fleet`` dispatch, then runs the functional device
        interpreter per item.  Returns [(y, timing result)] in order.
        """
        plans = []
        for it in items:
            spec = it.spec or self.default_spec
            ctx = spec_context(spec)
            H, W = it.weights.shape
            layout, program = self.plan(H, W, it.dtype, reshape=it.reshape,
                                        spec=spec)
            dram = ctx.mapper.pack(layout, it.weights)
            gs = self.build_streams(layout, program, x=it.x, fence=it.fence)
            plans.append((ctx, layout, program, dram, gs))
        fleet = engine.resolve_fleet(
            [(ctx.cyc, gs.streams) for ctx, _l, _p, _d, gs in plans],
            keys=[gs.stream_keys for _c, _l, _p, _d, gs in plans],
            need_issue=False)
        out = []
        for (ctx, layout, program, dram, gs), fr in zip(plans, fleet):
            y = device.execute_gemv(layout, program, dram, gs.streams,
                                    gs.payloads)
            out.append((y, self._pim_result(ctx, gs, fr.totals)))
        return out

    def _finish(self, p: PlannedGemv, totals: np.ndarray) -> PimResult:
        if p.req.kind == "baseline":
            return self._baseline_result(p.ctx, p.req, p.streams, totals,
                                         p.weight_bytes)
        return self._pim_result(p.ctx, p.gs, totals)

    # -- result assembly -------------------------------------------------
    def _pim_result(self, ctx: SpecContext, gs: GemvStreams,
                    totals: np.ndarray) -> PimResult:
        cycles = int(totals.max()) if totals.size else 0
        counts = sum((C.op_counts(s) for s in gs.streams),
                     np.zeros(C.NUM_OPCODES, dtype=np.int64))
        active = max(1, int(round(16 * gs.layout.utilization)))
        energy = gemv_energy_summary(gs.streams, totals, ctx.spec,
                                     gs.meta["flops"], self.energy_params,
                                     active_banks=active)
        return PimResult(
            cycles=cycles,
            ns=cycles * ctx.cyc.tck_ns,
            flops=gs.meta["flops"],
            weight_bytes=gs.meta["weight_bytes"],
            utilization=gs.meta["utilization"],
            split=gs.meta["split"],
            energy=energy,
            counts=counts,
            meta=gs.meta,
        )

    def _baseline_result(self, ctx: SpecContext, req: GemvRequest,
                         streams: list[np.ndarray],
                         totals: np.ndarray, total_bytes: int) -> PimResult:
        cycles = int(totals.max()) if totals.size else 0
        counts = sum((C.op_counts(s) for s in streams),
                     np.zeros(C.NUM_OPCODES, dtype=np.int64))
        energy = gemv_energy_summary(streams, totals, ctx.spec,
                                     2 * req.H * req.W, self.energy_params)
        return PimResult(cycles=cycles, ns=cycles * ctx.cyc.tck_ns,
                         flops=2 * req.H * req.W,
                         weight_bytes=total_bytes,
                         utilization=1.0, split=1, energy=energy,
                         counts=counts, meta=dict(kind="baseline"))

    # -- non-PIM baseline (Fig. 4 normalization) --------------------------
    def run_baseline(self, H: int, W: int, dtype: PimDType,
                     spec: SystemSpec | None = None) -> PimResult:
        """Sequential weight read on a non-PIM system (all channels)."""
        return self.run_many([GemvRequest.baseline(H, W, dtype,
                                                   spec=spec)])[0]
