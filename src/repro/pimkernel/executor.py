"""PIM Executor (paper §2.2): runtime orchestration.

Glues Code Gen + PIM Control + GEMV Kernel over a Data-Mapper layout and
runs the result through the cycle engine (timing view) and optionally the
functional device model (behavioral view).

The executor speaks the *fleet request* API: a :class:`GemvRequest` names
one unit of simulator work (a PIM GEMV or the non-PIM baseline), and
:meth:`PimExecutor.run_many` plans every request eagerly, dedupes repeats,
pads all per-channel command streams into one flat fleet batch and
resolves them with a single ``engine.resolve_fleet`` call.  ``run_gemv`` /
``run_baseline`` are the one-request conveniences on top.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core import commands as C
from repro.core import controller, device, engine
from repro.core.energy import EnergyParams, gemv_energy_summary
from repro.core.timing import SystemSpec
from . import codegen
from .datamapper import DataMapper, PimLayout
from .gemv import GemvKernel, GemvStreams
from .tileconfig import PimDType, TileConfig


@dataclasses.dataclass(frozen=True)
class GemvRequest:
    """One unit of fleet work: a PIM GEMV point or its host baseline.

    ``key`` is the canonical dedupe/cache key — baseline timing depends
    only on (H, W, dtype), so the PIM-only knobs are excluded there.
    """

    H: int
    W: int
    dtype: PimDType
    fence: bool = False
    reshape: bool = False
    flush: str = "bus"
    kind: str = "pim"            # "pim" | "baseline"

    @staticmethod
    def pim(H: int, W: int, dtype: PimDType | str, *, fence: bool = False,
            reshape: bool = False, flush: str = "bus") -> "GemvRequest":
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        return GemvRequest(H, W, dtype, fence, reshape, flush, "pim")

    @staticmethod
    def baseline(H: int, W: int, dtype: PimDType | str) -> "GemvRequest":
        dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
        return GemvRequest(H, W, dtype, kind="baseline")

    @property
    def key(self) -> tuple:
        if self.kind == "baseline":
            return ("base", self.H, self.W, self.dtype)
        return ("pim", self.H, self.W, self.dtype, self.fence,
                self.reshape, self.flush)


@dataclasses.dataclass
class PlannedGemv:
    """A request with its layouts/programs/streams built, ready to time."""

    req: GemvRequest
    streams: list[np.ndarray]
    gs: GemvStreams | None = None      # pim requests only
    weight_bytes: int = 0              # baseline requests only


@dataclasses.dataclass
class PimResult:
    cycles: int                 # max over channels
    ns: float
    flops: int
    weight_bytes: int
    utilization: float
    split: int
    energy: dict
    counts: np.ndarray          # aggregated opcode histogram
    meta: dict

    @property
    def gflops(self) -> float:
        return self.flops / max(self.ns, 1e-9)


class PimExecutor:
    """Runtime control for GEMV offload on LP5X-PIM."""

    def __init__(self, spec: SystemSpec,
                 energy_params: EnergyParams | None = None):
        self.spec = spec
        self.cyc = spec.derive_cycles()
        self.mapper = DataMapper(spec)
        self.kernel = GemvKernel(spec)
        self.energy_params = energy_params or EnergyParams()

    # -- paper pipeline -------------------------------------------------
    def plan(self, H: int, W: int, dtype: PimDType,
             reshape: bool = False) -> tuple[PimLayout, codegen.PimProgram]:
        layout = self.mapper.layout(H, W, dtype, reshape=reshape)
        program = codegen.synthesize(layout.tc, self.spec.pim)
        return layout, program

    def build_streams(self, layout: PimLayout, program: codegen.PimProgram,
                      x: np.ndarray | None = None,
                      fence: bool = False,
                      flush: str = "bus") -> GemvStreams:
        return self.kernel.build(layout, program, x=x, fence=fence,
                                 flush=flush)

    def time_streams(self, gs: GemvStreams) -> PimResult:
        _, totals = engine.run_streams(self.cyc, gs.streams)
        return self._pim_result(gs, totals)

    def run_gemv(self, H: int, W: int, dtype: PimDType,
                 fence: bool = False, reshape: bool = False,
                 flush: str = "bus") -> PimResult:
        """Timing-only GEMV simulation (the Fig. 4 path)."""
        layout, program = self.plan(H, W, dtype, reshape=reshape)
        gs = self.build_streams(layout, program, fence=fence, flush=flush)
        return self.time_streams(gs)

    def run_gemv_functional(self, weights: np.ndarray, x: np.ndarray,
                            dtype: PimDType, fence: bool = False,
                            reshape: bool = False
                            ) -> tuple[np.ndarray, PimResult]:
        """Full HW/SW co-simulation: returns (y, timing result)."""
        H, W = weights.shape
        layout, program = self.plan(H, W, dtype, reshape=reshape)
        dram = self.mapper.pack(layout, weights)
        gs = self.build_streams(layout, program, x=x, fence=fence)
        y = device.execute_gemv(layout, program, dram, gs.streams,
                                gs.payloads)
        return y, self.time_streams(gs)

    # -- fleet API -------------------------------------------------------
    def plan_many(self, reqs: Iterable[GemvRequest]) -> list[PlannedGemv]:
        """Build every layout/program/stream eagerly (no timing yet)."""
        out = []
        for r in reqs:
            if r.kind == "baseline":
                total_bytes = r.H * r.W * r.dtype.w_bits // 8
                per_ch = -(-total_bytes // self.spec.num_channels)
                stream = controller.sequential_read_stream(per_ch, self.spec)
                out.append(PlannedGemv(
                    req=r, streams=[stream] * self.spec.num_channels,
                    weight_bytes=total_bytes))
            else:
                layout, program = self.plan(r.H, r.W, r.dtype,
                                            reshape=r.reshape)
                gs = self.build_streams(layout, program, fence=r.fence,
                                        flush=r.flush)
                out.append(PlannedGemv(req=r, streams=gs.streams, gs=gs))
        return out

    def run_many(self, reqs: Sequence[GemvRequest]) -> list[PimResult]:
        """Resolve many requests through ONE batched engine call.

        Duplicate requests (by ``key``) are planned and timed once; the
        returned list matches the input order.  Results are bit-identical
        to the per-call ``run_gemv`` / ``run_baseline`` paths.
        """
        reqs = list(reqs)
        uniq: dict[tuple, GemvRequest] = {}
        for r in reqs:
            uniq.setdefault(r.key, r)
        planned = self.plan_many(uniq.values())
        fleet = engine.resolve_fleet(
            [(self.cyc, p.streams) for p in planned])
        by_key = {p.req.key: self._finish(p, fr.totals)
                  for p, fr in zip(planned, fleet)}
        return [by_key[r.key] for r in reqs]

    def _finish(self, p: PlannedGemv, totals: np.ndarray) -> PimResult:
        if p.req.kind == "baseline":
            return self._baseline_result(p.req, p.streams, totals,
                                         p.weight_bytes)
        return self._pim_result(p.gs, totals)

    # -- result assembly -------------------------------------------------
    def _pim_result(self, gs: GemvStreams,
                    totals: np.ndarray) -> PimResult:
        cycles = int(totals.max()) if totals.size else 0
        counts = sum((C.op_counts(s) for s in gs.streams),
                     np.zeros(C.NUM_OPCODES, dtype=np.int64))
        active = max(1, int(round(16 * gs.layout.utilization)))
        energy = gemv_energy_summary(gs.streams, totals, self.spec,
                                     gs.meta["flops"], self.energy_params,
                                     active_banks=active)
        return PimResult(
            cycles=cycles,
            ns=cycles * self.cyc.tck_ns,
            flops=gs.meta["flops"],
            weight_bytes=gs.meta["weight_bytes"],
            utilization=gs.meta["utilization"],
            split=gs.meta["split"],
            energy=energy,
            counts=counts,
            meta=gs.meta,
        )

    def _baseline_result(self, req: GemvRequest, streams: list[np.ndarray],
                         totals: np.ndarray, total_bytes: int) -> PimResult:
        cycles = int(totals.max()) if totals.size else 0
        counts = sum((C.op_counts(s) for s in streams),
                     np.zeros(C.NUM_OPCODES, dtype=np.int64))
        energy = gemv_energy_summary(streams, totals, self.spec,
                                     2 * req.H * req.W, self.energy_params)
        return PimResult(cycles=cycles, ns=cycles * self.cyc.tck_ns,
                         flops=2 * req.H * req.W,
                         weight_bytes=total_bytes,
                         utilization=1.0, split=1, energy=energy,
                         counts=counts, meta=dict(kind="baseline"))

    # -- non-PIM baseline (Fig. 4 normalization) --------------------------
    def run_baseline(self, H: int, W: int, dtype: PimDType) -> PimResult:
        """Sequential weight read on a non-PIM system (all channels)."""
        return self.run_many([GemvRequest.baseline(H, W, dtype)])[0]
