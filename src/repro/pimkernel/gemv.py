"""GEMV Kernel (paper §2.2, PIM Executor sub-component 3).

"Executes General Matrix-Vector Multiplication on a per-tile basis using
the specialized PIM ISA and manages pipeline flush-out operations."

Given a :class:`PimLayout` (Data Mapper) and a :class:`PimProgram` (Code
Gen) this module synthesizes the per-channel command streams:

    MODE_MB · IRF setup
    per round:   per chunk:  [FENCE] · chunk config · SRF broadcast fill
                             ACT_MB/MAC sweep (row-buffer aware) · PRE_MB
                 [FENCE] · ACC flush-out (RD_ACC per active bank)
    MODE_SB

The same structure drives both the timing engine (issue cycles) and the
functional device interpreter (`core/device.py`), which is what ties the
HW and SW models together "organically" as the paper puts it: one command
stream, two views.

Synthesis is *block-vectorized*: each round is assembled from numpy
blocks (the ACT/MAC row sweep, SRF fill, and flush-out sections are pure
column/row arithmetic, never per-command ``emit()``), all channel-
independent round structure is computed once per round, and channels
whose active (round, bank-set) sequences coincide — the common case in a
round-robin block placement — share one stream ndarray, one payload dict
and one *structural stream key* (``GemvStreams.stream_keys``), which is
what lets the engine dedupe/cache lanes without hashing the bytes.
``build_reference`` retains the original per-command ``StreamBuilder``
path as the parity oracle for the vectorized synthesizer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commands as C
from repro.core.commands import StreamBuilder, repeat_block, single
from . import codegen
from .control import FencePolicy, PimControl
from .datamapper import PimLayout

BURST = 32

_FENCE = single(C.FENCE)
_PRE_MB = single(C.PRE_MB)
_MODE_MB = single(C.MODE_MB)
_MODE_SB = single(C.MODE_SB)
_EMPTY = np.zeros((0, 4), dtype=np.int32)


@dataclasses.dataclass
class GemvStreams:
    """Per-channel command streams + WR_SRF payload side-band.

    ``stream_keys`` (vectorized builds only) carries one hashable
    structural identity per channel: equal keys guarantee byte-identical
    streams, so ``engine.resolve_lanes`` can dedupe and LRU-cache lanes
    without re-hashing them.
    """

    streams: list[np.ndarray]
    payloads: list[dict[int, np.ndarray]]
    layout: PimLayout
    meta: dict
    stream_keys: list | None = None


@dataclasses.dataclass
class _ChunkPlan:
    """Channel-independent structure of one (round, chunk) tile step."""

    header: np.ndarray          # WR_IRF chunk marker + re-config block
    srf: np.ndarray             # WR_SRF broadcast fill block
    srf_meta: list              # (w_tile, j) per WR_SRF command, in order
    mac: np.ndarray             # (n_bursts, 4) MAC block, rows/cols filled
    trans: np.ndarray           # burst indices that open a new row
    trans_rows: np.ndarray      # the row opened at each transition


class GemvKernel:
    """Stateless stream synthesizer: the spec rides on the layout."""

    def build(self, layout: PimLayout, program: codegen.PimProgram,
              x: np.ndarray | None = None,
              fence: bool = False, flush: str = "bus") -> GemvStreams:
        """Synthesize command streams (and payloads when ``x`` given).

        ``flush``: "bus" reads accumulators to the host over the data
        bus (RD_ACC); "dram" moves them into DRAM internally (MOV_ACC —
        the paper's "accumulation register-to-DRAM data movements"), the
        host reading y later with normal SB reads.

        Byte-identical to :meth:`build_reference` (asserted by the
        parity suite); ~an order of magnitude faster because rounds are
        numpy blocks shared across channels with equal round-sets.
        """
        spec = layout.spec
        policy = FencePolicy(per_tile=fence)
        xpad = None
        if x is not None:
            xpad = np.zeros(layout.padded_w, dtype=np.asarray(x).dtype)
            xpad[: layout.W] = x

        # Per-channel round-set: the only channel-dependent inputs of the
        # synthesis are which rounds a channel participates in and with
        # which (rank, bank) blocks.
        per_ch = []
        for ch in range(spec.num_channels):
            rnds = []
            for rnd in range(layout.rounds):
                banks = layout.active_banks(rnd, ch)
                if banks:
                    rnds.append((rnd, tuple(banks)))
            per_ch.append(tuple(rnds))

        base_key = ("gemv", layout.spec, layout.H, layout.W,
                    layout.tc.dtype, layout.split, bool(fence), flush)
        round_cache: dict[int, list[_ChunkPlan]] = {}
        raw_cache: dict[int, np.ndarray] = {}
        built: dict[tuple, tuple[np.ndarray, dict]] = {}
        streams, payloads, stream_keys = [], [], []
        for cls in per_ch:
            ent = built.get(cls)
            if ent is None:
                ent = self._build_class(layout, program, cls, policy,
                                        flush, xpad, round_cache,
                                        raw_cache)
                built[cls] = ent
            streams.append(ent[0])
            payloads.append(ent[1])
            stream_keys.append(base_key + (cls,))

        meta = dict(
            flops=layout.flops,
            weight_bytes=layout.weight_bytes,
            utilization=layout.utilization,
            split=layout.split,
            rounds=layout.rounds,
            tiles=layout.n_htiles * layout.n_wtiles,
        )
        return GemvStreams(streams, payloads, layout, meta,
                           stream_keys=stream_keys)

    # -- vectorized synthesis ------------------------------------------
    def _round_plan(self, layout: PimLayout, program: codegen.PimProgram,
                    rnd: int, page: int) -> list[_ChunkPlan]:
        """Channel-independent blocks of one round (cached per build)."""
        tc = layout.tc
        plans: list[_ChunkPlan] = []
        prev_row = -1                   # open-row chains across chunks
        for chunk in range(layout.group_w):
            groups = layout.active_groups(rnd, chunk)
            if not groups:
                continue
            header = np.concatenate([
                single(C.WR_IRF, a=rnd % (1 << 15), b=1, c=chunk),
                repeat_block(C.WR_IRF, program.chunk_cfg_cmds - 1)])
            n_srf = len(groups) * tc.srf_wr_cmds
            srf = np.zeros((n_srf, 4), dtype=np.int32)
            srf[:, 0] = C.WR_SRF
            srf[:, 1] = np.repeat(np.asarray(groups, np.int32),
                                  tc.srf_wr_cmds)
            srf[:, 2] = np.tile(np.arange(tc.srf_wr_cmds, dtype=np.int32),
                                len(groups))
            srf_meta = [(layout.w_tile_at(g, chunk), j)
                        for g in groups for j in range(tc.srf_wr_cmds)]

            n_bursts = layout.max_bursts(rnd, chunk)
            offs = (layout.chunk_offset(rnd, chunk)
                    + BURST * np.arange(n_bursts, dtype=np.int64))
            rows = (offs // page).astype(np.int32)
            mac = np.zeros((n_bursts, 4), dtype=np.int32)
            mac[:, 0] = C.MAC
            mac[:, 2] = rows
            mac[:, 3] = (offs % page) // BURST
            first_new = rows[0] != prev_row if n_bursts else False
            interior = np.flatnonzero(rows[1:] != rows[:-1]) + 1
            trans = (np.concatenate([[0], interior]) if first_new
                     else interior).astype(np.int64)
            plans.append(_ChunkPlan(header=header, srf=srf,
                                    srf_meta=srf_meta, mac=mac,
                                    trans=trans, trans_rows=rows[trans]))
            if n_bursts:
                prev_row = int(rows[-1])
        return plans

    def _build_class(self, layout: PimLayout, program: codegen.PimProgram,
                     cls: tuple, policy: FencePolicy, flush: str,
                     xpad, round_cache: dict, raw_cache: dict
                     ) -> tuple[np.ndarray, dict]:
        """Assemble one channel-class stream from per-round blocks."""
        if not cls:
            return _EMPTY, {}
        tc = layout.tc
        page = layout.spec.timings.page_bytes
        blocks: list[np.ndarray] = [_MODE_MB,
                                    repeat_block(C.WR_IRF,
                                                 program.setup_cmds)]
        pay_meta: list = []
        any_tile = False
        for rnd, banks in cls:
            plans = round_cache.get(rnd)
            if plans is None:
                plans = round_cache[rnd] = self._round_plan(
                    layout, program, rnd, page)
            quads = sorted({bank % 4 for _rank, bank in banks})
            opened = False
            for p in plans:
                if policy.per_tile and any_tile:
                    blocks.append(_FENCE)
                blocks.append(p.header)
                blocks.append(p.srf)
                pay_meta.extend(p.srf_meta)
                # ACT/MAC row sweep: MAC runs split at row transitions,
                # each opening PRE_MB (if a row is open) + ACT_MB x quads.
                k = p.trans.shape[0]
                if k:
                    acts = np.zeros((k, len(quads), 4), dtype=np.int32)
                    acts[:, :, 0] = C.ACT_MB
                    acts[:, :, 1] = np.asarray(quads, np.int32)
                    acts[:, :, 2] = p.trans_rows[:, None]
                    bounds = np.append(p.trans, p.mac.shape[0])
                    if p.trans[0] > 0:
                        blocks.append(p.mac[: p.trans[0]])
                    for j in range(k):
                        if opened:
                            blocks.append(_PRE_MB)
                        opened = True
                        blocks.append(acts[j])
                        blocks.append(p.mac[bounds[j]: bounds[j + 1]])
                elif p.mac.shape[0]:
                    blocks.append(p.mac)
                if policy.per_tile and policy.double:
                    blocks.append(_FENCE)
                any_tile = True
            # Flush-out: close rows, move accumulators out of the blocks.
            if policy.per_tile and policy.before_flush and not policy.double:
                blocks.append(_FENCE)
            if opened:
                blocks.append(_PRE_MB)
            if flush == "dram":
                blocks.append(repeat_block(C.MOV_ACC, tc.acc_rd_cmds))
            else:
                n_rd = len(banks) * tc.acc_rd_cmds
                rd = np.zeros((n_rd, 4), dtype=np.int32)
                rd[:, 0] = C.RD_ACC
                rd[:, 1] = np.repeat([bank for _r, bank in banks],
                                     tc.acc_rd_cmds)
                rd[:, 2] = np.repeat([rank for rank, _b in banks],
                                     tc.acc_rd_cmds)
                rd[:, 3] = np.tile(np.arange(tc.acc_rd_cmds,
                                             dtype=np.int32), len(banks))
                blocks.append(rd)
        blocks.append(_MODE_SB)
        stream = np.concatenate(blocks, axis=0)

        pay: dict[int, np.ndarray] = {}
        if xpad is not None:
            positions = np.flatnonzero(stream[:, 0] == C.WR_SRF)
            for pos, (w_tile, j) in zip(positions, pay_meta):
                raw = raw_cache.get(w_tile)
                if raw is None:
                    seg = xpad[w_tile * tc.t_w:(w_tile + 1) * tc.t_w]
                    raw = codegen.encode_acts(seg, tc.dtype)
                    raw = np.pad(raw,
                                 (0, tc.srf_wr_cmds * BURST - raw.size))
                    raw_cache[w_tile] = raw
                pay[int(pos)] = raw[j * BURST:(j + 1) * BURST]
        return stream, pay

    # -- reference (per-command) synthesis -----------------------------
    def build_reference(self, layout: PimLayout,
                        program: codegen.PimProgram,
                        x: np.ndarray | None = None,
                        fence: bool = False,
                        flush: str = "bus") -> GemvStreams:
        """The original per-command ``StreamBuilder`` path.

        Retained as the oracle for the vectorized synthesizer: the
        parity tests (and ``benchmarks/fleet_speed.py`` plan rows)
        assert :meth:`build` produces byte-identical streams/payloads.
        """
        spec = layout.spec
        page = spec.timings.page_bytes
        xpad = None
        if x is not None:
            xpad = np.zeros(layout.padded_w, dtype=np.asarray(x).dtype)
            xpad[: layout.W] = x

        streams, payloads = [], []
        for ch in range(spec.num_channels):
            b = StreamBuilder()
            pay: dict[int, np.ndarray] = {}
            ctl = PimControl(b, FencePolicy(per_tile=fence))
            ch_rounds = [r for r in range(layout.rounds)
                         if layout.active_banks(r, ch)]
            if ch_rounds:
                ctl.enter_mb()
                b.emit_repeat(C.WR_IRF, program.setup_cmds, a=0, b=0)
                for rnd in ch_rounds:
                    self._round(b, pay, ctl, layout, program, rnd, ch,
                                xpad, page, flush)
                ctl.enter_sb()
            streams.append(b.build())
            payloads.append(pay)

        meta = dict(
            flops=layout.flops,
            weight_bytes=layout.weight_bytes,
            utilization=layout.utilization,
            split=layout.split,
            rounds=layout.rounds,
            tiles=layout.n_htiles * layout.n_wtiles,
        )
        return GemvStreams(streams, payloads, layout, meta)

    # ------------------------------------------------------------------
    def _round(self, b: StreamBuilder, pay: dict, ctl: PimControl,
               layout: PimLayout, program: codegen.PimProgram, rnd: int,
               ch: int, xpad, page: int, flush: str = "bus") -> None:
        tc = layout.tc
        banks = layout.active_banks(rnd, ch)
        quads = sorted({bank % 4 for _, bank in banks})
        open_row = -1

        for chunk in range(layout.group_w):
            groups = layout.active_groups(rnd, chunk)
            if not groups:
                continue
            ctl.tile_begin()
            # chunk re-config (marks chunk start for the interpreter:
            # b-field 1 = chunk-start flag, c-field = chunk index).
            b.emit(C.WR_IRF, a=rnd % (1 << 15), b=1, c=chunk)
            if program.chunk_cfg_cmds > 1:
                b.emit_repeat(C.WR_IRF, program.chunk_cfg_cmds - 1,
                              a=0, b=0)
            # SRF broadcast fill, one pass per split group.
            for g in groups:
                w_tile = layout.w_tile_at(g, chunk)
                if xpad is not None:
                    seg = xpad[w_tile * tc.t_w:(w_tile + 1) * tc.t_w]
                    raw = codegen.encode_acts(seg, tc.dtype)
                    raw = np.pad(raw, (0, tc.srf_wr_cmds * BURST - raw.size))
                for j in range(tc.srf_wr_cmds):
                    if xpad is not None:
                        pay[len(b)] = raw[j * BURST:(j + 1) * BURST]
                    b.emit(C.WR_SRF, a=g, b=j)
            # MAC sweep over the tile bytes, row-buffer aware.
            n_bursts = layout.max_bursts(rnd, chunk)
            off = layout.chunk_offset(rnd, chunk)
            emitted = 0
            while emitted < n_bursts:
                row = off // page
                if row != open_row:
                    if open_row >= 0:
                        b.emit(C.PRE_MB)
                    for q in quads:
                        b.emit(C.ACT_MB, a=q, b=row)
                    open_row = row
                col0 = (off % page) // BURST
                n = min(n_bursts - emitted, page // BURST - col0)
                b.emit_repeat(C.MAC, n, a=0, b=row, c_start=col0)
                emitted += n
                off += n * BURST
            ctl.tile_end()
        # Flush-out: close rows, move accumulators out of the blocks.
        ctl.flush_boundary()
        if open_row >= 0:
            b.emit(C.PRE_MB)
        if flush == "dram":
            # internal ACC->DRAM move (broadcast, no data-bus usage);
            # the host reads y later with standard SB-mode reads.
            b.emit_repeat(C.MOV_ACC, tc.acc_rd_cmds)
        else:
            for rank, bank in banks:
                b.emit_repeat(C.RD_ACC, tc.acc_rd_cmds, a=bank, b=rank)
