"""GEMV Kernel (paper §2.2, PIM Executor sub-component 3).

"Executes General Matrix-Vector Multiplication on a per-tile basis using
the specialized PIM ISA and manages pipeline flush-out operations."

Given a :class:`PimLayout` (Data Mapper) and a :class:`PimProgram` (Code
Gen) this module synthesizes the per-channel command streams:

    MODE_MB · IRF setup
    per round:   per chunk:  [FENCE] · chunk config · SRF broadcast fill
                             ACT_MB/MAC sweep (row-buffer aware) · PRE_MB
                 [FENCE] · ACC flush-out (RD_ACC per active bank)
    MODE_SB

The same structure drives both the timing engine (issue cycles) and the
functional device interpreter (`core/device.py`), which is what ties the
HW and SW models together "organically" as the paper puts it: one command
stream, two views.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commands as C
from repro.core.commands import StreamBuilder
from . import codegen
from .control import FencePolicy, PimControl
from .datamapper import PimLayout

BURST = 32


@dataclasses.dataclass
class GemvStreams:
    """Per-channel command streams + WR_SRF payload side-band."""

    streams: list[np.ndarray]
    payloads: list[dict[int, np.ndarray]]
    layout: PimLayout
    meta: dict


class GemvKernel:
    """Stateless stream synthesizer: the spec rides on the layout."""

    def build(self, layout: PimLayout, program: codegen.PimProgram,
              x: np.ndarray | None = None,
              fence: bool = False, flush: str = "bus") -> GemvStreams:
        """Synthesize command streams (and payloads when ``x`` given).

        ``flush``: "bus" reads accumulators to the host over the data
        bus (RD_ACC); "dram" moves them into DRAM internally (MOV_ACC —
        the paper's "accumulation register-to-DRAM data movements"), the
        host reading y later with normal SB reads.
        """
        spec = layout.spec
        page = spec.timings.page_bytes
        xpad = None
        if x is not None:
            xpad = np.zeros(layout.padded_w, dtype=np.asarray(x).dtype)
            xpad[: layout.W] = x

        streams, payloads = [], []
        for ch in range(spec.num_channels):
            b = StreamBuilder()
            pay: dict[int, np.ndarray] = {}
            ctl = PimControl(b, FencePolicy(per_tile=fence))
            ch_rounds = [r for r in range(layout.rounds)
                         if layout.active_banks(r, ch)]
            if ch_rounds:
                ctl.enter_mb()
                b.emit_repeat(C.WR_IRF, program.setup_cmds, a=0, b=0)
                for rnd in ch_rounds:
                    self._round(b, pay, ctl, layout, program, rnd, ch,
                                xpad, page, flush)
                ctl.enter_sb()
            streams.append(b.build())
            payloads.append(pay)

        meta = dict(
            flops=layout.flops,
            weight_bytes=layout.weight_bytes,
            utilization=layout.utilization,
            split=layout.split,
            rounds=layout.rounds,
            tiles=layout.n_htiles * layout.n_wtiles,
        )
        return GemvStreams(streams, payloads, layout, meta)

    # ------------------------------------------------------------------
    def _round(self, b: StreamBuilder, pay: dict, ctl: PimControl,
               layout: PimLayout, program: codegen.PimProgram, rnd: int,
               ch: int, xpad, page: int, flush: str = "bus") -> None:
        tc = layout.tc
        banks = layout.active_banks(rnd, ch)
        quads = sorted({bank % 4 for _, bank in banks})
        open_row = -1

        for chunk in range(layout.group_w):
            groups = layout.active_groups(rnd, chunk)
            if not groups:
                continue
            ctl.tile_begin()
            # chunk re-config (marks chunk start for the interpreter:
            # b-field 1 = chunk-start flag, c-field = chunk index).
            b.emit(C.WR_IRF, a=rnd % (1 << 15), b=1, c=chunk)
            if program.chunk_cfg_cmds > 1:
                b.emit_repeat(C.WR_IRF, program.chunk_cfg_cmds - 1,
                              a=0, b=0)
            # SRF broadcast fill, one pass per split group.
            for g in groups:
                w_tile = layout.w_tile_at(g, chunk)
                if xpad is not None:
                    seg = xpad[w_tile * tc.t_w:(w_tile + 1) * tc.t_w]
                    raw = codegen.encode_acts(seg, tc.dtype)
                    raw = np.pad(raw, (0, tc.srf_wr_cmds * BURST - raw.size))
                for j in range(tc.srf_wr_cmds):
                    if xpad is not None:
                        pay[len(b)] = raw[j * BURST:(j + 1) * BURST]
                    b.emit(C.WR_SRF, a=g, b=j)
            # MAC sweep over the tile bytes, row-buffer aware.
            n_bursts = layout.max_bursts(rnd, chunk)
            off = layout.chunk_offset(rnd, chunk)
            emitted = 0
            while emitted < n_bursts:
                row = off // page
                if row != open_row:
                    if open_row >= 0:
                        b.emit(C.PRE_MB)
                    for q in quads:
                        b.emit(C.ACT_MB, a=q, b=row)
                    open_row = row
                col0 = (off % page) // BURST
                n = min(n_bursts - emitted, page // BURST - col0)
                b.emit_repeat(C.MAC, n, a=0, b=row, c_start=col0)
                emitted += n
                off += n * BURST
            ctl.tile_end()
        # Flush-out: close rows, move accumulators out of the blocks.
        ctl.flush_boundary()
        if open_row >= 0:
            b.emit(C.PRE_MB)
        if flush == "dram":
            # internal ACC->DRAM move (broadcast, no data-bus usage);
            # the host reads y later with standard SB-mode reads.
            b.emit_repeat(C.MOV_ACC, tc.acc_rd_cmds)
        else:
            for rank, bank in banks:
                b.emit_repeat(C.RD_ACC, tc.acc_rd_cmds, a=bank, b=rank)
