"""PIM tile configuration (paper Fig. 3).

The tile size is "constrained by the capacities of the PIM block's
input/output register files and the data precision" (§2.3):

* ``T_w`` — number of input-vector elements a tile consumes = SRF capacity
  in bits / activation bits.
* ``T_h`` — number of output rows a tile produces = number of 32-bit
  accumulator registers.

With the default ``PimSpec`` (SRF = 512 B, 64 ACC regs) this yields the
paper's large-tile group (W8A8, W4A4, FP-W8A8: T_w >= 512) and small-tile
group (W8A16, W4A16, FP-W8A16: T_w = 256), reproducing the SRF-write
frequency argument for their speedup gap.
"""
from __future__ import annotations

import dataclasses
import enum
import math

from repro.core.timing import PimSpec


class PimDType(enum.Enum):
    """Weight/activation precision formats evaluated in the paper."""

    W8A8 = ("int", 8, 8)
    W4A4 = ("int", 4, 4)
    W8A16 = ("int", 8, 16)
    W4A8 = ("int", 4, 8)
    W4A16 = ("int", 4, 16)
    FP_W8A8 = ("fp", 8, 8)
    FP_W8A16 = ("fp", 8, 16)

    def __init__(self, kind: str, w_bits: int, a_bits: int):
        self.kind = kind
        self.w_bits = w_bits
        self.a_bits = a_bits

    @property
    def is_fp(self) -> bool:
        return self.kind == "fp"

    @property
    def w_bytes(self) -> float:
        return self.w_bits / 8

    @property
    def a_bytes(self) -> float:
        return self.a_bits / 8

    @classmethod
    def parse(cls, name: str) -> "PimDType":
        return cls[name.upper().replace("-", "_")]


ALL_DTYPES = list(PimDType)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Concrete PIM tile geometry for one dtype under one PimSpec."""

    dtype: PimDType
    t_h: int                 # rows per tile (ACC registers)
    t_w: int                 # input elements per tile (SRF capacity)
    tile_w_bytes: int        # weight bytes per tile = t_h * t_w * w_bits/8
    srf_chunk_bytes: int     # activation bytes per SRF fill = t_w * a_bits/8
    srf_wr_cmds: int         # WR_SRF commands per SRF fill (32 B each)
    macs_per_tile: int       # 32 B weight bursts per tile
    acc_rd_cmds: int         # RD_ACC bursts to flush one bank's ACC file

    @classmethod
    def make(cls, dtype: PimDType, pim: PimSpec,
             burst_bytes: int = 32) -> "TileConfig":
        t_w = pim.srf_bytes * 8 // dtype.a_bits
        t_h = pim.acc_regs
        tile_w_bytes = t_h * t_w * dtype.w_bits // 8
        srf_chunk = t_w * dtype.a_bits // 8
        return cls(
            dtype=dtype,
            t_h=t_h,
            t_w=t_w,
            tile_w_bytes=tile_w_bytes,
            srf_chunk_bytes=srf_chunk,
            srf_wr_cmds=int(math.ceil(srf_chunk / burst_bytes)),
            macs_per_tile=int(math.ceil(tile_w_bytes / burst_bytes)),
            acc_rd_cmds=int(math.ceil(pim.acc_file_bytes / burst_bytes)),
        )

    def tiles_for(self, h: int, w: int) -> tuple[int, int]:
        """Number of (h, w) tiles covering an H x W matrix."""
        return (int(math.ceil(h / self.t_h)), int(math.ceil(w / self.t_w)))
