"""PIM Control (paper §2.2, PIM Executor sub-component 2).

Manages system-wide control logic: transitions between Single-Bank (SB)
mode — standard DRAM operation — and Multi-Bank (MB) mode — broadcast PIM
execution across banks — plus the memory-fence policy of §3.2 ("fences
between successive tiles strictly guarantee inter-tile execution order").
"""
from __future__ import annotations

import dataclasses

from repro.core import commands as C
from repro.core.commands import StreamBuilder


@dataclasses.dataclass
class FencePolicy:
    """Where fences are inserted.  `per_tile` reproduces the paper §3.2.

    A per-tile ordering point needs two fences in a real driver: one before
    the operand update (the next tile's SRF write must not overtake the
    previous tile's MACs) and one after the tile's compute phase (the next
    tile's commands must not be reordered before it).  ``double`` models
    that; with it disabled only the inter-tile fence is emitted.
    """

    per_tile: bool = False      # FENCE around successive tile (chunk) steps
    double: bool = True         # operand-ordering fence + inter-tile fence
    before_flush: bool = True   # FENCE before ACC readout (result ordering)


class PimControl:
    """Tracks SB/MB mode and emits transition / fence commands."""

    def __init__(self, builder: StreamBuilder,
                 policy: FencePolicy | None = None):
        self.b = builder
        self.policy = policy or FencePolicy()
        self.mode = 0  # SB
        self._any_tile_done = False

    def enter_mb(self) -> None:
        if self.mode != 1:
            self.b.emit(C.MODE_MB)
            self.mode = 1

    def enter_sb(self) -> None:
        if self.mode != 0:
            self.b.emit(C.MODE_SB)
            self.mode = 0

    def tile_begin(self) -> None:
        """Operand-ordering fence before each tile step after the first."""
        if self.policy.per_tile and self._any_tile_done:
            self.b.emit(C.FENCE)

    def tile_end(self) -> None:
        """Inter-tile ordering fence after each tile's compute phase."""
        if self.policy.per_tile and self.policy.double:
            self.b.emit(C.FENCE)
        self._any_tile_done = True

    def flush_boundary(self) -> None:
        if (self.policy.per_tile and self.policy.before_flush
                and not self.policy.double):
            self.b.emit(C.FENCE)
