"""PIM Kernel software layer (paper §2.2): Data Mapper + PIM Executor."""
from .tileconfig import PimDType, TileConfig, ALL_DTYPES  # noqa: F401
from .datamapper import DataMapper, PimLayout  # noqa: F401
from .executor import PimExecutor, PimResult  # noqa: F401
