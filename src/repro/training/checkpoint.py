"""Sharded, atomic, async checkpointing with elastic restore.

Layout: one directory per step::

    ckpt_dir/step_000120/
        manifest.json     # tree structure, shapes, dtypes, step
        <leaf-path>.npy   # one file per pytree leaf (host-local shard
                          #  on multi-host; full array in this container)

Guarantees:
  * atomic: written to step_xxx.tmp, fsync'd, then renamed — a crash
    mid-save never corrupts the latest checkpoint (restart-safe);
  * async: ``AsyncCheckpointer.save`` snapshots to host memory on the
    training thread and writes on a background thread (overlaps I/O with
    the next steps — the distributed-optimization trick of hiding ckpt
    latency);
  * elastic restore: ``restore`` takes the *target* shardings, so a
    checkpoint written on one mesh loads onto a different mesh/pod count
    (node-failure recovery with changed topology re-shards at load).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_paths(tree):
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        paths.append(name.replace("/", "__"))
    return paths


def save(ckpt_dir, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    paths = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for name, leaf in zip(paths, leaves):
        arr = np.asarray(leaf)
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            dict(name=name, shape=list(arr.shape), dtype=str(arr.dtype)))
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    fd = os.open(tmp, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir, tree_like, step: int | None = None,
            shardings=None) -> tuple:
    """Load into the structure of ``tree_like``; re-shard onto
    ``shardings`` (elastic: target mesh may differ from the writer's)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(tree_like)
    paths = _leaf_paths(tree_like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for name, ref, sh in zip(paths, leaves, shard_leaves):
        arr = np.load(d / f"{name}.npy")
        assert list(arr.shape) == list(ref.shape), \
            f"{name}: ckpt {arr.shape} vs model {ref.shape}"
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot (sync point)

        def work():
            save(self.ckpt_dir, step, host_tree, extra)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(p for p in self.ckpt_dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
