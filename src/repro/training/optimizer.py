"""AdamW + gradient clipping + LR schedules, pure JAX (no optax).

State layout keeps (m, v) with the same pytree structure and sharding as
the parameters, so ZeRO-style sharding rules apply to optimizer state for
free (the dry-run shards opt state with the params).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params: PyTree, grads: PyTree, state: AdamWState,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(step, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
