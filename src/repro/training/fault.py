"""Fault tolerance: heartbeats, straggler mitigation, elastic re-meshing.

This container has one host, so the multi-host control plane is modeled
exactly the way a real deployment drills it: a :class:`HeartbeatMonitor`
tracks per-host liveness (tests inject failures), a
:class:`StragglerDetector` flags slow steps from the step-time stream, and
:func:`elastic_plan` computes the survivor mesh + restore plan after a
failure.  ``launch/train.py`` wires these into the training loop: on a
detected failure the loop rebuilds the mesh from survivors, restores the
latest checkpoint with the new shardings (checkpoint.restore is elastic)
and continues — the standard checkpoint/restart story for 1000+ nodes,
where MTBF makes this path hot.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.faults import SYSTEM_CLOCK


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    """Tracks host liveness from heartbeat timestamps.

    ``clock`` is any ``time.monotonic``-style callable; the default is
    the process :data:`repro.core.faults.SYSTEM_CLOCK`, and tests pass
    :class:`repro.core.faults.VirtualClock` — the same injectable clock
    the serving retry/backoff path uses, so no fault-tolerance test
    ever real-sleeps."""

    def __init__(self, n_hosts: int, timeout_s: float = 30.0,
                 clock=SYSTEM_CLOCK):
        self.timeout = timeout_s
        self.clock = clock
        now = clock()
        self.hosts = {i: HostState(i, now) for i in range(n_hosts)}

    def beat(self, host_id: int):
        h = self.hosts[host_id]
        h.last_beat = self.clock()
        h.alive = True

    def sweep(self) -> list[int]:
        """Returns newly-dead host ids."""
        now = self.clock()
        dead = []
        for h in self.hosts.values():
            if h.alive and now - h.last_beat > self.timeout:
                h.alive = False
                dead.append(h.host_id)
        return dead

    @property
    def alive_hosts(self) -> list[int]:
        return [i for i, h in self.hosts.items() if h.alive]


class StragglerDetector:
    """Flags steps slower than ``threshold`` x rolling median.

    Mitigation hooks: the trainer can (a) exclude the straggler host from
    the next data-parallel assignment (elastic_plan), or (b) lower its
    microbatch count (returned advice).
    """

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.times: deque = deque(maxlen=window)
        self.threshold = threshold
        self.events: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold * med:
                is_straggler = True
                self.events.append((step, dt))
        self.times.append(dt)
        return is_straggler

    def advice(self) -> str:
        if len(self.events) >= 3:
            return "persistent"   # re-mesh without the slow host
        if self.events:
            return "transient"    # keep, maybe shrink its microbatch
        return "none"


@dataclasses.dataclass
class ElasticPlan:
    n_hosts: int
    data_parallel: int
    drop_batch: int        # global batch shrink to stay divisible
    restore_step: int | None


def elastic_plan(alive_hosts: list[int], devices_per_host: int,
                 model_parallel: int, global_batch: int,
                 latest_ckpt: int | None) -> ElasticPlan:
    """Survivor topology after failures: keep model-parallel intact,
    shrink the data-parallel axis to what the survivors support."""
    n_dev = len(alive_hosts) * devices_per_host
    if n_dev < model_parallel:
        raise RuntimeError(
            f"not enough devices ({n_dev}) for model parallel "
            f"{model_parallel}")
    dp = n_dev // model_parallel
    # largest batch <= global_batch divisible by the new dp degree
    batch = (global_batch // dp) * dp
    return ElasticPlan(n_hosts=len(alive_hosts), data_parallel=dp,
                       drop_batch=global_batch - batch,
                       restore_step=latest_ckpt)
