"""Training loop: microbatched gradient accumulation, compression,
async checkpointing, fault hooks.

The step function keeps the accumulation loop *inside* jit as a
``lax.scan`` over microbatches: XLA overlaps each microbatch's
reduce-scatter/all-gather traffic with the next microbatch's compute
(compute/comm overlap without manual double buffering).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from . import checkpoint as CKPT
from .fault import StragglerDetector
from .grad_compress import (CompressionConfig, apply_with_error_feedback,
                            init_error_state)
from .optimizer import adamw_init, adamw_update, cosine_schedule

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    microbatches: int = 1
    compression: CompressionConfig = dataclasses.field(
        default_factory=lambda: CompressionConfig("none"))
    ckpt_every: int = 100
    ckpt_dir: str = "checkpoints"
    remat: bool = True


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    """(params, opt, err, batch, step) -> (params, opt, err, metrics)."""

    def step_fn(params, opt, err, batch, step):
        nmb = tcfg.microbatches

        def one_micro(_, mb):
            def lf(p):
                return M.loss_fn(cfg, p, mb, remat=tcfg.remat)[0]
            return None, jax.value_and_grad(lf)(params)

        if nmb > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:]),
                batch)
            _, (losses, grads) = jax.lax.scan(one_micro, None, mbs)
            loss = losses.mean()
            grads = jax.tree.map(lambda g: g.mean(0), grads)
        else:
            _, (loss, grads) = one_micro(None, batch)

        grads, err = apply_with_error_feedback(grads, err,
                                               tcfg.compression)
        lr = cosine_schedule(step, tcfg.lr, tcfg.warmup, tcfg.total_steps)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, err, dict(loss=loss, lr=lr)

    return step_fn


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig, params=None,
                 key=None):
        self.cfg, self.tcfg = cfg, tcfg
        key = key if key is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else \
            M.init_params(cfg, key)
        self.opt = adamw_init(self.params)
        self.err = init_error_state(self.params)
        self.step = 0
        self.step_fn = jax.jit(make_train_step(cfg, tcfg),
                               donate_argnums=(0, 1, 2))
        self.ckpt = CKPT.AsyncCheckpointer(tcfg.ckpt_dir)
        self.straggler = StragglerDetector()
        self.history: list[dict] = []

    def restore_latest(self) -> bool:
        latest = CKPT.latest_step(self.tcfg.ckpt_dir)
        if latest is None:
            return False
        (self.params, self.opt), manifest = CKPT.restore(
            self.tcfg.ckpt_dir, (self.params, self.opt))
        self.step = manifest["step"]
        return True

    def train(self, batches, steps: int, log_every: int = 10) -> list:
        for _ in range(steps):
            batch = next(batches)
            t0 = time.perf_counter()
            self.params, self.opt, self.err, metrics = self.step_fn(
                self.params, self.opt, self.err, batch,
                jnp.asarray(self.step, jnp.int32))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(self.step, dt)
            self.step += 1
            rec = dict(step=self.step, loss=loss, dt=dt)
            self.history.append(rec)
            if self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, (self.params, self.opt))
        self.ckpt.wait()
        return self.history
