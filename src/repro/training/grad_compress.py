"""Gradient compression for the cross-pod all-reduce.

Two composable schemes with error feedback (the residual of the lossy
step is carried and added to the next gradient, preserving convergence):

* int8 quantization (4x over f32 / 2x over bf16): per-leaf absmax scale.
* top-k sparsification: keep the k largest-magnitude entries per leaf.

In the multi-pod mesh the pod axis carries only gradient all-reduce
traffic (DESIGN.md §4); compressing it attacks the slowest link in the
system.  The trainer applies compress -> psum(pod) -> decompress inside
the step, so XLA sees int8 collectives on the pod axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "int8"      # none | int8 | topk | int8+topk
    topk_frac: float = 0.01


def init_error_state(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_mask(g, frac: float):
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_decompress(g, cfg: CompressionConfig):
    """The lossy channel a gradient leaf passes through (round trip)."""
    out = g.astype(jnp.float32)
    if "topk" in cfg.scheme:
        out = out * topk_mask(out, cfg.topk_frac)
    if "int8" in cfg.scheme:
        q, s = quantize_int8(out)
        out = dequantize_int8(q, s)
    return out


def apply_with_error_feedback(grads: PyTree, err: PyTree,
                              cfg: CompressionConfig,
                              reduce_fn=None) -> tuple[PyTree, PyTree]:
    """grads -> (compressed+reduced grads, new error state).

    ``reduce_fn`` is the cross-pod reduction applied in compressed space
    (e.g. ``lambda q: jax.lax.pmean(q, 'pod')``); identity by default.
    """
    if cfg.scheme == "none":
        if reduce_fn is not None:
            grads = jax.tree.map(reduce_fn, grads)
        return grads, err

    def one(g, e):
        g = g.astype(jnp.float32) + e
        sent = compress_decompress(g, cfg)
        new_e = g - sent
        if reduce_fn is not None:
            sent = reduce_fn(sent)
        return sent, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))


def compression_ratio(cfg: CompressionConfig) -> float:
    r = 1.0
    if "topk" in cfg.scheme:
        r *= cfg.topk_frac * 2  # indices + values
    if "int8" in cfg.scheme:
        r *= 0.25
    return min(r, 1.0)
