"""Gemma3-4B [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab=262144, mlp="swiglu", tie_embeddings=True,
    sliding_window=1024, global_every=6,  # 5 local : 1 global
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt; unverified",
    notes="5:1 local:global sliding window, 128k context",
)
