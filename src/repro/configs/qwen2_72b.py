"""Qwen2-72B [arXiv:2407.10671; hf] — dense GQA with QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=29568, vocab=152064, qkv_bias=True, mlp="swiglu",
    rope_theta=1e6, source="arXiv:2407.10671; hf",
    notes="GQA kv=8, QKV bias",
)
