"""DBRX-132B [hf:databricks/dbrx-base; unverified] — 16e top-4 MoE."""
from .base import ArchConfig, MoeConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=10752, vocab=100352, mlp="swiglu",
    moe=MoeConfig(n_experts=16, top_k=4),
    source="hf:databricks/dbrx-base; unverified",
    notes="fine-grained 16-expert top-4",
)
