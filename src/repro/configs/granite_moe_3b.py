"""Granite-MoE-3B-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ArchConfig, MoeConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155, mlp="swiglu",
    moe=MoeConfig(n_experts=40, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    notes="fine-grained 40-expert top-8 MoE; per-expert d_ff=512 is the "
          "paper's reshape-optimization regime (W<2048)",
)
