"""Config registry: --arch <id> -> ArchConfig."""
from . import (dbrx_132b, gemma3_4b, granite_20b, granite_8b,
               granite_moe_3b, hymba_1_5b, internvl2_26b, mamba2_130m,
               musicgen_large, qwen2_72b)
from .base import SHAPES, ArchConfig, ShapeConfig, shapes_for, smoke_config
from .specfam import SPEC_FAMILIES, family_specs

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_72b, granite_8b, gemma3_4b, granite_20b,
              musicgen_large, granite_moe_3b, dbrx_132b, hymba_1_5b,
              internvl2_26b, mamba2_130m)
}

__all__ = ["ARCHS", "SHAPES", "SPEC_FAMILIES", "ArchConfig", "ShapeConfig",
           "family_specs", "shapes_for", "smoke_config"]
