"""MusicGen-large [arXiv:2306.05284; hf] — decoder over EnCodec tokens.

Backbone only: the EnCodec frontend is a stub; ``input_specs`` feeds
precomputed frame embeddings (input_mode='embeddings')."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="dense",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048, mlp="gelu", input_mode="embeddings",
    source="arXiv:2306.05284; hf",
    notes="audio decoder-only over EnCodec tokens; frontend stubbed",
)
