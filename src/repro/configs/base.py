"""Architecture / run configuration dataclasses.

Every assigned architecture is an :class:`ArchConfig` in its own module
(``src/repro/configs/<id>.py``) selectable via ``--arch <id>``.  The input
shapes of the assignment are :class:`ShapeConfig` entries; which shapes an
arch supports (decode vs train, sub-quadratic requirements) is derived
here and documented in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    state_dim: int
    head_dim: int = 64
    conv_kernel: int = 4
    expand: int = 2          # d_inner = expand * d_model
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    mlp: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    # sliding-window pattern: window size + every Nth layer global
    sliding_window: Optional[int] = None
    global_every: int = 0           # 0 = all layers global (full attn)
    moe: Optional[MoeConfig] = None
    ssm: Optional[SsmConfig] = None
    input_mode: str = "tokens"      # tokens | embeddings (modality stub)
    prefix_patches: int = 0         # VLM: patch embeddings before tokens
    # annotations
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window-local)."""
        return self.family in ("ssm", "hybrid") or \
            self.sliding_window is not None

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        per = 2 * d  # norms
        if not self.attention_free:
            per += d * self.n_heads * self.d_head   # q
            per += 2 * d * self.n_kv_heads * self.d_head  # k, v
            per += self.n_heads * self.d_head * d   # o
        if self.family == "moe":
            e = self.moe.n_experts
            per += d * e  # router
            per += e * 3 * d * self.d_ff
        elif self.d_ff > 0:
            mult = 3 if self.mlp == "swiglu" else 2
            per += mult * d * self.d_ff
        if self.ssm is not None:
            di = self.d_inner
            s = self.ssm.state_dim
            per += d * (2 * di + 2 * s + self.n_ssm_heads)  # in_proj
            per += di * d                                   # out_proj
            per += self.ssm.conv_kernel * (di + 2 * s)      # conv
            per += 2 * self.n_ssm_heads                     # A, D
        return n + L * per

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        total = self.param_count()
        e, k = self.moe.n_experts, self.moe.top_k
        expert_params = L * e * 3 * d * self.d_ff
        return total - expert_params + expert_params * k // e


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> list[str]:
    """The assignment's applicability rule (DESIGN.md §3)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=2,
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab=512,
        prefix_patches=8 if cfg.prefix_patches else 0,
    )
    if cfg.moe:
        changes["moe"] = MoeConfig(n_experts=4, top_k=2,
                                   capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm:
        changes["ssm"] = SsmConfig(state_dim=16, head_dim=32,
                                   conv_kernel=cfg.ssm.conv_kernel,
                                   expand=2, chunk=32)
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    return dataclasses.replace(cfg, **changes)
