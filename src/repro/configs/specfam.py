"""Heterogeneous memory-device populations as first-class spec families.

CXLRAMSim's observation (PAPERS.md) is that real deployments mix memory
populations — on-device LPDDR in phones, faster server parts, and
CXL-attached expanders with extra link latency — and co-evaluation only
means something against the *fleet*, not one golden device.  The
spec-vectorized facade makes that cheap here: each family below is just
a frozen :class:`~repro.core.timing.SystemSpec` variant, so a whole
mixed population resolves in ONE ``run_many``/``plan_grid`` engine
dispatch (heterogeneous ``TimingCycles`` ride the fleet axis as traced
data; no extra compiles).  ``benchmarks/fleet_speed.py`` reports the
per-population offload frontiers as ``fleet/specfam_*`` rows and
asserts the batched grid is bit-identical to looping the families.

All families share the default bank geometry (4 bankgroups x 4 banks)
on purpose: the engine compiles one program per bank count, so the
entire fleet shares executables and the comparison measures *timing*
differences, not compile-cache churn.
"""
from __future__ import annotations

from repro.core.timing import (DEFAULT_SYSTEM, LpddrTimings, PimSpec,
                               SystemSpec)

# Phone-class LP5X: a 6400 MT/s bin on half the channels, slower core
# timings and a slower PIM MAC — the on-device regime the paper's
# motivating use case (local LLM decode) actually ships on.
PHONE_LP5X = SystemSpec(
    timings=LpddrTimings(data_rate_mtps=6400, tRCD=21.0, tRP=21.0,
                         tRAS=48.0, tRC=70.0, tRL=18.0),
    pim=PimSpec(mac_interval_ck=4),
    num_channels=2,
)

# Server-class LP5X: the default 9600 MT/s four-channel part.
SERVER_LP5X = DEFAULT_SYSTEM

# Server fast-bin: tightened core timings, faster PIM MAC cadence —
# the upper envelope of the same silicon.
SERVER_LP5X_FAST = SystemSpec(
    timings=LpddrTimings(tRCD=15.0, tRP=15.0, tRAS=36.0, tRC=52.0),
    pim=PimSpec(mac_interval_ck=2),
)

# CXL-expander-like profile: default media behind an expander link —
# extra read latency on every access and a much costlier mode fence
# (the mode-switch handshake crosses the link), per CXLRAMSim.
CXL_EXPANDER = SystemSpec(
    timings=LpddrTimings(tRL=27.0, tRCD=24.0, tRP=24.0),
    fence_ns=450.0,
)

SPEC_FAMILIES = {
    "phone-lp5x": PHONE_LP5X,
    "server-lp5x": SERVER_LP5X,
    "server-lp5x-fast": SERVER_LP5X_FAST,
    "cxl-expander": CXL_EXPANDER,
}


def family_specs() -> list:
    """(name, SystemSpec) pairs in deterministic report order."""
    return list(SPEC_FAMILIES.items())
