"""Hymba-1.5B [arXiv:2411.13676; hf] — parallel attention + mamba heads."""
from .base import ArchConfig, SsmConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab=32001, mlp="swiglu",
    sliding_window=2048, global_every=16,  # a few global layers
    ssm=SsmConfig(state_dim=16, head_dim=64, expand=1),
    source="arXiv:2411.13676; hf",
    notes="parallel attn+mamba heads per layer; SWA + sparse global",
)
