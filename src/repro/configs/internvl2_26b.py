"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

Backbone only (InternLM2-20B-style GQA decoder); the InternViT frontend is
a stub supplying `prefix_patches` precomputed patch embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=92553, mlp="swiglu",
    prefix_patches=256,
    source="arXiv:2404.16821; hf",
    notes="VLM backbone; patch embeddings stubbed via input_specs()",
)
