"""Granite-20B code [arXiv:2405.04324; hf] — MQA (kv=1)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab=49152, mlp="gelu",
    source="arXiv:2405.04324; hf",
    notes="gpt_bigcode-style: MQA (kv=1), GELU FFN (d_ff=4d); RoPE used "
          "in place of learned positions (documented deviation)",
)
