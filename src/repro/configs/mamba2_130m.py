"""Mamba2-130M [arXiv:2405.21060; unverified] — SSD, attention-free."""
from .base import ArchConfig, SsmConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50280, mlp="swiglu",
    ssm=SsmConfig(state_dim=128, head_dim=64, expand=2),
    source="arXiv:2405.21060; unverified",
    notes="SSD (state-space duality); attn-free, d_ff=0 (no MLP block)",
)
