"""Data pipeline: deterministic synthetic LM token stream with
host-side prefetch and device-sharded delivery.

Production shape: an iterator of global batches, each placed with
``jax.device_put`` against the batch sharding (so per-host, only the local
shard is materialized — on a real multi-host pod each host feeds its
addressable devices).  Synthetic data is a seeded Zipf-ish mixture so runs
are reproducible and loss curves are meaningful (structure to learn:
repeated n-grams).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class SyntheticLM:
    """Deterministic synthetic corpus with learnable bigram structure."""

    def __init__(self, vocab: int, seed: int = 0, ngram: int = 3):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # sparse deterministic bigram table: each token has few successors
        self.successors = rng.integers(0, vocab, size=(vocab, ngram))

    def batch(self, step: int, batch: int, seq: int) -> dict:
        rng = np.random.default_rng(hash((step, batch, seq)) % 2**31)
        toks = np.empty((batch, seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        noise = rng.random((batch, seq))
        choice = rng.integers(0, self.successors.shape[1],
                              size=(batch, seq))
        for t in range(seq):
            nxt = self.successors[toks[:, t], choice[:, t]]
            rand = rng.integers(0, self.vocab, size=batch)
            toks[:, t + 1] = np.where(noise[:, t] < 0.1, rand, nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch + device placement."""

    def __init__(self, source: SyntheticLM, batch: int, seq: int,
                 sharding=None, depth: int = 2, start_step: int = 0):
        self.source = source
        self.batch, self.seq = batch, seq
        self.sharding = sharding
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        while not self._stop.is_set():
            b = self.source.batch(self.step, self.batch, self.seq)
            if self.sharding is not None:
                b = {k: jax.device_put(v, self.sharding[k])
                     for k, v in b.items()}
            try:
                self.q.put(b, timeout=1.0)
                self.step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self.q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
