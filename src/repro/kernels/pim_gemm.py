"""Pallas TPU kernel: batched PIM-tile quantized GEMM (decode projections).

The serving hot path is a *batch* of GEMVs — one token per active request
against the same weight matrix (``(B, W) x (H, W) -> (B, H)``).  The PIM
blocking carries over from `pim_gemv`: the W (reduction) grid dimension
revisits a float32/int32 VMEM accumulator, the weight tile is the PIM tile
padded to MXU alignment, and the batch block plays the SRF-broadcast role
(one activation block reused by every H tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pim_gemv import _CompilerParams, _pad_to


def _gemm_int_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, w_bits: int,
                     n_w: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]
    if w_bits == 4:
        lo = jnp.right_shift(jnp.left_shift(w, 4), 4)
        hi = jnp.right_shift(w, 4)
        w = jnp.stack([lo, hi], axis=-1).reshape(w.shape[0], -1)
    x = x_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)            # (BB, BH)

    @pl.when(k == n_w - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * s_ref[...]


def _gemm_fp_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_w: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_w - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("w_bits", "block", "interpret"))
def pim_gemm_int(wq, xb_q, w_scale, x_scale, *, w_bits: int = 8,
                 block: tuple[int, int, int] = (8, 256, 512),
                 interpret: bool = True) -> jnp.ndarray:
    """Quantized GEMM: (B, W) x (H, W[/2]) -> f32 (B, H)."""
    bb, bh, bw = block
    b, _ = xb_q.shape
    h = wq.shape[0]
    wq = _pad_to(_pad_to(wq, 0, bh), 1, bw // (2 if w_bits == 4 else 1))
    xb_q = _pad_to(_pad_to(xb_q, 0, bb), 1, bw)
    ws = _pad_to(w_scale.reshape(1, -1).astype(jnp.float32)
                 * jnp.asarray(x_scale, jnp.float32), 1, bh)
    bp, wp = xb_q.shape
    hp = wq.shape[0]
    n_b, n_h, n_w = bp // bb, hp // bh, wp // bw
    bw_bytes = bw // 2 if w_bits == 4 else bw

    out = pl.pallas_call(
        functools.partial(_gemm_int_kernel, w_bits=w_bits, n_w=n_w),
        grid=(n_b, n_h, n_w),
        in_specs=[
            pl.BlockSpec((bb, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bh, bw_bytes), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bh), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bh), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, hp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bh), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xb_q, wq, ws)
    return out[:b, :h]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pim_gemm_fp(w_fp8, xb, *, block: tuple[int, int, int] = (8, 256, 512),
                interpret: bool = True) -> jnp.ndarray:
    """fp8 weight GEMM: (B, W) x (H, W) -> f32 (B, H)."""
    bb, bh, bw = block
    b = xb.shape[0]
    h = w_fp8.shape[0]
    w_fp8 = _pad_to(_pad_to(w_fp8, 0, bh), 1, bw)
    xb = _pad_to(_pad_to(xb, 0, bb), 1, bw)
    bp, wp = xb.shape
    hp = w_fp8.shape[0]
    n_b, n_h, n_w = bp // bb, hp // bh, wp // bw

    out = pl.pallas_call(
        functools.partial(_gemm_fp_kernel, n_w=n_w),
        grid=(n_b, n_h, n_w),
        in_specs=[
            pl.BlockSpec((bb, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bh, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bh), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, hp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xb, w_fp8)
    return out[:b, :h]
