"""Pure-jnp oracles for the PIM-tile quantized GEMV/GEMM kernels.

The numerics contract shared with the Pallas kernels:

* int paths (W8/W4 x A8/A16/A4): exact integer MACs into int32, then a
  single dequantization ``y = acc * w_scale[row] * x_scale`` in float32.
* fp paths (fp8-e4m3 weights x fp8/bf16 activations): operands upcast to
  float32, accumulated in float32 (no scales).

W4 weights travel *packed*, two signed nibbles per int8 byte
(little-nibble = even column), exactly the byte layout the Data Mapper
writes to DRAM — the kernels unpack in-register, mirroring how the PIM
MAC unit consumes a 32 B burst.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pack_w4(q) -> jnp.ndarray:
    """(H, W) int values in [-8, 7] -> (H, W//2) packed int8."""
    q = jnp.asarray(q, jnp.int8)
    assert q.shape[-1] % 2 == 0
    lo = q[..., 0::2] & 0xF
    hi = q[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_w4(packed) -> jnp.ndarray:
    """(..., W//2) packed int8 -> (..., W) int8 (sign-extended nibbles)."""
    p = jnp.asarray(packed, jnp.int8)
    lo = jnp.left_shift(p, 4)
    lo = jnp.right_shift(lo, 4)                 # arithmetic: sign-extend
    hi = jnp.right_shift(p, 4)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def quantize_weights(w, w_bits: int = 8):
    """Symmetric per-row quantization: returns (q, scale[H]) with q int8.

    For w_bits=4 the caller packs with :func:`pack_w4`.
    """
    w = jnp.asarray(w, jnp.float32)
    qmax = 2 ** (w_bits - 1) - 1
    scale = jnp.max(jnp.abs(w), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale[:, 0]


def quantize_acts(x, a_bits: int = 8):
    """Symmetric per-tensor activation quantization -> (q, scale)."""
    x = jnp.asarray(x, jnp.float32)
    qmax = 2 ** (a_bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    dtype = jnp.int8 if a_bits <= 8 else jnp.int16
    return q.astype(dtype), scale


def ref_gemv_int(wq, x_q, w_scale, x_scale, w_bits: int = 8) -> jnp.ndarray:
    """Oracle for the int GEMV: (H,[W or W/2]) x (W,) -> f32 (H,)."""
    w = unpack_w4(wq) if w_bits == 4 else jnp.asarray(wq, jnp.int8)
    acc = jnp.dot(w.astype(jnp.int32), jnp.asarray(x_q).astype(jnp.int32))
    return acc.astype(jnp.float32) * jnp.asarray(w_scale, jnp.float32) \
        * jnp.asarray(x_scale, jnp.float32)


def ref_gemm_int(wq, xb_q, w_scale, x_scale, w_bits: int = 8) -> jnp.ndarray:
    """Oracle for the batched int GEMM: (B, W) x (H, W) -> f32 (B, H)."""
    w = unpack_w4(wq) if w_bits == 4 else jnp.asarray(wq, jnp.int8)
    acc = jnp.dot(jnp.asarray(xb_q).astype(jnp.int32),
                  w.astype(jnp.int32).T)
    return acc.astype(jnp.float32) * jnp.asarray(w_scale, jnp.float32)[None] \
        * jnp.asarray(x_scale, jnp.float32)


def ref_gemv_fp(w_fp8, x) -> jnp.ndarray:
    """Oracle for the fp path: fp8 weights x fp8/bf16 acts -> f32."""
    w = jnp.asarray(w_fp8).astype(jnp.float32)
    return jnp.dot(w, jnp.asarray(x).astype(jnp.float32))


def ref_gemm_fp(w_fp8, xb) -> jnp.ndarray:
    w = jnp.asarray(w_fp8).astype(jnp.float32)
    return jnp.dot(jnp.asarray(xb).astype(jnp.float32), w.T)
