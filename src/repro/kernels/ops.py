"""Public jit'd wrappers for the PIM-tile kernels.

`pim_linear` is the layer-facing entry point: it takes a float input and a
pre-quantized weight bundle (see :func:`prepare_weights`), quantizes the
activations on the fly, and dispatches to the Pallas kernel (interpret
mode on CPU — the TPU path compiles the same kernel natively).

The default block shapes come from the PIM tile configuration: the Data
Mapper's ``T_h x T_w`` scaled to MXU alignment (DESIGN.md §2.3), so the
HW/SW co-design parameters flow from the simulator into the kernels.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.timing import PimSpec
from repro.pimkernel.tileconfig import PimDType, TileConfig
from . import pim_gemm, pim_gemv, ref


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pim_block_shape(dtype: PimDType,
                    pim: PimSpec = PimSpec()) -> tuple[int, int]:
    """PIM tile -> MXU-aligned VMEM block (BH, BW)."""
    tc = TileConfig.make(dtype, pim)
    bh = max(128, -(-tc.t_h // 128) * 128)
    bw = max(128, -(-tc.t_w // 128) * 128)
    return (min(bh, 512), min(bw, 1024))


@dataclasses.dataclass
class QuantWeights:
    """A weight matrix prepared for PIM-tile kernels."""

    dtype: PimDType
    q: jnp.ndarray           # int8 (H, W[/2]) or fp8 (H, W)
    scale: jnp.ndarray | None  # (H,) f32, int paths only
    shape: tuple[int, int]   # logical (H, W)


def prepare_weights(w, dtype: PimDType | str) -> QuantWeights:
    dtype = PimDType.parse(dtype) if isinstance(dtype, str) else dtype
    w = jnp.asarray(w, jnp.float32)
    if dtype.is_fp:
        return QuantWeights(dtype, w.astype(jnp.float8_e4m3fn), None,
                            tuple(w.shape))
    q, scale = ref.quantize_weights(w, dtype.w_bits)
    if dtype.w_bits == 4:
        q = ref.pack_w4(q)
    return QuantWeights(dtype, q, scale, tuple(w.shape))


def pim_linear(x, qw: QuantWeights, *, block=None,
               interpret: bool | None = None) -> jnp.ndarray:
    """y = x @ W^T with PIM-tile kernels.  x: (W,) or (B, W) float."""
    if interpret is None:
        interpret = default_interpret()
    if block is None:
        block = pim_block_shape(qw.dtype)
    x = jnp.asarray(x)
    squeeze = x.ndim == 1
    xb = x[None] if squeeze else x

    if qw.dtype.is_fp:
        xk = xb.astype(jnp.float8_e4m3fn if qw.dtype.a_bits == 8
                       else jnp.bfloat16)
        if squeeze:
            out = pim_gemv.pim_gemv_fp(qw.q, xk[0], block=block,
                                       interpret=interpret)
        else:
            out = pim_gemm.pim_gemm_fp(qw.q, xk, block=(8,) + block,
                                       interpret=interpret)
    else:
        xq, xs = ref.quantize_acts(xb, qw.dtype.a_bits)
        if squeeze:
            out = pim_gemv.pim_gemv_int(qw.q, xq[0], qw.scale, xs,
                                        w_bits=qw.dtype.w_bits,
                                        block=block, interpret=interpret)
        else:
            out = pim_gemm.pim_gemm_int(qw.q, xq, qw.scale, xs,
                                        w_bits=qw.dtype.w_bits,
                                        block=(8,) + block,
                                        interpret=interpret)
    return out


def pim_linear_ref(x, qw: QuantWeights) -> jnp.ndarray:
    """Oracle path with identical numerics contract."""
    x = jnp.asarray(x)
    squeeze = x.ndim == 1
    xb = x[None] if squeeze else x
    if qw.dtype.is_fp:
        xk = xb.astype(jnp.float8_e4m3fn if qw.dtype.a_bits == 8
                       else jnp.bfloat16)
        out = ref.ref_gemm_fp(qw.q, xk)
    else:
        xq, xs = ref.quantize_acts(xb, qw.dtype.a_bits)
        out = ref.ref_gemm_int(qw.q, xq, qw.scale, xs,
                               w_bits=qw.dtype.w_bits)
    return out[0] if squeeze else out
