"""Pallas TPU kernel: PIM-tile quantized GEMV.

TPU-native re-tiling of the LP5X-PIM GEMV execution model (DESIGN.md
§2.3):

* a PIM tile ``T_h x T_w`` becomes a VMEM block ``(BH, BW)`` aligned to the
  MXU (multiples of 8 x 128 / 32 x 128 for int8);
* the SRF broadcast becomes the ``x`` block, resident in VMEM and shared
  by every row block of the H grid dimension (the grid iterates H in the
  *inner* loop for each W chunk — same reuse the SRF gives the 16 banks);
* the ACC register file becomes the int32/float32 VMEM scratch accumulator
  revisited across the W (reduction) grid dimension;
* the ACC->host flush-out becomes the masked dequantizing write of the
  final grid step.

Weight dtypes: int8, packed-int4 (two nibbles per byte — the Data Mapper's
DRAM byte layout), fp8-e4m3.  Activations: int8 / int16 / bf16 / fp8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # fail at import, not inside pallas_call
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported")


def _gemv_int_kernel(w_ref, x_ref, s_ref, o_ref, acc_ref, *, w_bits: int,
                     n_w: int):
    """One (BH, BW) tile step: acc += W_tile @ x_tile (int32 MACs)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]
    if w_bits == 4:
        lo = jnp.right_shift(jnp.left_shift(w, 4), 4)
        hi = jnp.right_shift(w, 4)
        w = jnp.stack([lo, hi], axis=-1).reshape(w.shape[0], -1)
    x = x_ref[...]                                   # (1, BW)
    acc_ref[...] += jax.lax.dot_general(
        w.astype(jnp.int32), x.astype(jnp.int32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)            # (BH, 1)

    @pl.when(k == n_w - 1)
    def _flush():
        scale = s_ref[...]                           # (BH, 1) f32
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def _gemv_fp_kernel(w_ref, x_ref, o_ref, acc_ref, *, n_w: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        w, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_w - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _pad_to(arr, axis, mult):
    n = arr.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return arr
    width = [(0, 0)] * arr.ndim
    width[axis] = (0, pad)
    return jnp.pad(arr, width)


@functools.partial(jax.jit, static_argnames=("w_bits", "block", "interpret"))
def pim_gemv_int(wq, x_q, w_scale, x_scale, *, w_bits: int = 8,
                 block: tuple[int, int] = (256, 512),
                 interpret: bool = True) -> jnp.ndarray:
    """Quantized GEMV: (H, W[/2]) x (W,) -> f32 (H,).

    ``block`` is (BH, BW) in *element* space; the PIM-tile-derived default
    is 4 x T_h x 1 x T_w of the W8A8 tile config, MXU aligned.
    """
    bh, bw = block
    h = wq.shape[0]
    w_elems = wq.shape[1] * (2 if w_bits == 4 else 1)
    wq = _pad_to(_pad_to(wq, 0, bh), 1, bw // (2 if w_bits == 4 else 1))
    x_q = _pad_to(x_q.reshape(1, -1), 1, bw)
    ws = _pad_to(w_scale.reshape(-1, 1).astype(jnp.float32) *
                 jnp.asarray(x_scale, jnp.float32), 0, bh)
    hp, wp = wq.shape[0], x_q.shape[1]
    n_h, n_w = hp // bh, wp // bw
    bw_bytes = bw // 2 if w_bits == 4 else bw

    out = pl.pallas_call(
        functools.partial(_gemv_int_kernel, w_bits=w_bits, n_w=n_w),
        grid=(n_h, n_w),
        in_specs=[
            pl.BlockSpec((bh, bw_bytes), lambda i, k: (i, k)),
            pl.BlockSpec((1, bw), lambda i, k: (0, k)),
            pl.BlockSpec((bh, 1), lambda i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bh, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bh, 1), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(wq, x_q, ws)
    return out[:h, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pim_gemv_fp(w_fp8, x, *, block: tuple[int, int] = (256, 512),
                interpret: bool = True) -> jnp.ndarray:
    """fp8-e4m3 weight GEMV: (H, W) x (W,) -> f32 (H,)."""
    bh, bw = block
    h, w_elems = w_fp8.shape
    w_fp8 = _pad_to(_pad_to(w_fp8, 0, bh), 1, bw)
    x = _pad_to(x.reshape(1, -1), 1, bw)
    hp, wp = w_fp8.shape
    n_h, n_w = hp // bh, wp // bw

    out = pl.pallas_call(
        functools.partial(_gemv_fp_kernel, n_w=n_w),
        grid=(n_h, n_w),
        in_specs=[
            pl.BlockSpec((bh, bw), lambda i, k: (i, k)),
            pl.BlockSpec((1, bw), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((bh, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bh, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(w_fp8, x)
    return out[:h, 0]
