"""Pallas lane resolver: the timing engine's hot loop as a kernel.

The engine's scan resolver is a ``vmap``-ed ``lax.scan`` over a ~100-op
branchless int32 state machine (``core/engine._build_step``).  This
module re-expresses the *same* body as a Pallas kernel so the fleet axis
becomes the Pallas grid and the per-lane channel state — ~20 small
per-bank int32 vectors — stays in VMEM/registers for the whole command
stream instead of round-tripping through the vmapped batch between
steps, with the opcode-masked timing updates fused inside one kernel.

Bit-identity with the scan resolver (and therefore with ``RefEngine``)
is by *construction*, not by reimplementation: the kernel body calls the
shared ``engine._lane_runner`` scan, exactly the way the ``shard_map``
mesh resolver shares it.  The differential suites
(``tests/test_pallas_resolver.py``, the conformance battery run under
``REPRO_LANE_BACKEND=pallas``) enforce the contract.

Interpret-mode plumbing mirrors ``kernels/ops.py``: on CPU the kernel
runs under the Pallas interpreter (how CI exercises it); on TPU the same
kernel compiles natively.  :func:`pallas_lane_supported` is the
capability probe behind the engine's automatic backend fallback — any
failure to build/run the kernel, or a mismatch against the scan
resolver on a tiny probe lane, degrades ``configure_lane_backend
("pallas")`` to the scan path instead of breaking resolution.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import commands as C
from repro.core import engine as _engine
from repro.core.timing import DEFAULT_SYSTEM, TimingCycles
from .ops import default_interpret
from .pim_gemv import _CompilerParams

# The timing configuration rides the grid as an int32 matrix: one row per
# lane, one column per cycle field (``tck_ns``/``num_banks`` excluded —
# the former is unused by the step, the latter is static kernel metadata).
CYC_FIELDS = tuple(f.name for f in dataclasses.fields(TimingCycles)
                   if f.name not in ("tck_ns", "num_banks"))


def _lane_kernel(cyc_ref, stream_ref, issue_ref, total_ref, *,
                 num_banks: int, unroll: int):
    """One grid step = one lane: scan the command stream with the shared
    step body; carry (the ChannelState pytree) lives in VMEM/registers."""
    cyc = TimingCycles(
        tck_ns=0.0, num_banks=num_banks,
        **{name: cyc_ref[0, j] for j, name in enumerate(CYC_FIELDS)})
    issue, total = _engine._lane_runner(num_banks, unroll)(
        cyc, stream_ref[0])
    issue_ref[0, :] = issue
    total_ref[0, 0] = total


def pack_cycles(cycs: TimingCycles) -> jnp.ndarray:
    """Stacked fleet-axis ``TimingCycles`` -> int32 ``(F, len(CYC_FIELDS))``."""
    return jnp.stack(
        [jnp.asarray(getattr(cycs, name)).astype(jnp.int32)
         for name in CYC_FIELDS], axis=-1)


def make_lane_resolver(num_banks: int, unroll: int | None = None,
                       interpret: bool | None = None):
    """Build the jitted Pallas fleet resolver for one bank count.

    The returned ``fn(cycs, streams)`` honours the exact
    ``engine._fleet_resolver`` contract — ``cycs`` a ``TimingCycles``
    pytree stacked along the fleet axis, ``streams`` int32 ``(F, N, 4)``,
    result ``(issue (F, N), total (F,))`` int32 — so the engine's slab
    dispatch, dedupe and lane LRU are backend-oblivious.  The jit cache
    keys only on shapes (the timing data is traced), preserving the
    compile-count story of the scan path.
    """
    if unroll is None:
        unroll = _engine.scan_unroll()
    kern = functools.partial(_lane_kernel, num_banks=num_banks,
                             unroll=unroll)
    ncyc = len(CYC_FIELDS)

    def fn(cycs, streams):
        f, n, _ = streams.shape
        interp = default_interpret() if interpret is None else interpret
        issue, total = pl.pallas_call(
            kern,
            grid=(f,),
            in_specs=[
                pl.BlockSpec((1, ncyc), lambda i: (i, 0)),
                pl.BlockSpec((1, n, 4), lambda i: (i, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, n), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((f, n), jnp.int32),
                jax.ShapeDtypeStruct((f, 1), jnp.int32),
            ],
            compiler_params=_CompilerParams(
                dimension_semantics=("arbitrary",)),
            interpret=interp,
        )(pack_cycles(cycs), streams)
        return issue, total[:, 0]

    return jax.jit(fn)


def _probe_stream(num_banks: int) -> np.ndarray:
    """A tiny but non-trivial lane touching ACT/RD/MAC/fence paths."""
    ops = [(C.ACT, 0, 3, 0), (C.RD, 0, 0, 0), (C.PRE, 0, 0, 0),
           (C.MODE_MB, 0, 0, 0), (C.ACT_MB, 1 % num_banks, 2, 0),
           (C.WR_SRF, 0, 0, 0), (C.MAC, 0, 0, 0), (C.RD_ACC, 0, 0, 0),
           (C.FENCE, 0, 0, 0), (C.MODE_SB, 0, 0, 0)]
    s = np.zeros((16, 4), dtype=np.int32)
    s[: len(ops)] = np.asarray(ops, dtype=np.int32)
    return s


@functools.lru_cache(maxsize=None)
def pallas_lane_supported() -> bool:
    """Capability probe behind the engine's automatic backend fallback.

    Builds and runs the kernel on one probe lane and demands bit-identity
    with the scan resolver; any exception (Pallas feature missing on this
    jax version/backend) or mismatch reports unsupported.  Cached per
    process — the probe costs two tiny compiles, once.
    """
    try:
        cyc = DEFAULT_SYSTEM.derive_cycles()
        stream = _probe_stream(cyc.num_banks)[None]
        cycs = _engine.stack_cycles([cyc])
        ref_iss, ref_tot = _engine._fleet_resolver(cyc.num_banks)(
            cycs, stream)
        got_iss, got_tot = make_lane_resolver(cyc.num_banks)(cycs, stream)
        return (np.array_equal(np.asarray(got_iss), np.asarray(ref_iss))
                and np.array_equal(np.asarray(got_tot),
                                   np.asarray(ref_tot)))
    except Exception:          # noqa: BLE001 - any failure means fallback
        return False
