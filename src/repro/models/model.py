"""Model assembly: every assigned architecture as one scanned decoder.

A single parameter schema covers all five families (dense / local-global /
MoE / SSM / hybrid): per-layer parameters are stacked along a leading L
axis and the backbone is one ``jax.lax.scan`` over layers (bounded HLO for
the 80-cell dry-run matrix), with per-layer kind flags (local vs global
attention) as scanned leaves.

Public surface:
  init_params(cfg, key)            -> params pytree (stacked layers)
  param_logical(cfg)               -> same-structure tree of logical axes
  forward(cfg, params, batch)      -> logits (train/prefill path)
  init_cache(cfg, batch, seq)      -> KV/SSM cache pytree
  prefill(cfg, params, tokens)     -> (logits_last, cache)
  decode_step(cfg, params, cache, token, pos) -> (logits, cache)
  loss_fn / make_train_step        -> training
  input_specs(cfg, shape, ...)     -> ShapeDtypeStruct stand-ins (dry-run)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig, ShapeConfig
from . import layers as L
from . import moe as MOE
from . import quant as Q
from . import ssm as SSM

PyTree = Any

# When True, layer scans fully unroll (no while loop).  Used by the
# dry-run cost extrapolation: XLA's cost_analysis counts a while body
# once regardless of trip count, so exact per-layer FLOPs/bytes are
# derived from small fully-unrolled variants (see launch/dryrun.py).
UNROLL_SCAN = False

# §Perf hillclimb knobs (launch/dryrun.py --variant flips these):
#   REMAT_POLICY: "full" = nothing_saveable (max recompute, min memory),
#   "dots" = matmul outputs saved (less recompute), "none" = no remat.
#   CE_CHUNKS: > 0 computes the cross-entropy in that many sequence
#   chunks without materializing the full (B, S, vocab) logits.
REMAT_POLICY = "full"
CE_CHUNKS = 0

# Quantized serving (§Perf iterations / the paper's W8-W4 formats):
# 0 = bf16 params; 8/4 = int8 / packed-int4 matmul weights + scales
# (models/quant.py).  Embedding tables stay int8 under w4 (row gather).
QUANT_BITS = 0

# int8 KV cache (§Perf Cell A next step): halves the decode memory floor.
# Per-(layer, batch, head) scales fixed at prefill; decode clips to them.
KV_QUANT = False


def _deq(leaf):
    """Dequantize a possibly-quantized parameter leaf on use."""
    if Q.is_bundle(leaf):
        return Q.dequant_leaf(leaf, QUANT_BITS or 8)
    return leaf


def _head_matrix(cfg, params):
    if cfg.tie_embeddings:
        emb = params["embed"]
        if Q.is_bundle(emb):
            return Q.dequant_leaf(emb, 8).T   # embed is always 8-bit
        return emb.T
    lm = params["lm_head"]
    return Q.dequant_leaf(lm, QUANT_BITS or 8) if Q.is_bundle(lm) else lm


def _remat_wrap(body):
    if REMAT_POLICY == "none":
        return body
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if REMAT_POLICY == "moe-save":
        # keep expert outputs across the remat boundary: the backward
        # pass must not re-run the dispatch collectives
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "moe_out"))
    if REMAT_POLICY == "tp-save":
        # keep TP-boundary outputs (post all-reduce): the recompute
        # must not re-run the Megatron activation all-reduces
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "tp_out"))
    return jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)


def _scan(body, init, xs):
    if UNROLL_SCAN:
        length = jax.tree.leaves(xs)[0].shape[0]
        return jax.lax.scan(body, init, xs, unroll=length)
    return jax.lax.scan(body, init, xs)


# ---------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------

def _attn_init(key, cfg: ArchConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), dtype)
        * (1.0 / math.sqrt(hq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _attn_logical(cfg: ArchConfig):
    p = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv_heads",),
                  "bv": ("kv_heads",)})
    return p


def _layer_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), dtype),
               "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.attention_free:
        p["attn"] = _attn_init(ks[0], cfg, dtype)
    if cfg.family == "moe":
        p["moe"] = MOE.moe_init(ks[1], cfg.d_model, cfg.d_ff,
                                cfg.moe.n_experts, cfg.mlp, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    if cfg.ssm is not None:
        p["ssm"] = SSM.ssm_init(ks[2], cfg.d_model, cfg.ssm, dtype)
    return p


def layer_kinds(cfg: ArchConfig) -> jnp.ndarray:
    """(L,) int32: 1 = global attention, 0 = local (sliding window)."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.sliding_window is None or cfg.global_every == 0:
        return jnp.ones((cfg.n_layers,), jnp.int32)
    return (idx % cfg.global_every == cfg.global_every - 1).astype(
        jnp.int32)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> PyTree:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    v = cfg.vocab_padded
    params = {
        "embed": jax.random.normal(k_emb, (v, cfg.d_model), dtype) * 0.02,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "blocks": jax.vmap(
            lambda k: _layer_init(k, cfg, dtype))(
                jax.random.split(k_layers, cfg.n_layers)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            k_head, (cfg.d_model, v), dtype) * 0.02
    if cfg.prefix_patches:
        params["patch_proj"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.d_model), dtype) * 0.02
    return params


def param_logical(cfg: ArchConfig) -> PyTree:
    blk: dict = {"ln1": ("layers", "embed"), "ln2": ("layers", "embed")}
    if not cfg.attention_free:
        blk["attn"] = {k: ("layers",) + v
                       for k, v in _attn_logical(cfg).items()}
    if cfg.family == "moe":
        blk["moe"] = {k: ("layers",) + v
                      for k, v in MOE.moe_logical(cfg.mlp).items()}
    elif cfg.d_ff > 0:
        blk["mlp"] = {k: ("layers",) + v
                      for k, v in L.mlp_logical(cfg.mlp).items()}
    if cfg.ssm is not None:
        blk["ssm"] = {k: ("layers",) + v
                      for k, v in SSM.ssm_logical().items()}
    out = {"embed": ("vocab", "embed"), "ln_f": ("embed",),
           "blocks": blk}
    if not cfg.tie_embeddings:
        out["lm_head"] = ("embed", "vocab")
    if cfg.prefix_patches:
        out["patch_proj"] = ("embed", "embed2")
    return out



# ---------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------

def _attn_apply(p, cfg: ArchConfig, x, kind, positions, cache_kv=None,
                pos: Optional[jnp.ndarray] = None, kv_len=None,
                kv_scale=None):
    """kind: per-layer scalar (0 local / 1 global).  Returns (out, (k,v))."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)

    new_scale = kv_scale
    if cache_kv is not None:
        ck, cv = cache_kv
        kv_q = ck.dtype == jnp.int8
        if s == 1:
            # decode: per-slot write positions (ragged continuous batching)
            posv = jnp.broadcast_to(pos, (b,)).astype(jnp.int32)
            upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(
                c, u, (p, 0, 0)))
            if kv_q:
                # quantize the new entries to the prefill-time scales
                sk, sv = kv_scale
                kq = jnp.clip(jnp.round(k / sk), -127, 127).astype(
                    jnp.int8)
                vq = jnp.clip(jnp.round(v / sv), -127, 127).astype(
                    jnp.int8)
            else:
                kq, vq = k.astype(ck.dtype), v.astype(cv.dtype)
            ck = upd(ck, kq, posv)
            cv = upd(cv, vq, posv)
            new_cache = (ck, cv)
            # attend over the cache (padded; mask via kv_len)
            if kv_q:
                k_all = (ck.astype(jnp.float32) * sk).astype(q.dtype)
                v_all = (cv.astype(jnp.float32) * sv).astype(q.dtype)
            else:
                k_all, v_all = ck, cv
            q_offset = posv
            kv_len_eff = posv + 1
        else:
            if kv_q:
                # per-(batch, head) scales fixed at prefill time
                sk = jnp.max(jnp.abs(k), axis=(1, 3), keepdims=True
                             ).astype(jnp.float32) / 127 + 1e-8
                sv = jnp.max(jnp.abs(v), axis=(1, 3), keepdims=True
                             ).astype(jnp.float32) / 127 + 1e-8
                kq = jnp.clip(jnp.round(k / sk), -127, 127).astype(
                    jnp.int8)
                vq = jnp.clip(jnp.round(v / sv), -127, 127).astype(
                    jnp.int8)
                new_scale = (sk, sv)
            else:
                kq, vq = k.astype(ck.dtype), v.astype(cv.dtype)
            ck = jax.lax.dynamic_update_slice(ck, kq, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vq, (0, pos, 0, 0))
            new_cache = (ck, cv)
            # prefill: the fresh k/v ARE the valid cache prefix
            k_all, v_all = k, v
            q_offset = 0
            kv_len_eff = None
    else:
        k_all, v_all = k, v
        q_offset = 0
        new_cache = (k, v)
        kv_len_eff = None

    window = None
    if cfg.sliding_window is not None:
        # kind==1 -> global: disable the window via a huge value.
        big = 1 << 30
        window = jnp.where(kind == 1, big, cfg.sliding_window)
    out = L.attention(q, k_all.astype(q.dtype), v_all.astype(q.dtype),
                      window=window, q_offset=q_offset,
                      kv_len=kv_len_eff)
    return out.reshape(b, s, hq * hd) @ p["wo"], new_cache, new_scale


def _block_apply(cfg: ArchConfig, params, kind, x, positions,
                 cache=None, pos=None):
    """One decoder layer.  cache: dict of per-layer state or None."""
    if QUANT_BITS:
        params = Q.dequant_tree(params, QUANT_BITS,
                                dtype=params["ln1"].dtype)
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    mix = 0.0
    if not cfg.attention_free:
        attn_out, kv, kv_scale = _attn_apply(
            params["attn"], cfg, h, kind, positions,
            cache_kv=None if cache is None else cache.get("kv"),
            pos=pos,
            kv_scale=None if cache is None else cache.get("kv_scale"))
        new_cache["kv"] = kv
        if kv_scale is not None:
            new_cache["kv_scale"] = kv_scale
        mix = checkpoint_name(attn_out, "tp_out")
    if cfg.ssm is not None:
        y, st, cst = SSM.ssm_block(
            params["ssm"], h, cfg.ssm,
            state=None if cache is None else cache.get("ssm"),
            conv_state=None if cache is None else cache.get("conv"))
        new_cache["ssm"] = st
        new_cache["conv"] = cst
        if cfg.family == "hybrid":
            # Hymba: parallel attn + SSM heads, normalized mean fusion.
            mix = 0.5 * (_rmsn(mix) + _rmsn(y))
        else:
            mix = y
    x = x + mix
    h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = MOE.moe_apply(params["moe"], h, top_k=cfg.moe.top_k,
                               capacity_factor=cfg.moe.capacity_factor,
                               mlp_kind=cfg.mlp)
    elif cfg.d_ff > 0:
        y = checkpoint_name(L.mlp_apply(params["mlp"], h, cfg.mlp),
                            "tp_out")
    else:
        y = jnp.zeros_like(h)
    return x + y, aux, new_cache


def _rmsn(x):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(
        x.dtype)


def _embed_inputs(cfg: ArchConfig, params, batch):
    """tokens and/or stub-modality embeddings -> (B, S, d), positions."""
    if cfg.input_mode == "embeddings":
        x = batch["embeds"]
    else:
        emb = params["embed"]
        if Q.is_bundle(emb):
            rows = jnp.take(emb["q"], batch["tokens"], axis=0)
            x = (rows.astype(jnp.float32) * emb["s"]).astype(
                params["ln_f"].dtype)
        else:
            x = jnp.take(emb, batch["tokens"], axis=0)
        if cfg.prefix_patches:
            patches = batch["patches"] @ _deq(params["patch_proj"])
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions


def _backbone(cfg: ArchConfig, params, x, positions, remat: bool = True):
    kinds = layer_kinds(cfg)

    def body(carry, scanned):
        xc, aux = carry
        blk, kind = scanned
        xc, a, _ = _block_apply(cfg, blk, kind, xc, positions)
        return (xc, aux + a), None

    if remat:
        body = _remat_wrap(body)
    (x, aux), _ = _scan(body, (x, jnp.zeros((), jnp.float32)),
                        (params["blocks"], kinds))
    return x, aux


def forward(cfg: ArchConfig, params, batch, remat: bool = True):
    """Full-sequence forward -> (logits (B, S, vocab), aux_loss)."""
    x, positions = _embed_inputs(cfg, params, batch)
    x, aux = _backbone(cfg, params, x, positions, remat)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ _head_matrix(cfg, params)
    if cfg.prefix_patches:
        logits = logits[:, cfg.prefix_patches:]
    return logits, aux


# ---------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------

def loss_fn(cfg: ArchConfig, params, batch, remat: bool = True):
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    if CE_CHUNKS > 1:
        # chunked CE: never materialize the full (B, S, vocab) logits.
        x, positions = _embed_inputs(cfg, params, batch)
        x, aux = _backbone(cfg, params, x, positions, remat)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.prefix_patches:
            x = x[:, cfg.prefix_patches:]
        head = _head_matrix(cfg, params)
        s = x.shape[1]
        nc = CE_CHUNKS
        csz = -(-s // nc)
        nll_sum = jnp.zeros((), jnp.float32)
        for i in range(nc):  # static unroll: probe-visible FLOPs
            xc = x[:, i * csz:(i + 1) * csz]
            lc = labels[:, i * csz:(i + 1) * csz]
            mc = mask[:, i * csz:(i + 1) * csz]
            if xc.shape[1] == 0:
                continue
            logits_c = (xc @ head).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits_c, axis=-1)
            nll = -jnp.take_along_axis(logp, lc[..., None],
                                       axis=-1)[..., 0]
            nll_sum = nll_sum + (nll * mc).sum()
        loss = nll_sum / jnp.maximum(mask.sum(), 1.0)
        return loss + 0.01 * aux, dict(loss=loss, aux=aux)
    logits, aux = forward(cfg, params, batch, remat)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux, dict(loss=loss, aux=aux)


# ---------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> PyTree:
    cache = {}
    nl = cfg.n_layers
    if not cfg.attention_free:
        kv_shape = (nl, batch, seq, cfg.n_kv_heads, cfg.d_head)
        kv_dtype = jnp.int8 if KV_QUANT else dtype
        cache["kv"] = (jnp.zeros(kv_shape, kv_dtype),
                       jnp.zeros(kv_shape, kv_dtype))
        if KV_QUANT:
            s_shape = (nl, batch, 1, cfg.n_kv_heads, 1)
            cache["kv_scale"] = (jnp.ones(s_shape, jnp.float32),
                                 jnp.ones(s_shape, jnp.float32))
    if cfg.ssm is not None:
        nh = cfg.n_ssm_heads
        p = cfg.ssm.head_dim
        cache["ssm"] = jnp.zeros((nl, batch, nh, p, cfg.ssm.state_dim),
                                 jnp.float32)
        conv_dim = cfg.d_inner + 2 * cfg.ssm.state_dim
        cache["conv"] = jnp.zeros(
            (nl, batch, cfg.ssm.conv_kernel - 1, conv_dim), dtype)
    return cache


def _cache_layer(cache, i=None):
    """Slice / restructure helpers handled by scan's xs mechanism."""
    return cache


def _serve_scan(cfg: ArchConfig, params, x, positions, cache, pos):
    kinds = layer_kinds(cfg)

    def body(carry, scanned):
        xc = carry
        blk, kind, layer_cache = scanned
        lc = {}
        if "kv" in layer_cache:
            lc["kv"] = layer_cache["kv"]
            if "kv_scale" in layer_cache:
                lc["kv_scale"] = layer_cache["kv_scale"]
        if "ssm" in layer_cache:
            lc["ssm"] = layer_cache["ssm"]
            lc["conv"] = layer_cache["conv"]
        xc, _, new_lc = _block_apply(cfg, blk, kind, xc, positions,
                                     cache=lc, pos=pos)
        out = {}
        if "kv" in new_lc:
            out["kv"] = tuple(a.astype(layer_cache["kv"][0].dtype)
                              for a in new_lc["kv"])
            if "kv_scale" in new_lc:
                out["kv_scale"] = new_lc["kv_scale"]
        if "ssm" in new_lc:
            out["ssm"] = new_lc["ssm"]
            out["conv"] = new_lc["conv"].astype(layer_cache["conv"].dtype)
        return xc, out

    x, new_cache = _scan(body, x, (params["blocks"], kinds, cache))
    return x, new_cache


def prefill(cfg: ArchConfig, params, batch, cache):
    """Process the prompt, fill the cache.  Returns (last_logits, cache)."""
    x, positions = _embed_inputs(cfg, params, batch)
    x, new_cache = _serve_scan(cfg, params, x, positions, cache,
                               pos=jnp.zeros((), jnp.int32))
    x = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return (x @ _head_matrix(cfg, params))[:, 0], new_cache


def decode_step(cfg: ArchConfig, params, cache, token, pos):
    """One decode step.  token (B, 1) int32 or embeds (B,1,d); pos scalar.

    This is the PIM-offload target: with batch B it is a batch of GEMVs
    against every projection matrix (see serving/offload.py).
    """
    if cfg.input_mode == "embeddings":
        x = token  # (B, 1, d) frame embedding (modality stub)
    else:
        emb = params["embed"]
        if Q.is_bundle(emb):
            rows = jnp.take(emb["q"], token, axis=0)
            x = (rows.astype(jnp.float32) * emb["s"]).astype(
                params["ln_f"].dtype)
        else:
            x = jnp.take(emb, token, axis=0)
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None], (b, 1)) \
        if jnp.ndim(pos) == 0 else pos[:, None]
    x, new_cache = _serve_scan(cfg, params, x, positions, cache, pos=pos)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return (x @ _head_matrix(cfg, params))[:, 0], new_cache


# ---------------------------------------------------------------------
# Dry-run input specs (no allocation)
# ---------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                param_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    out: dict = {}
    if shape.kind == "train":
        if cfg.input_mode == "embeddings":
            batch = {"embeds": f((b, s, cfg.d_model), param_dtype),
                     "labels": f((b, s), jnp.int32)}
        else:
            toks = s - cfg.prefix_patches
            batch = {"tokens": f((b, toks), jnp.int32),
                     "labels": f((b, toks), jnp.int32)}
            if cfg.prefix_patches:
                batch["patches"] = f((b, cfg.prefix_patches, cfg.d_model),
                                     param_dtype)
        out["batch"] = batch
    elif shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            out["batch"] = {"embeds": f((b, s, cfg.d_model), param_dtype)}
        else:
            toks = s - cfg.prefix_patches
            out["batch"] = {"tokens": f((b, toks), jnp.int32)}
            if cfg.prefix_patches:
                out["batch"]["patches"] = f(
                    (b, cfg.prefix_patches, cfg.d_model), param_dtype)
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, b, s, jnp.bfloat16))
    else:  # decode
        if cfg.input_mode == "embeddings":
            out["token"] = f((b, 1, cfg.d_model), param_dtype)
        else:
            out["token"] = f((b, 1), jnp.int32)
        out["pos"] = f((), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, b, s, jnp.bfloat16))
    return out


def param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    """ShapeDtypeStruct tree of the parameters (dry-run, no allocation)."""
    def build(key):
        p = init_params(cfg, key, dtype=dtype)
        if QUANT_BITS:
            p = quantize_for_serving(p, QUANT_BITS)
        return p
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def quantize_for_serving(params, w_bits: int):
    """Quantize matmul weights (embedding stays 8-bit for row gather)."""
    emb = params.get("embed")
    out = Q.quantize_params(params, w_bits)
    if w_bits == 4 and emb is not None:
        out["embed"] = Q.quantize_params({"embed": emb}, 8)["embed"]
    return out
