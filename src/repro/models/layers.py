"""Core transformer layers, pure JAX (no framework dependencies).

Everything here is shape-polymorphic over a leading batch and works in
three modes: training (full sequence), prefill (full sequence + returns KV
cache) and decode (single token against a cache).  Long sequences use a
blockwise streaming-softmax attention (two nested ``lax.scan``s over query
/ key blocks) so the 32k prefill and 500k decode shapes lower without
materializing S x S score tensors.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Sequences longer than this use the blockwise streaming-softmax path.
FLASH_THRESHOLD = 2048

# §Perf knob: skip causal upper-triangle (q-block, k-block) pairs in the
# blockwise attention.  Statically halves executed attention FLOPs (the
# white-box account in distribution.roofline tracks executed blocks).
# Window-block skipping would additionally need static per-layer kinds
# (the layer scan traces them) — documented future work.
FLASH_SKIP_BLOCKS = False


def rms_norm(x, gamma, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin],
        axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------

def _mask(qi, ki, window):
    m = ki[None, :] <= qi[:, None]
    if window is not None:
        m &= (qi[:, None] - ki[None, :]) < window
    return m


def dense_attention(q, k, v, *, window=None, q_offset=0, kv_len=None):
    """Quadratic-path GQA attention (short sequences / decode).

    q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd).  ``q_offset`` is the
    absolute position of q[0] — scalar, or (B,) for ragged decode slots;
    ``kv_len`` (scalar or (B,)) masks the valid cache prefix when Sk is a
    padded cache.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    qo = jnp.asarray(q_offset)
    qi = (qo[:, None] if qo.ndim == 1 else qo) + jnp.arange(sq)
    qi = jnp.broadcast_to(qi.reshape(-1, sq) if qi.ndim > 1
                          else qi[None], (qi.shape[0] if qi.ndim > 1
                                          else 1, sq))
    ki = jnp.arange(sk)
    mask = ki[None, None, :] <= qi[..., None]          # (B|1, sq, sk)
    if window is not None:
        mask &= (qi[..., None] - ki[None, None, :]) < window
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        kl = kl[:, None, None] if kl.ndim == 1 else kl
        mask &= ki[None, None, :] < kl
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, hq, hd)


def flash_attention(q, k, v, *, window=None, q_offset=0,
                    block_q: int = 512, block_k: int = 512):
    """Blockwise streaming-softmax attention (prefill / train on long S).

    Never materializes more than (B, Hkv, G, block_q, block_k) scores.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq = -(-sq // bq)
    nk = -(-sk // bk)
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * bk - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * bk - sk), (0, 0), (0, 0)))
    qb = qp.reshape(b, nq, bq, hkv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = kp.reshape(b, nk, bk, hkv, hd).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(b, nk, bk, hkv, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / math.sqrt(hd)

    def kv_scan(qblk, qi, kb_sel, vb_sel, k_idx):
        def kv_step(carry, kv_blk):
            m, l, acc = carry
            ki_idx, kblk, vblk = kv_blk
            ki = ki_idx * bk + jnp.arange(bk)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qi, ki, window) & (ki < sk)[None, :]
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(vblk.dtype), vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_idx, kb_sel, vb_sel))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if FLASH_SKIP_BLOCKS and q_offset == 0:
        # static q-block loop; k blocks limited to the causal triangle
        outs = []
        for qi_idx in range(nq):
            qi = qi_idx * bq + jnp.arange(bq)
            # k blocks overlapping the causal range of this q block
            hi = min(nk, -(-((qi_idx + 1) * bq) // bk))
            o = kv_scan(qb[qi_idx], qi, kb[:hi], vb[:hi],
                        jnp.arange(hi))
            outs.append(o.astype(q.dtype))
        ob = jnp.stack(outs)
    else:
        def q_step(_, qi_blk):
            qi_idx, qblk = qi_blk                  # (b, hkv, g, bq, hd)
            qi = q_offset + qi_idx * bq + jnp.arange(bq)
            out = kv_scan(qblk, qi, kb, vb, jnp.arange(nk))
            return None, out.astype(q.dtype)

        _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * bq, hq, hd)
    return out[:, :sq]


def attention(q, k, v, *, window=None, q_offset=0, kv_len=None,
              flash_threshold: int | None = None):
    if flash_threshold is None:
        flash_threshold = FLASH_THRESHOLD
    if q.shape[1] == 1 or k.shape[1] <= flash_threshold:
        return dense_attention(q, k, v, window=window, q_offset=q_offset,
                               kv_len=kv_len)
    assert kv_len is None, "flash path expects unpadded kv"
    return flash_attention(q, k, v, window=window, q_offset=q_offset)


# ---------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------

def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    else:  # gelu
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


def mlp_init(key, d, ff, kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(ff)
    p = {"wi": jax.random.normal(ks[0], (d, ff), dtype) * scale_in,
         "wo": jax.random.normal(ks[1], (ff, d), dtype) * scale_out}
    if kind == "swiglu":
        p["wg"] = jax.random.normal(ks[2], (d, ff), dtype) * scale_in
    return p


def mlp_logical(kind: str):
    p = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if kind == "swiglu":
        p["wg"] = ("embed", "mlp")
    return p
