"""Mixture-of-Experts block: top-k routing, dropless by default.

GShard-style position-in-expert dispatch (one (N, E) cumsum per top-k
slot) — O(N·E) intermediates, no (N, E, C) dispatch tensors and no global
sort.  Capacity is derived from the flattened token count so it never
binds (routing is batching-invariant — prefill, teacher-forced decode
and B>1 decode steps agree exactly); pass ``drop_tokens=True`` to get
the legacy capacity-factor-bounded buffer for memory-constrained
training (the 1M-token train_4k cells).  Experts are sharded on the
model axis; the scatters/gathers lower to the expected all-to-all-class
collectives under SPMD.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# §Perf knob: PartitionSpec dims for the (e*cap, d) dispatch buffer.
# None = let SPMD choose (baseline — the partitioner replicates it, which
# the roofline exposes as massive all-gather traffic); ("data", None)
# shards the capacity rows so dispatch lowers to all-to-all-class
# traffic.  Only consulted when tracing under a mesh (dry-run/launcher).
DISPATCH_SPEC = None


def _constrain(x, spec):
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except (ValueError, RuntimeError):
        return x  # no mesh in context (unit tests)


def moe_init(key, d, ff, n_experts, mlp_kind, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    p = {
        "router": jax.random.normal(ks[0], (d, n_experts), dtype) * si,
        "wi": jax.random.normal(ks[1], (n_experts, d, ff), dtype) * si,
        "wo": jax.random.normal(ks[2], (n_experts, ff, d), dtype) * so,
    }
    if mlp_kind == "swiglu":
        p["wg"] = jax.random.normal(ks[3], (n_experts, d, ff), dtype) * si
    return p


def moe_logical(mlp_kind: str):
    p = {"router": ("embed", "experts"),
         "wi": ("experts", "embed", "mlp"),
         "wo": ("experts", "mlp", "embed")}
    if mlp_kind == "swiglu":
        p["wg"] = ("experts", "embed", "mlp")
    return p


def moe_apply(params, x, *, top_k: int, capacity_factor: float,
              mlp_kind: str, drop_tokens: bool = False):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss.

    Routing is *dropless* by default: expert capacity is derived from the
    flattened token count ``n`` such that it can never bind — a token
    lands in a given expert through at most one of its (distinct) top-k
    slots, so ``cap = n`` always holds every assignment.  That makes the
    routing decision independent of how the same tokens are batched,
    which is what prefill/decode consistency requires: the legacy
    per-call GShard capacity ``ceil(n*k*cf/e)`` shrank with ``n``, so a
    decode-shaped call (B, S=1) silently dropped batch rows > 0 whose
    position-in-expert (a cumsum across the flattened *batch* rows)
    overflowed the tiny per-step capacity (see
    ``test_moe_decode_drops_batch_rows``).  ``drop_tokens=True`` restores
    the capacity-factor-bounded dispatch buffer for memory-constrained
    training runs (the 1M-token train_4k cells), accepting the drops.
    """
    b, s, d = x.shape
    e = params["router"].shape[-1]
    n = b * s
    xt = x.reshape(n, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, math.ceil(n * top_k * capacity_factor / e))) \
        if drop_tokens else n

    # GShard dispatch: per top-k slot, position-in-expert via cumsum.
    buf = _constrain(jnp.zeros((e * cap, d), xt.dtype), DISPATCH_SPEC)
    locs = []
    counts = jnp.zeros((e,), jnp.int32)
    for slot in range(top_k):
        onehot = jax.nn.one_hot(gate_idx[:, slot], e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot + counts[None, :]
        counts = counts + onehot.sum(axis=0)
        pos_tok = (pos * onehot).sum(-1)                     # (N,)
        ok = pos_tok < cap
        idx = jnp.where(ok, gate_idx[:, slot] * cap + pos_tok, e * cap)
        buf = _constrain(buf.at[idx].add(xt, mode="drop"), DISPATCH_SPEC)
        locs.append((idx, ok))

    he = buf.reshape(e, cap, d)
    if mlp_kind == "swiglu":
        hid = jax.nn.silu(jnp.einsum("ecd,edf->ecf", he, params["wg"])) \
            * jnp.einsum("ecd,edf->ecf", he, params["wi"])
    else:
        hid = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", he, params["wi"]))
    out_e = jnp.einsum("ecf,efd->ecd", hid, params["wo"])
    out_flat = out_e.reshape(e * cap, d)

    y = jnp.zeros_like(xt)
    for slot, (idx, ok) in enumerate(locs):
        gathered = jnp.take(out_flat, jnp.minimum(idx, e * cap - 1),
                            axis=0)
        w = (gate_vals[:, slot] * ok).astype(y.dtype)
        y = y + gathered * w[:, None]

    from jax.ad_checkpoint import checkpoint_name
    y = checkpoint_name(y, "moe_out")
    # Switch-style load-balance aux loss.
    frac_tokens = jax.nn.one_hot(gate_idx[:, 0], e).mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(b, s, d), aux
