"""Weight quantization for serving (the paper's W8/W4 formats on TPU).

LP5X-PIM wins by streaming quantized weights; the TPU serving analogue is
storing matmul weights as int8 (or nibble-packed int4) + per-output-channel
scales and dequantizing on use — HBM reads shrink 2x/4x, which is exactly
the dominant roofline term of the TP decode cells (§Perf iteration 2).

``quantize_params`` transforms the bf16/f32 parameter tree: every large
matmul leaf becomes ``{"q": int8[...], "s": f32[..., 1, out]}``; the
models dequantize on use (XLA fuses the convert into the consumer, so HBM
reads stay int8).  Numerics mirror ``kernels/ref.py`` (symmetric,
per-output-channel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# parameter names that get quantized (2D+ matmul weights)
QUANT_KEYS = {"embed", "lm_head", "patch_proj", "wq", "wk", "wv", "wo",
              "wi", "wg", "in_proj", "out_proj"}


def is_bundle(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"q", "s"}


def _quantize_leaf(w, w_bits: int):
    w = jnp.asarray(w, jnp.float32)
    qmax = 2 ** (w_bits - 1) - 1
    scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    if w_bits == 4:
        lo = q[..., 0::2, :] & 0xF
        hi = q[..., 1::2, :] & 0xF
        q = (lo | (hi << 4)).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def dequant_leaf(leaf, w_bits: int, dtype=jnp.bfloat16):
    q = leaf["q"]
    if w_bits == 4:
        lo = jnp.right_shift(jnp.left_shift(q, 4), 4)
        hi = jnp.right_shift(q, 4)
        q = jnp.stack([lo, hi], axis=-2).reshape(
            *q.shape[:-2], q.shape[-2] * 2, q.shape[-1])
    return (q.astype(jnp.float32) * leaf["s"]).astype(dtype)


def _walk(d, fn):
    out = {}
    for k, v in d.items():
        if is_bundle(v):
            out[k] = v
        elif isinstance(v, dict):
            out[k] = _walk(v, fn)
        else:
            out[k] = fn(k, v)
    return out


def quantize_params(params, w_bits: int = 8):
    """bf16/f32 param tree -> serving tree with quantized matmul leaves."""
    def fn(k, v):
        if k in QUANT_KEYS and hasattr(v, "ndim") and v.ndim >= 2 and \
                v.shape[-2] % 2 == 0:
            return _quantize_leaf(v, w_bits)
        return v
    return _walk(params, fn)


def dequant_tree(tree, w_bits: int = 8, dtype=jnp.bfloat16):
    """Dequantize every {"q","s"} bundle in a (sub)tree on use."""
    if is_bundle(tree):
        return dequant_leaf(tree, w_bits, dtype)
    if isinstance(tree, dict):
        return {k: dequant_tree(v, w_bits, dtype)
                for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(dequant_tree(v, w_bits, dtype) for v in tree)
    return tree


def quantize_logical(logical):
    """Transform the logical-axis tree alongside quantize_params."""
    def fn(k, v):
        if k in QUANT_KEYS and isinstance(v, tuple) and len(v) >= 2:
            return {"q": v, "s": v[:-2] + (None, v[-1])}
        return v
    return _walk(logical, fn)
