"""Mamba2 / SSD (state-space duality) block, chunked scan + decode step.

Follows the minimal SSD formulation of arXiv:2405.21060: within a chunk
the output is computed in dual (attention-like) form with the decay mask
L, across chunks a small recurrence over the (heads, head_dim, state)
tensor carries the SSM state.  Single B/C group shared across heads.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SsmConfig


def ssm_init(key, d_model, ssm: SsmConfig, dtype=jnp.float32):
    d_in = ssm.expand * d_model
    nh = d_in // ssm.head_dim
    n = ssm.state_dim
    k = ssm.conv_kernel
    ks = jax.random.split(key, 5)
    si = 1.0 / math.sqrt(d_model)
    conv_dim = d_in + 2 * n
    return {
        # projects to [z | x | B | C | dt]
        "in_proj": jax.random.normal(
            ks[0], (d_model, 2 * d_in + 2 * n + nh), dtype) * si,
        "conv_w": jax.random.normal(ks[1], (k, conv_dim), dtype) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(dtype)),
        "d_skip": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": jax.random.normal(ks[2], (d_in, d_model), dtype)
        * (1.0 / math.sqrt(d_in)),
    }


def ssm_logical():
    return {"in_proj": ("embed", "ssm_inner"), "conv_w": (None, "ssm_inner"),
            "conv_b": ("ssm_inner",), "a_log": ("ssm_heads",),
            "d_skip": ("ssm_heads",), "dt_bias": ("ssm_heads",),
            "norm": ("ssm_inner",), "out_proj": ("ssm_inner", "embed")}


def _split(params, d_in, n, nh, proj):
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return z, xin, bmat, cmat, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv, kernel k: x (B, S, C), w (k, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _segsum_chunk(dA):
    """dA (..., Q) -> cumulative log-decay L (..., Q, Q), lower-triangular."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # seg[i] - seg[j]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(xh, dt, a_log, bmat, cmat, chunk: int):
    """Chunked SSD.  xh (B,S,nh,p), dt (B,S,nh), bmat/cmat (B,S,N).

    Returns y (B,S,nh,p) and final state (B,nh,p,N).
    """
    b, s, nh, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    xc = xh.reshape(b, nc, q, nh, p)
    dtc = dt.reshape(b, nc, q, nh)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    a = -jnp.exp(a_log.astype(jnp.float32))             # (nh,) negative
    dA = dtc.astype(jnp.float32) * a                    # (b,nc,q,nh)
    dAh = jnp.moveaxis(dA, -1, 2)                       # (b,nc,nh,q)
    lmat = jnp.exp(_segsum_chunk(dAh))                  # (b,nc,nh,q,q)

    # intra-chunk (dual / attention-like form)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)          # (b,nc,q,q)
    dtx = xc * dtc[..., None]                           # (b,nc,q,nh,p)
    y_intra = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", cb, lmat,
                         dtx.astype(jnp.float32))

    # chunk summaries -> inter-chunk recurrence
    seg = jnp.cumsum(dAh, axis=-1)                      # (b,nc,nh,q)
    decay_to_end = jnp.exp(seg[..., -1:] - seg)         # (b,nc,nh,q)
    s_chunk = jnp.einsum("bchk,bckn,bckhp->bchpn", decay_to_end, bc,
                         dtx.astype(jnp.float32))       # (b,nc,nh,p,n)
    chunk_decay = jnp.exp(seg[..., -1])                 # (b,nc,nh)

    def step(h, inp):
        s_c, dec = inp                                  # (b,nh,p,n),(b,nh)
        y_state = h                                     # state BEFORE chunk
        h_new = h * dec[..., None, None] + s_c
        return h_new, y_state

    h0 = jnp.zeros((b, nh, p, n), jnp.float32)
    hT, h_prev = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                 # (b,nc,nh,p,n)

    decay_from_start = jnp.exp(seg)                     # (b,nc,nh,q)
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bcqhp", cc, decay_from_start,
                         h_prev)
    y = (y_intra + y_inter).reshape(b, nc * q, nh, p)[:, :s]
    return y.astype(xh.dtype), hT


def ssm_block(params, x, ssm: SsmConfig, state=None, conv_state=None):
    """Full Mamba2 mixer.  Train/prefill: state=None -> chunked scan.
    Decode (S==1): pass (state, conv_state), returns updated states.

    Returns (y, new_state, new_conv_state).
    """
    b, s, _ = x.shape
    d_in = params["out_proj"].shape[0]
    nh = params["a_log"].shape[0]
    p = d_in // nh
    n = ssm.state_dim
    proj = x @ params["in_proj"]
    z, xin, bmat, cmat, dt = _split(params, d_in, n, nh, proj)
    xbc = jnp.concatenate([xin, bmat, cmat], axis=-1)

    if s == 1 and conv_state is not None:
        # decode: roll the conv window
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B,k,conv)
        conv_out = (window * params["conv_w"]).sum(axis=1, keepdims=True) \
            + params["conv_b"]
        new_conv_state = window[:, 1:]
    else:
        conv_out = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        k = params["conv_w"].shape[0]
        tail = jnp.concatenate([jnp.zeros((b, k - 1, xbc.shape[-1]),
                                          xbc.dtype), xbc], axis=1)
        new_conv_state = tail[:, -(k - 1):]
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"])        # (B,S,nh)
    xh = xin.reshape(b, s, nh, p)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    if s == 1 and state is not None:
        # recurrent decode step
        dA = jnp.exp(dt[:, 0].astype(jnp.float32) * a)  # (B,nh)
        dbx = jnp.einsum("bn,bhp,bh->bhpn", bmat[:, 0], xh[:, 0],
                         dt[:, 0].astype(jnp.float32))
        new_state = state * dA[..., None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], new_state)
        y = y[:, None]                                  # (B,1,nh,p)
    else:
        y, new_state = ssd_scan(xh, dt, params["a_log"], bmat, cmat,
                                ssm.chunk)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in)
    # gated RMSNorm (mamba2)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * (1 + params["norm"])
    y = (y * jax.nn.silu(z)).astype(x.dtype)
    return y @ params["out_proj"], new_state, new_conv_state
